"""L2: the FerrisFL model zoo (JAX, calling the L1 Pallas kernels).

Mirrors TorchFL's ``models`` library (paper Table 2): model *families*
with *variants*, each exposing the flat-parameter ABI that the rust
coordinator consumes (DESIGN.md §Flat-parameter ABI).

Families:
  - ``mlp``       — mlp-s / mlp-m / mlp-l          (paper: MLP)
  - ``lenet``     — lenet5                          (paper: LeNet)
  - ``cnn``       — cnn-s / cnn-m / cnn-l           (paper: VGG/AlexNet class)
  - ``micronet``  — micronet-05 / micronet-10       (paper: MobileNet class)

Every variant supports the three training modes the paper evaluates:
``scratch``, ``finetune`` (warm start, all params trainable) and
``featext`` (warm start, only the classifier head trains).
"""

from .registry import (
    MODEL_REGISTRY,
    ModelSpec,
    build_model,
    list_variants,
)

__all__ = ["MODEL_REGISTRY", "ModelSpec", "build_model", "list_variants"]
