"""Layer mini-framework over the L1 Pallas kernels.

Each layer declares its parameter shapes (so the model can be flattened
into the single ``f32[P]`` vector the rust coordinator owns) and an
``apply`` over a list of unflattened parameter arrays.

MXU work (dense, conv-as-im2col-matmul) goes through the Pallas kernels;
pure data-movement / VPU work (pooling, flatten, depthwise conv) is plain
jnp, which XLA fuses around the kernels.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .. import kernels as K

Shape = tuple[int, ...]


class Layer:
    """Base layer: parameter introspection + functional apply."""

    def param_shapes(self, in_shape: Shape) -> tuple[list[Shape], Shape]:
        """Return (list of parameter shapes, output shape) for ``in_shape``
        (shape of a single example, no batch dim)."""
        raise NotImplementedError

    def apply(self, params: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
        """Apply to a batched input ``x`` (leading batch dim)."""
        raise NotImplementedError

    def init_scale(self, shape: Shape, in_shape: Shape) -> float:
        """He-style fan-in init scale for a parameter of ``shape``."""
        fan_in = int(math.prod(in_shape))
        return math.sqrt(2.0 / max(fan_in, 1))


@dataclasses.dataclass
class Dense(Layer):
    """Fully-connected layer via the fused Pallas dense kernel."""

    units: int
    act: str = "relu"

    def param_shapes(self, in_shape):
        (d,) = in_shape
        return [(d, self.units), (self.units,)], (self.units,)

    def apply(self, params, x):
        w, b = params
        return K.dense(x, w, b, self.act)


@dataclasses.dataclass
class Conv(Layer):
    """Convolution via im2col + the Pallas MXU matmul."""

    filters: int
    kernel: int = 3
    stride: int = 1
    pad: int = 1
    act: str = "relu"

    def param_shapes(self, in_shape):
        h, w, c = in_shape
        oh = (h + 2 * self.pad - self.kernel) // self.stride + 1
        ow = (w + 2 * self.pad - self.kernel) // self.stride + 1
        return (
            [(self.kernel, self.kernel, c, self.filters), (self.filters,)],
            (oh, ow, self.filters),
        )

    def apply(self, params, x):
        w, b = params
        return K.conv2d(x, w, b, self.stride, self.pad, self.act)


@dataclasses.dataclass
class DepthwiseConv(Layer):
    """Depthwise 3x3 conv (MicroNet family).

    Channel-wise spatial filtering is VPU work, not MXU work, so it is
    expressed as shifted-slice multiplies in plain jnp (the TPU analogue of
    a CUDA depthwise kernel that never touches tensor cores); the paired
    pointwise 1x1 conv (a real matmul) goes through the Pallas kernel.
    """

    kernel: int = 3
    stride: int = 1
    pad: int = 1
    act: str = "linear"

    def param_shapes(self, in_shape):
        h, w, c = in_shape
        oh = (h + 2 * self.pad - self.kernel) // self.stride + 1
        ow = (w + 2 * self.pad - self.kernel) // self.stride + 1
        return [(self.kernel, self.kernel, c), (c,)], (oh, ow, c)

    def apply(self, params, x):
        w, b = params
        if self.pad:
            x = jnp.pad(
                x, ((0, 0), (self.pad, self.pad), (self.pad, self.pad), (0, 0))
            )
        _, h, ww, c = x.shape
        oh = (h - self.kernel) // self.stride + 1
        ow = (ww - self.kernel) // self.stride + 1
        acc = jnp.zeros((x.shape[0], oh, ow, c), x.dtype)
        for i in range(self.kernel):
            for j in range(self.kernel):
                sl = x[
                    :,
                    i : i + oh * self.stride : self.stride,
                    j : j + ow * self.stride : self.stride,
                    :,
                ]
                acc = acc + sl * w[i, j][None, None, None, :]
        y = acc + b
        if self.act == "relu":
            y = jnp.maximum(y, 0.0)
        return y


@dataclasses.dataclass
class PointwiseConv(Layer):
    """1x1 conv == per-pixel matmul on the MXU via the Pallas kernel."""

    filters: int
    act: str = "relu"

    def param_shapes(self, in_shape):
        h, w, c = in_shape
        return [(c, self.filters), (self.filters,)], (h, w, self.filters)

    def apply(self, params, x):
        w, b = params
        bsz, h, ww, c = x.shape
        y = K.dense(x.reshape(bsz * h * ww, c), w, b, self.act)
        return y.reshape(bsz, h, ww, self.filters)


@dataclasses.dataclass
class AvgPool(Layer):
    k: int = 2

    def param_shapes(self, in_shape):
        h, w, c = in_shape
        return [], (h // self.k, w // self.k, c)

    def apply(self, params, x):
        return K.avg_pool(x, self.k)


@dataclasses.dataclass
class MaxPool(Layer):
    k: int = 2

    def param_shapes(self, in_shape):
        h, w, c = in_shape
        return [], (h // self.k, w // self.k, c)

    def apply(self, params, x):
        return K.max_pool(x, self.k)


@dataclasses.dataclass
class Flatten(Layer):
    def param_shapes(self, in_shape):
        return [], (int(math.prod(in_shape)),)

    def apply(self, params, x):
        return x.reshape(x.shape[0], -1)


@dataclasses.dataclass
class GlobalAvgPool(Layer):
    """Spatial mean -> feature vector (MicroNet head input)."""

    def param_shapes(self, in_shape):
        h, w, c = in_shape
        return [], (c,)

    def apply(self, params, x):
        return jnp.mean(x, axis=(1, 2))
