"""The FerrisFL model zoo registry (paper Table 2 analogue).

A ``ModelSpec`` names a variant and builds its layer stack for a given
input shape / class count.  ``build_model`` instantiates a ``Model`` —
the object that owns the flat-parameter layout and the forward pass.

The registry mirrors TorchFL's family/variant structure:

  family     variants                  featext  finetune
  ---------  ------------------------  -------  --------
  mlp        mlp-s, mlp-m, mlp-l       yes      yes
  lenet      lenet5                    yes      yes
  cnn        cnn-s, cnn-m, cnn-l       yes      yes
  micronet   micronet-05, micronet-10  yes      yes

(TorchFL marks ALEXNET/LENET/MLP as not supporting transfer modes because
torchvision ships no ImageNet weights for them; our pretraining substrate
pre-trains every variant, so every variant supports both modes.)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (
    AvgPool,
    Conv,
    Dense,
    DepthwiseConv,
    Flatten,
    GlobalAvgPool,
    Layer,
    MaxPool,
    PointwiseConv,
)

Shape = tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A zoo entry: family, variant name, and a layer-stack builder."""

    family: str
    variant: str
    build: Callable[[Shape, int], list[Layer]]
    description: str = ""


class Model:
    """A concrete model: layer stack + flat-parameter layout.

    The flat layout is the contract with the rust coordinator: parameters
    of every layer, in order, each flattened C-order, concatenated into a
    single ``f32[P]``.  The classifier head (the final Dense) occupies the
    trailing slice ``[P - head_size, P)`` — featext mode trains only that
    slice.
    """

    def __init__(self, spec: ModelSpec, input_shape: Shape, num_classes: int):
        self.spec = spec
        self.input_shape = tuple(input_shape)
        self.num_classes = num_classes
        self.layers = spec.build(self.input_shape, num_classes)

        # Walk shapes once to freeze the layout.
        self.param_shapes: list[Shape] = []
        self.layer_param_counts: list[int] = []
        shape = self.input_shape
        for layer in self.layers:
            shapes, shape = layer.param_shapes(shape)
            self.param_shapes.extend(shapes)
            self.layer_param_counts.append(len(shapes))
        if shape != ():
            assert shape == (num_classes,), (
                f"{spec.variant}: final shape {shape} != ({num_classes},)"
            )
        self.sizes = [int(math.prod(s)) for s in self.param_shapes]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(int)
        self.num_params = int(self.offsets[-1])

        # Head = parameters of the last layer that has any.
        head_layers = [i for i, n in enumerate(self.layer_param_counts) if n]
        assert head_layers, f"{spec.variant} has no parameters"
        last = head_layers[-1]
        n_before = sum(self.layer_param_counts[:last])
        self.head_size = sum(self.sizes[n_before:])

    # ------------------------------------------------------------- params

    def unflatten(self, flat: jnp.ndarray) -> list[jnp.ndarray]:
        """Split ``f32[P]`` into per-parameter arrays (zero-copy views)."""
        out = []
        for shape, size, off in zip(self.param_shapes, self.sizes, self.offsets):
            out.append(jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape))
        return out

    def init(self, seed: int) -> np.ndarray:
        """He-initialised flat parameter vector (numpy, host side)."""
        rng = np.random.default_rng(seed)
        chunks = []
        for shape in self.param_shapes:
            if len(shape) == 1:  # biases start at zero
                chunks.append(np.zeros(shape, np.float32))
            else:
                fan_in = int(math.prod(shape[:-1]))
                scale = math.sqrt(2.0 / max(fan_in, 1))
                chunks.append(
                    (rng.standard_normal(shape) * scale).astype(np.float32)
                )
        return np.concatenate([c.ravel() for c in chunks])

    def head_mask(self) -> np.ndarray:
        """``f32[P]`` mask: 1.0 on the classifier-head slice, else 0."""
        mask = np.zeros(self.num_params, np.float32)
        mask[self.num_params - self.head_size :] = 1.0
        return mask

    # ------------------------------------------------------------ forward

    def forward(
        self, flat: jnp.ndarray, x: jnp.ndarray, freeze_backbone: bool = False
    ) -> jnp.ndarray:
        """Logits for a batch ``x: f32[B, *input_shape]``.

        With ``freeze_backbone=True`` a ``stop_gradient`` is inserted at
        the classifier-head input, so reverse-mode AD never *builds* the
        backbone backward pass — this is what makes feature extraction
        genuinely cheaper per step (paper Table 3), not just masked.
        """
        params = self.unflatten(flat)
        head_li = max(
            i for i, n in enumerate(self.layer_param_counts) if n > 0
        )
        idx = 0
        for li, (layer, n) in enumerate(
            zip(self.layers, self.layer_param_counts)
        ):
            if freeze_backbone and li == head_li:
                x = jax.lax.stop_gradient(x)
            x = layer.apply(params[idx : idx + n], x)
            idx += n
        return x


# ----------------------------------------------------------------- zoo


def _mlp(hidden: Sequence[int]):
    def build(input_shape: Shape, num_classes: int) -> list[Layer]:
        layers: list[Layer] = [Flatten()]
        for h in hidden:
            layers.append(Dense(h, "relu"))
        layers.append(Dense(num_classes, "linear"))
        return layers

    return build


def _lenet5(input_shape: Shape, num_classes: int) -> list[Layer]:
    """Classic LeNet-5 (tanh/avg-pool flavour), as in the paper's Fig 8."""
    return [
        Conv(6, kernel=5, stride=1, pad=2, act="tanh"),
        AvgPool(2),
        Conv(16, kernel=5, stride=1, pad=0, act="tanh"),
        AvgPool(2),
        Flatten(),
        Dense(120, "tanh"),
        Dense(84, "tanh"),
        Dense(num_classes, "linear"),
    ]


def _cnn(widths: Sequence[int], dense: int):
    """VGG-ish conv stack: [conv-conv-pool] blocks + classifier."""

    def build(input_shape: Shape, num_classes: int) -> list[Layer]:
        layers: list[Layer] = []
        for w in widths:
            layers.append(Conv(w, kernel=3, stride=1, pad=1, act="relu"))
            layers.append(Conv(w, kernel=3, stride=1, pad=1, act="relu"))
            layers.append(MaxPool(2))
        layers.append(Flatten())
        layers.append(Dense(dense, "relu"))
        layers.append(Dense(num_classes, "linear"))
        return layers

    return build


def _micronet(width_mult: float):
    """MobileNet-style depthwise-separable stack (paper: MobileNetV3Small
    stand-in for the federated-transfer experiment, Fig 8ii)."""

    def c(base: int) -> int:
        return max(8, int(base * width_mult))

    def build(input_shape: Shape, num_classes: int) -> list[Layer]:
        return [
            Conv(c(16), kernel=3, stride=2, pad=1, act="relu"),
            DepthwiseConv(kernel=3, stride=1, pad=1, act="relu"),
            PointwiseConv(c(32), act="relu"),
            DepthwiseConv(kernel=3, stride=2, pad=1, act="relu"),
            PointwiseConv(c(64), act="relu"),
            DepthwiseConv(kernel=3, stride=1, pad=1, act="relu"),
            PointwiseConv(c(64), act="relu"),
            GlobalAvgPool(),
            Dense(num_classes, "linear"),
        ]

    return build


MODEL_REGISTRY: dict[str, ModelSpec] = {
    "mlp-s": ModelSpec("mlp", "mlp-s", _mlp([128]), "1 hidden layer, 128"),
    "mlp-m": ModelSpec("mlp", "mlp-m", _mlp([256, 128]), "2 hidden layers"),
    "mlp-l": ModelSpec("mlp", "mlp-l", _mlp([512, 256, 128]), "3 hidden layers"),
    "lenet5": ModelSpec("lenet", "lenet5", _lenet5, "classic LeNet-5"),
    "cnn-s": ModelSpec("cnn", "cnn-s", _cnn([16, 32], 128), "small VGG-ish"),
    "cnn-m": ModelSpec("cnn", "cnn-m", _cnn([32, 64], 256), "medium VGG-ish"),
    "cnn-l": ModelSpec("cnn", "cnn-l", _cnn([64, 128], 512), "large VGG-ish"),
    "micronet-05": ModelSpec(
        "micronet", "micronet-05", _micronet(0.5), "0.5x depthwise-separable"
    ),
    "micronet-10": ModelSpec(
        "micronet", "micronet-10", _micronet(1.0), "1.0x depthwise-separable"
    ),
}


def list_variants() -> list[str]:
    return sorted(MODEL_REGISTRY)


def build_model(variant: str, input_shape: Shape, num_classes: int) -> Model:
    """Instantiate a zoo variant for a dataset's input shape/classes."""
    if variant not in MODEL_REGISTRY:
        raise KeyError(
            f"unknown model {variant!r}; available: {list_variants()}"
        )
    return Model(MODEL_REGISTRY[variant], input_shape, num_classes)
