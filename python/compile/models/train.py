"""Training/eval step factories over the flat-parameter ABI.

These are the functions ``aot.py`` lowers to HLO text; the rust
coordinator executes them via PJRT with no python anywhere near the loop.

Signatures (all f32 unless noted):

  train_step_sgd  (params[P], x[B,...], y i32[B], lr[])
                  -> (params'[P], loss[], correct[])
  train_step_adam (params[P], m[P], v[P], t[], x, y, lr[])
                  -> (params'[P], m'[P], v'[P], t'[], loss[], correct[])
  eval_step       (params[P], x[Be,...], y i32[Be], mask[Be])
                  -> (loss_sum[], correct[], count[])

``mode``: "scratch" and "finetune" train every parameter; "featext"
multiplies the gradient by the head mask inside the graph, so only the
classifier head moves.  The rust side is mode-agnostic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import kernels as K
from .registry import Model

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def loss_and_hits(
    model: Model,
    flat: jnp.ndarray,
    x: jnp.ndarray,
    y: jnp.ndarray,
    freeze_backbone: bool = False,
):
    """Mean CE loss + number of top-1 hits, via the fused Pallas kernel."""
    logits = model.forward(flat, x, freeze_backbone=freeze_backbone)
    loss, hit = K.softmax_xent(logits, y)
    return jnp.mean(loss), jnp.sum(hit)


def make_grad_fn(model: Model, mode: str):
    """Value-and-grad of the mean loss.

    ``featext`` freezes the backbone with a stop_gradient (so the frozen
    backward pass is never built — the paper's Table-3 speedup) and
    belt-and-braces multiplies by the head mask so backbone coordinates
    are exactly unchanged.
    """
    featext = mode == "featext"
    head_start = model.num_params - model.head_size

    def objective(flat, x, y):
        loss, hits = loss_and_hits(model, flat, x, y, freeze_backbone=featext)
        return loss, hits

    vg = jax.value_and_grad(objective, has_aux=True)

    def grad_fn(flat, x, y):
        (loss, hits), g = vg(flat, x, y)
        if featext:
            # Head mask built from an in-graph iota comparison, NOT a
            # concrete array: XLA's text printer elides large literals
            # ("{...}") and the HLO-text parser reads them back as zeros.
            # lax.iota inside the trace stays a (tiny) iota op in text.
            mask = (
                jax.lax.iota(jnp.int32, model.num_params) >= head_start
            ).astype(g.dtype)
            g = g * mask
        return loss, hits, g

    return grad_fn


def make_train_step_sgd(model: Model, mode: str):
    grad_fn = make_grad_fn(model, mode)

    def train_step(params, x, y, lr):
        loss, hits, g = grad_fn(params, x, y)
        new_params = params - lr * g
        return new_params, loss, hits

    return train_step


def make_train_step_adam(model: Model, mode: str):
    grad_fn = make_grad_fn(model, mode)

    def train_step(params, m, v, t, x, y, lr):
        loss, hits, g = grad_fn(params, x, y)
        t = t + 1.0
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * (g * g)
        mhat = m / (1.0 - ADAM_B1**t)
        vhat = v / (1.0 - ADAM_B2**t)
        new_params = params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return new_params, m, v, t, loss, hits

    return train_step


def make_eval_step(model: Model):
    """Masked eval: ``mask`` zeroes padded tail examples in the last batch
    so rust can evaluate any test-set size with one fixed-shape artifact."""

    def eval_step(params, x, y, mask):
        logits = model.forward(params, x)
        loss, hit = K.softmax_xent(logits, y)
        return (
            jnp.sum(loss * mask),
            jnp.sum(hit * mask),
            jnp.sum(mask),
        )

    return eval_step


def make_aggregate(k_pad: int):
    """FedAvg aggregation entry point at fixed K_pad (Eq. 2)."""

    def aggregate(deltas, weights, global_params):
        assert deltas.shape[0] == k_pad
        return K.fedavg_aggregate(deltas, weights, global_params)

    return aggregate
