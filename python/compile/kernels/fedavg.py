"""FedAvg weighted-aggregation Pallas kernel — the FL server hot-spot.

Computes ``global' = global + weights @ deltas`` over a stacked delta
matrix ``f32[K, P]`` (K sampled agents, P flat model parameters).  This is
Equation (2) of the paper.

TPU schedule: K is small (<= a few dozen) while P is large (10^4..10^7),
so the grid runs over P-blocks; each step loads a ``[K, bp]`` strip of
deltas plus the matching ``[bp]`` slice of the global vector into VMEM,
reduces over K on the VPU, and writes the updated slice.  That turns the
paper's "embarrassingly parallel" aggregation into a single-pass streaming
kernel whose HBM traffic is exactly one read of the deltas + one
read/write of the global vector — the roofline minimum.

Padding invariance: rows with weight 0 contribute nothing, so the rust
coordinator compiles one artifact at K_pad >= max(sampled) and zero-pads —
property-tested in python/tests and rust proptests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import assert_vmem_ok, round_up

# P-block sized so K_pad=16 strips stay ~2 MiB in VMEM with double-buffer
# headroom: 16 * 32768 * 4 B = 2 MiB.  Wider strips mean fewer grid steps
# for multi-million-parameter models (one step per 32k params).
DEFAULT_BP = 32768


def _fedavg_kernel(d_ref, w_ref, g_ref, o_ref):
    # [K, bp] strip reduced against [1, K] weights on the VPU/MXU.
    d = d_ref[...]
    w = w_ref[...]  # [1, K]
    upd = jnp.dot(w, d, preferred_element_type=jnp.float32)  # [1, bp]
    o_ref[...] = g_ref[...] + upd


def fedavg_aggregate(
    deltas: jnp.ndarray,
    weights: jnp.ndarray,
    global_params: jnp.ndarray,
    bp: int = DEFAULT_BP,
) -> jnp.ndarray:
    """Apply the FedAvg update ``global + sum_i w_i * delta_i``.

    Args:
      deltas: ``f32[K, P]`` stacked agent deltas (Eq. 1 of the paper).
      weights: ``f32[K]`` simplex weights (Gamma in Eq. 2); zero rows are
        exact no-ops, enabling K padding.
      global_params: ``f32[P]`` current global flat parameter vector.
      bp: P-block size (VMEM strip width).

    Returns:
      ``f32[P]`` updated global parameters.
    """
    k, p = deltas.shape
    assert weights.shape == (k,), (weights.shape, k)
    assert global_params.shape == (p,), (global_params.shape, p)

    pp = round_up(p, bp)
    assert_vmem_ok((k, bp), (1, k), (1, bp), (1, bp))
    dp = jnp.pad(deltas, ((0, 0), (0, pp - p)))
    gp = jnp.pad(global_params, (0, pp - p)).reshape(1, pp)
    w2 = weights.reshape(1, k)

    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, bp), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), deltas.dtype),
        interpret=True,
    )(dp, w2, gp)
    return out[0, :p]
