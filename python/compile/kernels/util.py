"""Shared helpers for the Pallas kernel layer.

All kernels in this package are written TPU-style — blocked for VMEM with
MXU-aligned tiles — but are lowered with ``interpret=True`` so the emitted
HLO runs on any PJRT backend (the rust coordinator uses the CPU client).
Real-TPU lowering would emit a Mosaic custom-call the CPU plugin cannot
execute; see DESIGN.md §Hardware-Adaptation.

Because Pallas blocks must tile the array exactly for the schedules we use,
every public kernel wrapper pads its operands up to block multiples and
slices the result back.  Padding is with zeros, which is exact for the
matmul/reduction semantics used here.
"""

from __future__ import annotations

import jax.numpy as jnp

# MXU-shaped default tiles.  The MXU is a 128x128 systolic array; the VPU
# lane width is 128 and sublane is 8, so (128, 128) blocks with a 128-deep
# reduction strip keep both units fed while staying far under the ~16 MiB
# VMEM budget (3 f32 blocks of 128x128 = 192 KiB).
MXU_TILE = 128

# Hard VMEM budget we validate block choices against (bytes).  TPU v4 has
# 16 MiB of VMEM per core; we keep a 2x safety margin for double-buffering.
VMEM_BUDGET = 8 * 1024 * 1024


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return ((x + m - 1) // m) * m


def pick_block(dim: int, preferred: int = MXU_TILE) -> int:
    """Choose a block size for a dimension of size ``dim``.

    Small dimensions use the padded dimension itself as a single block
    (padding a 10-wide logit matrix to 128 lanes is cheaper than an extra
    grid axis); large dimensions use the MXU-aligned ``preferred`` tile.
    """
    if dim <= preferred:
        # Keep lane alignment: pad tiny dims up to a multiple of 8
        # (f32 sublane) so interpret-mode and Mosaic agree on layout.
        return max(8, round_up(dim, 8))
    return preferred


def pick_matmul_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Blocks (bm, bk, bn) for an ``[m,k] @ [k,n]`` matmul.

    Policy (§Perf, EXPERIMENTS.md): lane dims (n, k) get MXU-aligned tiles;
    the row dim bm then grows as large as the VMEM budget allows. Fewer,
    fatter grid steps amortise the per-step HBM↔VMEM transfer setup (and,
    on the interpret path the CPU runtime executes, the per-step loop
    overhead — measured 12x on the cnn-l conv matmuls).
    """
    bn = pick_block(n)
    # Take the whole reduction dim when it fits a reasonable strip: one
    # K-step means the accumulator never round-trips to HBM.
    bk = round_up(k, 8) if k <= 2048 else MXU_TILE * 8
    bm = 8192
    m_pad = max(8, round_up(m, 8))
    while bm > 8 and (
        vmem_bytes((bm, bk), (bk, bn), (bm, bn)) > VMEM_BUDGET or bm >= 2 * m_pad
    ):
        bm //= 2
    bm = max(8, min(bm, m_pad))
    return bm, bk, bn


def pad2(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    """Zero-pad a rank-2 array so each dim is a multiple of (m0, m1)."""
    p0 = round_up(x.shape[0], m0) - x.shape[0]
    p1 = round_up(x.shape[1], m1) - x.shape[1]
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def vmem_bytes(*block_shapes: tuple[int, ...], dtype_bytes: int = 4) -> int:
    """Total VMEM footprint of a set of simultaneously-resident blocks."""
    total = 0
    for shape in block_shapes:
        n = dtype_bytes
        for d in shape:
            n *= d
        total += n
    return total


def assert_vmem_ok(*block_shapes: tuple[int, ...]) -> None:
    """Static sanity check that a kernel's blocks fit the VMEM budget."""
    used = vmem_bytes(*block_shapes)
    if used > VMEM_BUDGET:
        raise ValueError(
            f"kernel blocks need {used} B of VMEM, budget is {VMEM_BUDGET} B"
        )
