"""Conv2D via im2col + the Pallas MXU matmul.

This is the documented TPU adaptation of a CUDA-style direct convolution
(DESIGN.md §Hardware-Adaptation): instead of cuDNN implicit GEMM over
threadblocks, we lay the receptive fields out as rows of a patch matrix
(im2col, pure data movement that XLA fuses into the surrounding graph) and
feed the MXU one large tiled matmul of shape
``[B*OH*OW, KH*KW*C] @ [KH*KW*C, O]``.

The im2col unfolding is plain (differentiable) jnp slicing, so autodiff
flows through it and reaches the custom VJP of the Pallas matmul — no
bespoke conv backward kernel is needed, and the backward pass is itself
two MXU matmuls.
"""

from __future__ import annotations

import jax.numpy as jnp

from .matmul import matmul


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1, pad: int = 0):
    """Unfold ``x: f32[B,H,W,C]`` into patches ``f32[B,OH,OW,KH*KW*C]``.

    Feature order of the last axis is (kh, kw, c) flattened, matching
    ``w.reshape(KH*KW*C, O)`` for weights stored as ``f32[KH,KW,C,O]``.
    """
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    b, h, w_, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w_ - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(
                x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    patches = jnp.stack(cols, axis=3)  # [B, OH, OW, KH*KW, C]
    return patches.reshape(b, oh, ow, kh * kw * c)


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
    act: str = "relu",
) -> jnp.ndarray:
    """2-D convolution with fused bias + activation.

    Args:
      x: ``f32[B, H, W, C]`` NHWC input.
      w: ``f32[KH, KW, C, O]`` HWIO filters.
      b: ``f32[O]`` bias.
      stride: spatial stride (same for H and W).
      pad: symmetric zero padding.
      act: ``"linear" | "relu" | "tanh"``.

    Returns:
      ``f32[B, OH, OW, O]``.
    """
    kh, kw, c, o = w.shape
    patches = im2col(x, kh, kw, stride, pad)
    bsz, oh, ow, pk = patches.shape
    flat = patches.reshape(bsz * oh * ow, pk)
    y = matmul(flat, w.reshape(kh * kw * c, o)) + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    return y.reshape(bsz, oh, ow, o)


def avg_pool(x: jnp.ndarray, k: int = 2, stride: int | None = None):
    """Average pooling over NHWC, window ``k`` x ``k``."""
    stride = stride or k
    b, h, w_, c = x.shape
    oh = (h - k) // stride + 1
    ow = (w_ - k) // stride + 1
    acc = jnp.zeros((b, oh, ow, c), x.dtype)
    for i in range(k):
        for j in range(k):
            acc = acc + x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
    return acc / float(k * k)


def max_pool(x: jnp.ndarray, k: int = 2, stride: int | None = None):
    """Max pooling over NHWC, window ``k`` x ``k``."""
    stride = stride or k
    b, h, w_, c = x.shape
    oh = (h - k) // stride + 1
    ow = (w_ - k) // stride + 1
    out = jnp.full((b, oh, ow, c), -jnp.inf, x.dtype)
    for i in range(k):
        for j in range(k):
            out = jnp.maximum(
                out, x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    return out
