"""L1: Pallas kernels for FerrisFL's compute hot-spots.

Public surface:
  - :func:`matmul` — blocked MXU matmul (custom VJP).
  - :func:`dense` — fused ``act(x @ w + b)`` (custom VJP).
  - :func:`conv2d`, :func:`im2col`, :func:`avg_pool`, :func:`max_pool` —
    conv stack via im2col + MXU matmul.
  - :func:`softmax_xent` — fused CE loss + top-1 hit (custom VJP).
  - :func:`fedavg_aggregate` — the FL server aggregation kernel (Eq. 2).

Everything lowers under ``interpret=True`` so the emitted HLO runs on the
rust coordinator's PJRT CPU client; see DESIGN.md §Hardware-Adaptation.
"""

from .conv2d import avg_pool, conv2d, im2col, max_pool
from .dense import dense
from .fedavg import fedavg_aggregate
from .matmul import matmul
from .softmax_xent import softmax_xent

__all__ = [
    "avg_pool",
    "conv2d",
    "dense",
    "fedavg_aggregate",
    "im2col",
    "matmul",
    "max_pool",
    "softmax_xent",
]
