"""Pure-jnp correctness oracle for every Pallas kernel.

These are deliberately the most naive possible expressions of each
operation — no blocking, no fusion, no padding tricks — so that a mismatch
always indicts the kernel, never the oracle.  pytest (python/tests) sweeps
shapes/dtypes with hypothesis and asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain ``x @ w``."""
    return jnp.matmul(x, w)


def dense_ref(x, w, b, act: str = "relu"):
    """``act(x @ w + b)`` with the same activation vocabulary as dense()."""
    z = jnp.matmul(x, w) + b
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "tanh":
        return jnp.tanh(z)
    return z


def conv2d_ref(x, w, b, stride: int = 1, pad: int = 0, act: str = "relu"):
    """NHWC/HWIO convolution via lax.conv_general_dilated."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + b
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "tanh":
        return jnp.tanh(y)
    return y


def softmax_xent_ref(z, y):
    """Stable per-example CE loss + top-1 hit indicator."""
    z = z.astype(jnp.float32)
    zmax = jnp.max(z, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - zmax), axis=1)) + zmax[:, 0]
    zy = jnp.take_along_axis(z, y[:, None], axis=1)[:, 0]
    loss = lse - zy
    hit = (jnp.argmax(z, axis=1).astype(y.dtype) == y).astype(jnp.float32)
    return loss, hit


def fedavg_ref(deltas, weights, global_params):
    """``global + weights @ deltas`` (Eq. 2 of the paper)."""
    return global_params + jnp.einsum("k,kp->p", weights, deltas)


def avg_pool_ref(x, k: int = 2, stride: int | None = None):
    stride = stride or k
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    ) / float(k * k)


def max_pool_ref(x, k: int = 2, stride: int | None = None):
    stride = stride or k
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )
