"""Blocked MXU matmul Pallas kernel with a custom VJP.

This is the workhorse of the kernel layer: the dense layers, the conv
layers (via im2col), and both backward passes all lower to this kernel, so
the entire model fwd/bwd hot path is expressed as MXU-tiled matmuls.

Schedule: a 3-D grid ``(M/bm, N/bn, K/bk)``; the K axis is the reduction
strip.  Each (i, j) output block stays resident in VMEM across the K steps
("arbitrary" semantics on the K axis), accumulating partial products — the
same HBM<->VMEM schedule a CUDA kernel would express with a threadblock
per output tile and a shared-memory K loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import assert_vmem_ok, pad2, pick_matmul_blocks


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # preferred_element_type pins the MXU accumulator to f32 even if the
    # inputs are bf16 — matching how TPU matmuls should be written.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jnp.ndarray:
    """``x @ w`` via the blocked Pallas kernel.

    Args:
      x: ``f32[M, K]``.
      w: ``f32[K, N]``.
      bm/bn/bk: optional block overrides (defaults are MXU-aligned picks).

    Returns:
      ``f32[M, N]``.
    """
    return _matmul_impl(x, w, bm, bn, bk)


def _matmul_impl(x, w, bm=None, bn=None, bk=None):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul shape mismatch: {x.shape} @ {w.shape}"
    abm, abk, abn = pick_matmul_blocks(m, k, n)
    bm, bk, bn = bm or abm, bk or abk, bn or abn
    assert_vmem_ok((bm, bk), (bk, bn), (bm, bn))

    xp = pad2(x, bm, bk)
    wp = pad2(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def _matmul_fwd(x, w, bm, bn, bk):
    return _matmul_impl(x, w, bm, bn, bk), (x, w)


def _matmul_bwd(bm, bn, bk, res, g):
    x, w = res
    # dX = g @ W^T and dW = X^T @ g — both through the same Pallas kernel,
    # so the backward pass is MXU-tiled too.
    dx = _matmul_impl(g, w.T)
    dw = _matmul_impl(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
