"""Fused softmax cross-entropy Pallas kernel with custom VJP.

Forward: one kernel pass over row blocks computes, per example, the
numerically-stable log-sum-exp loss AND whether the argmax equals the
label — so the training step gets loss and accuracy from a single fused
read of the logits (the paper's Lightning metrics do this in two).

Backward: a second kernel emits ``(softmax(z) - onehot(y)) * g`` per row,
recomputing the softmax from the saved logits rather than materialising
probabilities in HBM during the forward pass (rematerialisation is the
right trade at this size: C <= 128 lanes).

Labels arrive as ``i32[B]``; one-hot comparisons use a broadcasted iota so
no gather is needed inside the kernel (TPU-friendly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import assert_vmem_ok, pick_block, round_up


def _xent_fwd_kernel(z_ref, y_ref, loss_ref, hit_ref, *, c: int):
    z = z_ref[...].astype(jnp.float32)  # [bb, Cp]
    y = y_ref[...]  # [bb]
    bb, cp = z.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, cp), 1)
    valid = lane < c
    z = jnp.where(valid, z, -jnp.inf)

    zmax = jnp.max(z, axis=1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - zmax), axis=1)) + zmax[:, 0]
    onehot = (lane == y[:, None]).astype(jnp.float32)
    zy = jnp.sum(jnp.where(lane == y[:, None], z, 0.0), axis=1)
    loss_ref[...] = lse - zy
    pred = jnp.argmax(z, axis=1).astype(jnp.int32)
    hit_ref[...] = (pred == y).astype(jnp.float32)
    del onehot


def _xent_bwd_kernel(z_ref, y_ref, g_ref, dz_ref, *, c: int):
    z = z_ref[...].astype(jnp.float32)
    y = y_ref[...]
    g = g_ref[...]
    bb, cp = z.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, cp), 1)
    valid = lane < c
    z = jnp.where(valid, z, -jnp.inf)
    zmax = jnp.max(z, axis=1, keepdims=True)
    ez = jnp.exp(z - zmax)
    p = ez / jnp.sum(ez, axis=1, keepdims=True)
    onehot = (lane == y[:, None]).astype(jnp.float32)
    dz = (p - onehot) * g[:, None]
    dz_ref[...] = jnp.where(valid, dz, 0.0).astype(dz_ref.dtype)


def _run_fwd(z, y):
    b, c = z.shape
    bb = pick_block(b)
    cp = round_up(c, 128)
    assert_vmem_ok((bb, cp), (bb,), (bb,))
    bp = round_up(b, bb)
    zp = jnp.pad(z, ((0, bp - b), (0, cp - c)))
    # Padded rows get label -1: they match no lane, produce finite garbage
    # that is sliced away below.
    yp = jnp.pad(y, (0, bp - b), constant_values=-1)
    loss, hit = pl.pallas_call(
        functools.partial(_xent_fwd_kernel, c=c),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, cp), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((bp,), jnp.float32),
        ],
        interpret=True,
    )(zp, yp)
    return loss[:b], hit[:b]


@jax.custom_vjp
def softmax_xent(z: jnp.ndarray, y: jnp.ndarray):
    """Per-example cross-entropy loss and top-1 hit indicator.

    Args:
      z: ``f32[B, C]`` logits.
      y: ``i32[B]`` integer labels in ``[0, C)``.

    Returns:
      ``(loss f32[B], hit f32[B])`` — ``hit[i]`` is 1.0 when the argmax of
      row i equals ``y[i]``.  Gradients flow only through ``loss``.
    """
    return _run_fwd(z, y)


def _fwd(z, y):
    out = _run_fwd(z, y)
    return out, (z, y)


def _bwd(res, gs):
    z, y = res
    g_loss, _ = gs  # no gradient through the hit indicator
    b, c = z.shape
    bb = pick_block(b)
    cp = round_up(c, 128)
    bp = round_up(b, bb)
    zp = jnp.pad(z, ((0, bp - b), (0, cp - c)))
    yp = jnp.pad(y, (0, bp - b), constant_values=-1)
    gp = jnp.pad(g_loss, (0, bp - b))
    dz = pl.pallas_call(
        functools.partial(_xent_bwd_kernel, c=c),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, cp), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, cp), z.dtype),
        interpret=True,
    )(zp, yp, gp)
    return dz[:b, :c], None


softmax_xent.defvjp(_fwd, _bwd)
