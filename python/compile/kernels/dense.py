"""Fused dense layer: ``act(x @ w + b)`` in one Pallas kernel.

Fusing the bias add and activation into the matmul epilogue saves an HBM
round-trip for the (M, N) pre-activation — on TPU the epilogue runs on the
VPU over the block that is already resident in VMEM, exactly where a CUDA
kernel would fuse into the GEMM epilogue.

The custom VJP saves ``x`` and the pre-activation sign mask (for relu) so
the backward pass is two Pallas matmuls plus an elementwise mask.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _matmul_impl
from .util import assert_vmem_ok, pad2, pick_matmul_blocks, round_up

_ACTS = ("linear", "relu", "tanh")


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps: int, act: str):
    """Accumulate x@w over the K axis; on the last step apply bias + act."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...]
        if act == "relu":
            z = jnp.maximum(z, 0.0)
        elif act == "tanh":
            z = jnp.tanh(z)
        o_ref[...] = z


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, act: str = "relu"):
    """Fused ``act(x @ w + b)``.

    Args:
      x: ``f32[M, K]`` activations.
      w: ``f32[K, N]`` weights.
      b: ``f32[N]`` bias.
      act: one of ``"linear" | "relu" | "tanh"``.

    Returns:
      ``f32[M, N]``.
    """
    return _dense_impl(x, w, b, act)


def _dense_impl(x, w, b, act):
    assert act in _ACTS, f"unknown activation {act!r}"
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = pick_matmul_blocks(m, k, n)
    assert_vmem_ok((bm, bk), (bk, bn), (1, bn), (bm, bn))

    xp = pad2(x, bm, bk)
    wp = pad2(w, bk, bn)
    bp = jnp.pad(b, (0, round_up(n, bn) - n)).reshape(1, -1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    nsteps = kp // bk

    out = pl.pallas_call(
        functools.partial(_dense_kernel, nsteps=nsteps, act=act),
        grid=(mp // bm, np_ // bn, nsteps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def _dense_fwd(x, w, b, act):
    y = _dense_impl(x, w, b, act)
    return y, (x, w, y)


def _dense_bwd(act, res, g):
    x, w, y = res
    if act == "relu":
        # d/dz relu(z) = 1[z > 0]; y > 0 iff z > 0.
        g = g * (y > 0.0).astype(g.dtype)
    elif act == "tanh":
        g = g * (1.0 - y * y)
    dx = _matmul_impl(g, w.T)
    dw = _matmul_impl(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
