"""Upstream pre-training substrate (DESIGN.md Substitution #2).

The paper warm-starts finetune/feature-extract runs from ImageNet
weights.  Our stand-in: pre-train each model on an *upstream* task drawn
from the same class templates but with a different corruption regime
(heavier noise, larger jitter) — a genuinely related-but-shifted
distribution, which is exactly the structure transfer learning exploits.

Runs once inside ``make artifacts``; the flat weight vectors land in
``artifacts/pretrained_<model>_<dataset>.f32`` for the rust coordinator.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import datagen
from .models.registry import Model, build_model
from .models.train import make_train_step_adam, make_train_step_sgd

# Upstream regime: heavier corruption than the downstream task (rust uses
# the spec's own noise/jitter), so the tasks differ but share structure.
UPSTREAM_NOISE = 0.55
UPSTREAM_JITTER = 4
UPSTREAM_SEED = 0x5EED


def pretrain(
    variant: str,
    dataset: str,
    steps: int = 150,
    batch: int = 64,
    lr: float = 0.05,
    optimizer: str = "sgd",
    verbose: bool = True,
) -> np.ndarray:
    """Pre-train ``variant`` on the upstream task of ``dataset``.

    Returns the flat f32[P] weight vector.  ``optimizer`` is "sgd" or
    "adam" — tiny depthwise models (micronet) only train well under Adam.
    """
    spec = datagen.DATASET_REGISTRY[dataset]
    templates = datagen.make_templates(spec)
    model = build_model(variant, spec.input_shape, spec.num_classes)

    rng = np.random.default_rng(UPSTREAM_SEED)
    params = jnp.asarray(model.init(seed=UPSTREAM_SEED))
    if optimizer == "adam":
        step = jax.jit(make_train_step_adam(model, "scratch"))
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        t = jnp.float32(0.0)
    else:
        step = jax.jit(make_train_step_sgd(model, "scratch"))

    last_loss = float("nan")
    for i in range(steps):
        labels = rng.integers(0, spec.num_classes, batch)
        x = datagen.synthesize(
            templates, labels, rng, UPSTREAM_NOISE, UPSTREAM_JITTER
        )
        xb = jnp.asarray(x)
        yb = jnp.asarray(labels.astype(np.int32))
        if optimizer == "adam":
            params, m, v, t, loss, hits = step(
                params, m, v, t, xb, yb, jnp.float32(lr)
            )
        else:
            params, loss, hits = step(params, xb, yb, jnp.float32(lr))
        last_loss = float(loss)
        if verbose and (i + 1) % 50 == 0:
            acc = float(hits) / batch
            print(
                f"  [pretrain {variant}@{dataset}] step {i + 1}/{steps} "
                f"loss={last_loss:.4f} acc={acc:.3f}"
            )
    return np.asarray(params)
