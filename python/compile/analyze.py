"""HLO artifact analysis — the L2 profiling tool (EXPERIMENTS.md §Perf).

Static inspection of the AOT-lowered artifacts: op histograms, FLOP
estimates for the dot ops, constant sizes, and while-loop (pallas
interpret grid) counts. This is how we verify the lowered graphs have
no redundant recomputation and that kernel-block retunes actually
shrink the grid-loop count — interpret-mode wallclock is not a TPU
proxy, but graph *structure* is.

Usage: python -m compile.analyze ../artifacts [pattern]
"""

from __future__ import annotations

import re
import sys
from collections import Counter
from pathlib import Path


OP_RE = re.compile(r"^\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*[\w\[\]{},\s]*?\b([a-z][\w\-]*)\(")
SHAPE_RE = re.compile(r"f32\[([\d,]+)\]")


def op_histogram(text: str) -> Counter:
    """Count HLO opcodes per line (entry + nested computations)."""
    ops: Counter = Counter()
    for line in text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] += 1
    return ops


def dot_flops(text: str) -> int:
    """Rough FLOP count of all dot ops (2*M*K*N per dot, batch=lhs rows)."""
    total = 0
    for line in text.splitlines():
        if " dot(" not in line and not re.search(r"=\s*f32.*\bdot\b", line):
            continue
        shapes = SHAPE_RE.findall(line)
        if len(shapes) >= 1:
            out = [int(x) for x in shapes[0].split(",")]
            # contracting dim unknown from the out shape alone; estimate
            # with the largest operand dim found on the line.
            dims = [int(x) for s in shapes for x in s.split(",")]
            k = max(dims) if dims else 1
            import math

            total += 2 * k * int(math.prod(out))
    return total


def analyze_file(path: Path) -> dict:
    text = path.read_text()
    ops = op_histogram(text)
    # Tuple-typed results (e.g. while loops) defeat the line regex; count
    # those opcodes by call-site substring instead.
    return {
        "file": path.name,
        "bytes": len(text),
        "ops": sum(ops.values()),
        "while": text.count(" while("),
        "dot": text.count(" dot("),
        "fusion": text.count(" fusion("),
        "custom-call": text.count(" custom-call("),
        "top": ops.most_common(6),
    }


def main() -> None:
    art_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "../artifacts")
    pattern = sys.argv[2] if len(sys.argv) > 2 else ""
    rows = []
    for path in sorted(art_dir.glob("*.hlo.txt")):
        if pattern and pattern not in path.name:
            continue
        rows.append(analyze_file(path))
    w = max((len(r["file"]) for r in rows), default=20)
    print(f"{'artifact':<{w}} {'KB':>7} {'ops':>6} {'while':>6} {'dot':>5} {'cc':>4}")
    for r in rows:
        print(
            f"{r['file']:<{w}} {r['bytes'] / 1024:>7.1f} {r['ops']:>6} "
            f"{r['while']:>6} {r['dot']:>5} {r['custom-call']:>4}"
        )
    if rows:
        print("\nno custom-calls should appear (CPU PJRT cannot run Mosaic);")
        print("`while` counts are the pallas interpret grid loops — fewer is")
        print("better, and they shrink when kernel blocks grow (§Perf L1).")


if __name__ == "__main__":
    main()
