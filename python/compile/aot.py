"""AOT pipeline: lower the L2/L1 stack to HLO text + build all artifacts.

Run once by ``make artifacts``; python never appears on the request path.
Outputs (all under ``artifacts/``):

  manifest.json                    — the L2<->L3 contract (see DESIGN.md)
  <entry>_<model>_<dataset>.hlo.txt — AOT-lowered executables
  agg_p<P>_k<K>.hlo.txt            — FedAvg aggregation per parameter size
  templates_<dataset>.bin          — raw f32 class templates (datagen)
  init_<model>_<dataset>.f32       — He-initialised flat weights
  pretrained_<model>_<dataset>.f32 — upstream-pretrained flat weights

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, kernels, pretrain
from .kernels import ref as kref
from .models.registry import Model, build_model, MODEL_REGISTRY
from .models.train import (
    make_aggregate,
    make_eval_step,
    make_train_step_adam,
    make_train_step_sgd,
)

TRAIN_BATCH = 32
EVAL_BATCH = 128
K_PAD = 16  # max sampled agents per round for the single agg artifact

#: The artifact matrix: every (model, dataset) pair an experiment needs.
#: ``opts``: list of (optimizer, mode) train entries to lower.
#: ``pretrain``: build upstream-pretrained weights (transfer experiments).
#: ``ref_variant``: additionally lower with pure-jnp reference kernels
#:                  (the kernel-ablation bench).
ARTIFACTS = [
    dict(
        model="mlp-s",
        dataset="synth-mnist",
        opts=[("sgd", "full"), ("sgd", "featext")],
        pretrain=True,
        ref_variant=True,
    ),
    dict(
        model="lenet5",
        dataset="synth-mnist",
        opts=[("sgd", "full")],
        pretrain=False,
        ref_variant=False,
    ),
    dict(
        model="cnn-m",
        dataset="synth-cifar10",
        opts=[("sgd", "full"), ("sgd", "featext")],
        pretrain=True,
        pretrain_steps=100,
        pretrain_batch=32,
        ref_variant=False,
    ),
    dict(
        model="micronet-05",
        dataset="synth-mnist",
        opts=[("adam", "featext"), ("adam", "full"), ("sgd", "full")],
        pretrain=True,
        pretrain_steps=400,
        pretrain_opt="adam",
        pretrain_lr=0.01,
        ref_variant=False,
    ),
]

#: Canonical dataset per family, used for the Table-2 zoo inventory.
CANONICAL_DATASET = {
    "mlp": "synth-mnist",
    "lenet": "synth-mnist",
    "cnn": "synth-cifar10",
    "micronet": "synth-mnist",
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    # The HLO text printer elides large literals as "{...}", which the
    # downstream text parser silently reads back as zeros.  Any such
    # constant would corrupt the artifact — the graphs are written to
    # avoid big literals (e.g. iota-based masks), and this guard keeps it
    # that way.
    if "{...}" in text:
        raise RuntimeError(
            "lowered HLO contains an elided large constant ({...}); "
            "rewrite the graph to avoid large literals (use iota/broadcast)"
        )
    return text


@contextlib.contextmanager
def ref_kernels():
    """Swap the Pallas kernels for the pure-jnp oracle (ablation builds).

    The layer code resolves ``kernels.<fn>`` at call time, so patching the
    module attributes reroutes the whole zoo through the reference path.
    """
    saved = {
        "dense": kernels.dense,
        "conv2d": kernels.conv2d,
        "matmul": kernels.matmul,
        "softmax_xent": kernels.softmax_xent,
        "avg_pool": kernels.avg_pool,
        "max_pool": kernels.max_pool,
        "fedavg_aggregate": kernels.fedavg_aggregate,
    }
    kernels.dense = kref.dense_ref
    kernels.conv2d = kref.conv2d_ref
    kernels.matmul = kref.matmul_ref
    kernels.softmax_xent = kref.softmax_xent_ref
    kernels.avg_pool = kref.avg_pool_ref
    kernels.max_pool = kref.max_pool_ref
    kernels.fedavg_aggregate = kref.fedavg_ref
    try:
        yield
    finally:
        for k, v in saved.items():
            setattr(kernels, k, v)


def _shape(dt, *dims):
    return jax.ShapeDtypeStruct(tuple(dims), dt)


def lower_entries(model: Model, spec: datagen.DatasetSpec, opts, tag=""):
    """Lower train/eval entry points for one model@dataset.

    Returns ``{entry_name: hlo_text}``.
    """
    p = model.num_params
    h, w, c = spec.input_shape
    f32, i32 = jnp.float32, jnp.int32
    out = {}

    xb = _shape(f32, TRAIN_BATCH, h, w, c)
    yb = _shape(i32, TRAIN_BATCH)
    scalar = _shape(f32)
    pvec = _shape(f32, p)

    for optname, mode in opts:
        mode_key = "scratch" if mode == "full" else "featext"
        if optname == "sgd":
            fn = make_train_step_sgd(model, mode_key)
            lowered = jax.jit(fn).lower(pvec, xb, yb, scalar)
        elif optname == "adam":
            fn = make_train_step_adam(model, mode_key)
            lowered = jax.jit(fn).lower(
                pvec, pvec, pvec, scalar, xb, yb, scalar
            )
        else:
            raise ValueError(optname)
        out[f"train_{optname}_{mode}{tag}"] = to_hlo_text(lowered)

    ev = make_eval_step(model)
    lowered = jax.jit(ev).lower(
        pvec,
        _shape(f32, EVAL_BATCH, h, w, c),
        _shape(i32, EVAL_BATCH),
        _shape(f32, EVAL_BATCH),
    )
    out[f"eval{tag}"] = to_hlo_text(lowered)
    return out


def lower_aggregate(p: int, k_pad: int = K_PAD) -> str:
    fn = make_aggregate(k_pad)
    lowered = jax.jit(fn).lower(
        _shape(jnp.float32, k_pad, p),
        _shape(jnp.float32, k_pad),
        _shape(jnp.float32, p),
    )
    return to_hlo_text(lowered)


def build(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    manifest: dict = {
        "version": 1,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "k_pad": K_PAD,
        "datasets": {},
        "zoo": {},
        "artifacts": [],
    }

    # ---- datasets: registry + templates --------------------------------
    for name, spec in datagen.DATASET_REGISTRY.items():
        templates = datagen.make_templates(spec)
        tpath = os.path.join(out_dir, spec.template_file)
        templates.astype("<f4").tofile(tpath)
        manifest["datasets"][name] = {
            "group": spec.group,
            "height": spec.height,
            "width": spec.width,
            "channels": spec.channels,
            "num_classes": spec.num_classes,
            "train_n": spec.train_n,
            "test_n": spec.test_n,
            "real_train_n": spec.real_train_n,
            "real_test_n": spec.real_test_n,
            "noise": spec.noise,
            "jitter": spec.jitter,
            "template_file": spec.template_file,
        }
        print(f"[datagen] {name}: templates {templates.shape} -> {tpath}")

    # ---- zoo inventory (Table 2) ----------------------------------------
    for variant, mspec in MODEL_REGISTRY.items():
        ds = datagen.DATASET_REGISTRY[CANONICAL_DATASET[mspec.family]]
        m = build_model(variant, ds.input_shape, ds.num_classes)
        manifest["zoo"][variant] = {
            "family": mspec.family,
            "description": mspec.description,
            "canonical_dataset": ds.name,
            "num_params": m.num_params,
            "head_size": m.head_size,
            "feature_extract": True,
            "finetune": True,
        }

    # ---- per-experiment artifacts ---------------------------------------
    agg_done: set[int] = set()
    for art in ARTIFACTS:
        variant, dsname = art["model"], art["dataset"]
        spec = datagen.DATASET_REGISTRY[dsname]
        model = build_model(variant, spec.input_shape, spec.num_classes)
        ident = f"{variant}_{dsname}"
        print(f"[aot] lowering {ident} (P={model.num_params}) ...")

        entries = lower_entries(model, spec, art["opts"])
        if art.get("ref_variant"):
            with ref_kernels():
                entries.update(lower_entries(model, spec, art["opts"], "_ref"))

        entry_files = {}
        for ename, text in entries.items():
            fname = f"{ename}_{ident}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry_files[ename] = fname

        # aggregation artifact, one per distinct P
        agg_file = f"agg_p{model.num_params}_k{K_PAD}.hlo.txt"
        if model.num_params not in agg_done:
            agg_done.add(model.num_params)
            with open(os.path.join(out_dir, agg_file), "w") as f:
                f.write(lower_aggregate(model.num_params))
            print(f"[aot]   agg artifact {agg_file}")

        # initial + pretrained weights
        init_file = f"init_{ident}.f32"
        model.init(seed=0xF157).astype("<f4").tofile(
            os.path.join(out_dir, init_file)
        )
        pre_file = None
        if art["pretrain"]:
            steps = 20 if quick else art.get("pretrain_steps", 150)
            batch = art.get("pretrain_batch", 64)
            opt = art.get("pretrain_opt", "sgd")
            lr = art.get("pretrain_lr", 0.05)
            print(f"[pretrain] {ident} ({steps} steps, batch {batch}, {opt}) ...")
            wts = pretrain.pretrain(
                variant, dsname, steps=steps, batch=batch, lr=lr, optimizer=opt
            )
            pre_file = f"pretrained_{ident}.f32"
            wts.astype("<f4").tofile(os.path.join(out_dir, pre_file))

        manifest["artifacts"].append(
            {
                "id": ident,
                "model": variant,
                "dataset": dsname,
                "num_params": model.num_params,
                "head_size": model.head_size,
                "entries": entry_files,
                "agg_file": agg_file,
                "init_file": init_file,
                "pretrained_file": pre_file,
            }
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"[aot] wrote manifest with {len(manifest['artifacts'])} artifacts "
        f"in {time.time() - t0:.1f}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description="FerrisFL AOT artifact builder")
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--quick", action="store_true", help="short pretraining (CI/tests)"
    )
    args = ap.parse_args()
    build(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
