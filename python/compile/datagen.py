"""Synthetic dataset substrate (DESIGN.md Substitution #1).

The paper's datamodules wrap MNIST / EMNIST / CIFAR / FashionMNIST.  This
environment has no network or dataset files, so we build the closest
synthetic equivalent that exercises the same code paths: class-structured
image data where each class ``c`` has a fixed latent *template* image and
a sample is ``clip(template[c] + affine jitter + pixel noise)``.

The templates are generated HERE (once, at artifact-build time, from a
fixed seed) and stored as raw f32 in ``artifacts/templates_<name>.bin``;
the rust coordinator memory-maps them and synthesises train/test samples
deterministically from (split, index).  Python uses the same templates
for the *upstream* pre-training task (different jitter/noise level), which
is what makes the transfer-learning experiments meaningful.

The registry mirrors paper Table 1, scaled ~6x down by default so a full
FL experiment runs in CPU-minutes; the real sizes are kept in the spec for
reference and can be enabled via ``full_size=True`` runs.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """One registry entry (a row of paper Table 1)."""

    name: str
    group: str  # paper Table 1 "Group"
    height: int
    width: int
    channels: int
    num_classes: int
    train_n: int  # scaled-down default
    test_n: int
    real_train_n: int  # the paper dataset's true size, for the record
    real_test_n: int
    noise: float = 1.0  # downstream sample pixel-noise sigma
    jitter: int = 3  # max |shift| in pixels for downstream samples
    template_seed: int = 0x7F0A

    @property
    def input_shape(self) -> tuple[int, int, int]:
        return (self.height, self.width, self.channels)

    @property
    def template_file(self) -> str:
        return f"templates_{self.name}.bin"


def _spec(name, group, h, w, c, classes, rtrain, rtest, scale=6):
    return DatasetSpec(
        name=name,
        group=group,
        height=h,
        width=w,
        channels=c,
        num_classes=classes,
        train_n=max(classes * 40, rtrain // scale // 10 * 10),
        test_n=max(classes * 10, rtest // scale // 10 * 10),
        real_train_n=rtrain,
        real_test_n=rtest,
    )


#: Paper Table 1, synthetic equivalents.  All support IID and non-IID
#: sharding (sharding is dataset-agnostic, rust/src/federation).
DATASET_REGISTRY: dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        _spec("synth-mnist", "MNIST", 28, 28, 1, 10, 60000, 10000),
        _spec("synth-fmnist", "FashionMNIST", 28, 28, 1, 10, 60000, 10000),
        _spec("synth-cifar10", "CIFAR", 32, 32, 3, 10, 50000, 10000),
        _spec("synth-cifar100", "CIFAR", 32, 32, 3, 100, 50000, 10000),
        _spec("synth-emnist-digits", "EMNIST", 28, 28, 1, 10, 240000, 40000, 24),
        _spec("synth-emnist-letters", "EMNIST", 28, 28, 1, 26, 124800, 20800, 12),
        _spec("synth-emnist-balanced", "EMNIST", 28, 28, 1, 47, 112800, 18800, 12),
        _spec("synth-emnist-byclass", "EMNIST", 28, 28, 1, 62, 697932, 116323, 70),
        _spec("synth-emnist-bymerge", "EMNIST", 28, 28, 1, 47, 697932, 116323, 70),
    ]
}


def make_templates(spec: DatasetSpec) -> np.ndarray:
    """Deterministic per-class latent templates ``f32[C, H, W, ch]``.

    Each template is a smooth random field (sum of random 2-D sinusoids)
    plus a class-specific localized blob, normalised to [0, 1].  Smoothness
    makes small spatial jitter label-preserving; the blob gives each class
    a distinct low-frequency signature a small CNN/MLP can learn.
    """
    rng = np.random.default_rng(spec.template_seed ^ hash(spec.name) % (2**31))
    h, w, ch = spec.input_shape
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    yy /= h
    xx /= w
    out = np.zeros((spec.num_classes, h, w, ch), np.float32)
    for c in range(spec.num_classes):
        for k in range(ch):
            field = np.zeros((h, w), np.float32)
            # low-frequency sinusoid mixture
            for _ in range(4):
                fy, fx = rng.uniform(0.5, 3.0, 2)
                py, px = rng.uniform(0, 2 * np.pi, 2)
                amp = rng.uniform(0.5, 1.0)
                field += amp * np.sin(2 * np.pi * (fy * yy + fx * xx) + py + px)
            # class blob: Gaussian bump at a class-dependent location
            cy = 0.2 + 0.6 * ((c * 37 % spec.num_classes) / max(spec.num_classes - 1, 1))
            cx = 0.2 + 0.6 * ((c * 17 % spec.num_classes) / max(spec.num_classes - 1, 1))
            sig = 0.08 + 0.04 * (c % 3)
            blob = np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig**2)))
            field += 2.5 * blob
            lo, hi = field.min(), field.max()
            out[c, :, :, k] = (field - lo) / max(hi - lo, 1e-6)
    return out


def synthesize(
    templates: np.ndarray,
    labels: np.ndarray,
    rng: np.random.Generator,
    noise: float,
    jitter: int,
) -> np.ndarray:
    """Draw samples ``f32[N, H, W, C]`` for given labels.

    sample = roll(template[label], random shift) + N(0, noise), clipped to
    [-0.5, 1.5] then centred.  The SAME recipe is implemented in rust
    (rust/src/datasets) for the downstream task; python only uses it for
    upstream pre-training, with a different (noise, jitter) setting.
    """
    n = len(labels)
    _, h, w, ch = templates.shape
    out = np.empty((n, h, w, ch), np.float32)
    for i, lab in enumerate(labels):
        img = templates[lab]
        if jitter:
            dy = int(rng.integers(-jitter, jitter + 1))
            dx = int(rng.integers(-jitter, jitter + 1))
            img = np.roll(np.roll(img, dy, axis=0), dx, axis=1)
        img = img + rng.normal(0.0, noise, img.shape).astype(np.float32)
        out[i] = np.clip(img, -0.5, 1.5) - 0.5
    return out
