"""Dataset substrate tests: registry, templates, synthesis."""

import numpy as np
import pytest

from compile import datagen


def test_registry_matches_paper_table1():
    names = set(datagen.DATASET_REGISTRY)
    assert names == {
        "synth-mnist",
        "synth-fmnist",
        "synth-cifar10",
        "synth-cifar100",
        "synth-emnist-digits",
        "synth-emnist-letters",
        "synth-emnist-balanced",
        "synth-emnist-byclass",
        "synth-emnist-bymerge",
    }
    groups = {s.group for s in datagen.DATASET_REGISTRY.values()}
    assert groups == {"MNIST", "FashionMNIST", "CIFAR", "EMNIST"}


def test_real_sizes_recorded():
    s = datagen.DATASET_REGISTRY["synth-mnist"]
    assert (s.real_train_n, s.real_test_n) == (60000, 10000)
    c = datagen.DATASET_REGISTRY["synth-cifar100"]
    assert c.num_classes == 100
    e = datagen.DATASET_REGISTRY["synth-emnist-byclass"]
    assert e.num_classes == 62


@pytest.mark.parametrize("name", sorted(datagen.DATASET_REGISTRY))
def test_templates_shape_and_range(name):
    spec = datagen.DATASET_REGISTRY[name]
    t = datagen.make_templates(spec)
    assert t.shape == (spec.num_classes, *spec.input_shape)
    assert t.dtype == np.float32
    assert 0.0 <= t.min() and t.max() <= 1.0
    # Classes must be distinguishable: pairwise distances bounded away
    # from zero.
    flat = t.reshape(spec.num_classes, -1)
    for i in range(min(5, spec.num_classes)):
        for j in range(i + 1, min(5, spec.num_classes)):
            d = np.linalg.norm(flat[i] - flat[j])
            assert d > 1.0, f"classes {i},{j} too similar: {d}"


def test_templates_deterministic():
    spec = datagen.DATASET_REGISTRY["synth-mnist"]
    a = datagen.make_templates(spec)
    b = datagen.make_templates(spec)
    np.testing.assert_array_equal(a, b)


def test_templates_differ_across_datasets():
    a = datagen.make_templates(datagen.DATASET_REGISTRY["synth-mnist"])
    b = datagen.make_templates(datagen.DATASET_REGISTRY["synth-fmnist"])
    assert not np.array_equal(a, b)


def test_synthesize_shapes_and_clipping():
    spec = datagen.DATASET_REGISTRY["synth-cifar10"]
    t = datagen.make_templates(spec)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, spec.num_classes, 16)
    x = datagen.synthesize(t, labels, rng, noise=0.5, jitter=3)
    assert x.shape == (16, *spec.input_shape)
    assert x.min() >= -1.0 and x.max() <= 1.0


def test_synthesize_label_signal_survives_noise():
    """A nearest-template classifier on noisy samples must beat chance —
    otherwise no model could learn and every curve would be flat."""
    spec = datagen.DATASET_REGISTRY["synth-mnist"]
    t = datagen.make_templates(spec)
    rng = np.random.default_rng(1)
    labels = rng.integers(0, spec.num_classes, 200)
    x = datagen.synthesize(t, labels, rng, spec.noise, spec.jitter)
    flat_t = t.reshape(spec.num_classes, -1) - 0.5
    flat_x = x.reshape(200, -1)
    pred = np.argmax(flat_x @ flat_t.T, axis=1)
    acc = float(np.mean(pred == labels))
    assert acc > 0.4, f"template signal too weak: acc {acc}"
