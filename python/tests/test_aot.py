"""AOT pipeline tests: lowering, the elision guard, ref-kernel swap."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, datagen, kernels
from compile.kernels import ref as kref
from compile.models.registry import build_model
from compile.models.train import make_train_step_sgd


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple.
    assert "->" in text


def test_elision_guard_rejects_large_literals():
    big = jnp.asarray(np.random.default_rng(0).standard_normal(200_000), jnp.float32)

    def bad(x):
        return (x * big,)  # closes over a huge concrete array -> literal

    lowered = jax.jit(bad).lower(jax.ShapeDtypeStruct((200_000,), jnp.float32))
    with pytest.raises(RuntimeError, match="elided"):
        aot.to_hlo_text(lowered)


def test_featext_lowering_has_no_elided_mask():
    spec = datagen.DATASET_REGISTRY["synth-mnist"]
    m = build_model("mlp-s", spec.input_shape, spec.num_classes)
    fn = make_train_step_sgd(m, "featext")
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m.num_params,), jnp.float32),
        jax.ShapeDtypeStruct((4, *spec.input_shape), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)  # raises if any constant was elided
    assert "iota" in text, "head mask should lower to an iota op"


def test_ref_kernels_context_swaps_and_restores():
    orig = kernels.dense
    with aot.ref_kernels():
        assert kernels.dense is kref.dense_ref
        assert kernels.fedavg_aggregate is kref.fedavg_ref
    assert kernels.dense is orig


def test_ref_kernels_restore_on_exception():
    orig = kernels.matmul
    with pytest.raises(ValueError):
        with aot.ref_kernels():
            raise ValueError("boom")
    assert kernels.matmul is orig


def test_artifact_matrix_is_well_formed():
    for art in aot.ARTIFACTS:
        assert art["dataset"] in datagen.DATASET_REGISTRY
        assert art["opts"], f"{art['model']}: no train entries"
        for opt, mode in art["opts"]:
            assert opt in ("sgd", "adam")
            assert mode in ("full", "featext")
        if any(mode == "featext" for _, mode in art["opts"]):
            assert art["pretrain"], (
                f"{art['model']}: featext entries need pretrained weights"
            )


def test_lower_aggregate_small():
    text = aot.lower_aggregate(64, k_pad=4)
    assert text.startswith("HloModule")
    assert "f32[4,64]" in text
