"""L2 model-zoo tests: shapes, flat-param layout, training semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen
from compile.models.registry import MODEL_REGISTRY, build_model
from compile.models.train import (
    make_eval_step,
    make_train_step_adam,
    make_train_step_sgd,
)

MNIST = datagen.DATASET_REGISTRY["synth-mnist"]
CIFAR = datagen.DATASET_REGISTRY["synth-cifar10"]


def dataset_for(variant):
    return CIFAR if MODEL_REGISTRY[variant].family == "cnn" else MNIST


def tiny_batch(spec, b=8, seed=0):
    rng = np.random.default_rng(seed)
    tpl = datagen.make_templates(spec)
    labels = rng.integers(0, spec.num_classes, b)
    x = datagen.synthesize(tpl, labels, rng, spec.noise, spec.jitter)
    return jnp.asarray(x), jnp.asarray(labels.astype(np.int32))


@pytest.mark.parametrize("variant", sorted(MODEL_REGISTRY))
def test_forward_shape_and_param_layout(variant):
    spec = dataset_for(variant)
    m = build_model(variant, spec.input_shape, spec.num_classes)
    # Layout bookkeeping is self-consistent.
    assert m.num_params == sum(m.sizes)
    assert 0 < m.head_size < m.num_params
    flat = jnp.asarray(m.init(0))
    assert flat.shape == (m.num_params,)
    x = jnp.zeros((4, *spec.input_shape), jnp.float32)
    logits = m.forward(flat, x)
    assert logits.shape == (4, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("variant", sorted(MODEL_REGISTRY))
def test_init_is_deterministic_and_seed_sensitive(variant):
    spec = dataset_for(variant)
    m = build_model(variant, spec.input_shape, spec.num_classes)
    a, b = m.init(7), m.init(7)
    np.testing.assert_array_equal(a, b)
    c = m.init(8)
    assert not np.array_equal(a, c)


@pytest.mark.parametrize("variant", ["mlp-s", "lenet5", "micronet-05"])
def test_sgd_step_overfits_one_batch(variant):
    spec = dataset_for(variant)
    m = build_model(variant, spec.input_shape, spec.num_classes)
    x, y = tiny_batch(spec, b=8)
    opt = "adam" if m.spec.family == "micronet" else "sgd"
    if opt == "adam":
        step = jax.jit(make_train_step_adam(m, "scratch"))
        params = jnp.asarray(m.init(1))
        mm, vv, t = (
            jnp.zeros_like(params),
            jnp.zeros_like(params),
            jnp.float32(0),
        )
        losses = []
        for _ in range(30):
            params, mm, vv, t, loss, hits = step(
                params, mm, vv, t, x, y, jnp.float32(0.01)
            )
            losses.append(float(loss))
    else:
        step = jax.jit(make_train_step_sgd(m, "scratch"))
        params = jnp.asarray(m.init(1))
        losses = []
        for _ in range(30):
            params, loss, hits = step(params, x, y, jnp.float32(0.1))
            losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_featext_moves_only_head():
    m = build_model("mlp-s", MNIST.input_shape, MNIST.num_classes)
    x, y = tiny_batch(MNIST)
    step = jax.jit(make_train_step_sgd(m, "featext"))
    p0 = jnp.asarray(m.init(2))
    p1, loss, _ = step(p0, x, y, jnp.float32(0.1))
    bb = m.num_params - m.head_size
    assert bool(jnp.all(p0[:bb] == p1[:bb])), "backbone moved"
    assert not bool(jnp.all(p0[bb:] == p1[bb:])), "head frozen"


def test_featext_matches_masked_scratch_on_head():
    """featext's head update equals the scratch head gradient step
    (stop_gradient changes which params move, not the head math)."""
    m = build_model("mlp-s", MNIST.input_shape, MNIST.num_classes)
    x, y = tiny_batch(MNIST, seed=3)
    p0 = jnp.asarray(m.init(3))
    lr = jnp.float32(0.05)
    full = jax.jit(make_train_step_sgd(m, "scratch"))(p0, x, y, lr)[0]
    feat = jax.jit(make_train_step_sgd(m, "featext"))(p0, x, y, lr)[0]
    bb = m.num_params - m.head_size
    np.testing.assert_allclose(full[bb:], feat[bb:], rtol=1e-4, atol=1e-5)


def test_eval_step_mask_semantics():
    m = build_model("mlp-s", MNIST.input_shape, MNIST.num_classes)
    ev = jax.jit(make_eval_step(m))
    params = jnp.asarray(m.init(4))
    x, y = tiny_batch(MNIST, b=8, seed=5)
    full_mask = jnp.ones(8, jnp.float32)
    half_mask = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    l_full, c_full, n_full = ev(params, x, y, full_mask)
    l_half, c_half, n_half = ev(params, x, y, half_mask)
    assert float(n_full) == 8.0
    assert float(n_half) == 4.0
    assert float(l_half) <= float(l_full) + 1e-5
    # Masked loss equals the sum over the first four examples.
    l4, _, _ = ev(
        params,
        jnp.concatenate([x[:4], jnp.zeros_like(x[:4])]),
        jnp.concatenate([y[:4], jnp.zeros_like(y[:4])]),
        half_mask,
    )
    np.testing.assert_allclose(float(l4), float(l_half), rtol=1e-4)


def test_adam_step_shapes_and_state_progression():
    m = build_model("micronet-05", MNIST.input_shape, MNIST.num_classes)
    step = jax.jit(make_train_step_adam(m, "scratch"))
    params = jnp.asarray(m.init(6))
    mm = jnp.zeros_like(params)
    vv = jnp.zeros_like(params)
    t = jnp.float32(0.0)
    x, y = tiny_batch(MNIST)
    params2, m2, v2, t2, loss, hits = step(params, mm, vv, t, x, y, jnp.float32(0.01))
    assert params2.shape == params.shape
    assert float(t2) == 1.0
    assert bool(jnp.any(m2 != 0.0))
    assert bool(jnp.all(v2 >= 0.0))
    assert 0.0 <= float(hits) <= len(y)


def test_unflatten_round_trips():
    m = build_model("lenet5", MNIST.input_shape, MNIST.num_classes)
    flat = jnp.asarray(m.init(9))
    parts = m.unflatten(flat)
    assert len(parts) == len(m.param_shapes)
    rebuilt = jnp.concatenate([p.reshape(-1) for p in parts])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_registry_rejects_unknown_variant():
    with pytest.raises(KeyError):
        build_model("resnet-152", MNIST.input_shape, 10)
