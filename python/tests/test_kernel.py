"""Kernel-vs-ref correctness: the CORE signal for the L1 layer.

Hypothesis sweeps shapes (deliberately non-MXU-aligned to exercise the
padding paths) and checks every Pallas kernel against the pure-jnp oracle
in ``compile.kernels.ref``, forward and backward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref as R
from compile.kernels import util

jax.config.update("jax_enable_x64", False)

_SETTINGS = dict(max_examples=25, deadline=None)


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


# ---------------------------------------------------------------- matmul


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 140),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    got = K.matmul(x, w)
    want = R.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**_SETTINGS)
@given(
    m=st.integers(2, 40),
    k=st.integers(2, 40),
    n=st.integers(2, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_vjp_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    g1 = jax.grad(lambda a, b: jnp.sum(K.matmul(a, b) ** 2), (0, 1))(x, w)
    g2 = jax.grad(lambda a, b: jnp.sum(R.matmul_ref(a, b) ** 2), (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_matmul_large_mxu_aligned():
    rng = np.random.default_rng(7)
    x, w = _arr(rng, 256, 384), _arr(rng, 384, 256)
    np.testing.assert_allclose(
        K.matmul(x, w), R.matmul_ref(x, w), rtol=1e-4, atol=1e-3
    )


# ----------------------------------------------------------------- dense


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 150),
    n=st.integers(1, 70),
    act=st.sampled_from(["linear", "relu", "tanh"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, n)
    np.testing.assert_allclose(
        K.dense(x, w, b, act), R.dense_ref(x, w, b, act), rtol=1e-4, atol=1e-4
    )


@settings(**_SETTINGS)
@given(
    act=st.sampled_from(["linear", "relu", "tanh"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_vjp_matches_ref(act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, 9, 33), _arr(rng, 33, 12), _arr(rng, 12)

    def loss_k(x, w, b):
        return jnp.sum(jnp.sin(K.dense(x, w, b, act)))

    def loss_r(x, w, b):
        return jnp.sum(jnp.sin(R.dense_ref(x, w, b, act)))

    g1 = jax.grad(loss_k, (0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_r, (0, 1, 2))(x, w, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-3)


def test_dense_relu_is_nonnegative():
    rng = np.random.default_rng(3)
    y = K.dense(_arr(rng, 16, 16), _arr(rng, 16, 16), _arr(rng, 16), "relu")
    assert float(jnp.min(y)) >= 0.0


# ---------------------------------------------------------------- conv2d


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.integers(6, 16),
    c=st.integers(1, 4),
    o=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, hw, c, o, k, stride, pad, seed):
    if hw + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = _arr(rng, b, hw, hw, c)
    w = _arr(rng, k, k, c, o, scale=0.2)
    bias = _arr(rng, o, scale=0.2)
    got = K.conv2d(x, w, bias, stride, pad, "linear")
    want = R.conv2d_ref(x, w, bias, stride, pad, "linear")
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_conv2d_grad_flows():
    rng = np.random.default_rng(11)
    x = _arr(rng, 2, 8, 8, 3)
    w = _arr(rng, 3, 3, 3, 4, scale=0.2)
    bias = _arr(rng, 4, scale=0.2)

    def loss_k(w, bias):
        return jnp.sum(K.conv2d(x, w, bias, 1, 1, "relu"))

    def loss_r(w, bias):
        return jnp.sum(R.conv2d_ref(x, w, bias, 1, 1, "relu"))

    g1 = jax.grad(loss_k, (0, 1))(w, bias)
    g2 = jax.grad(loss_r, (0, 1))(w, bias)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    hw=st.sampled_from([4, 6, 8, 12]),
    k=st.sampled_from([2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pools_match_ref(hw, k, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, 2, hw, hw, 3)
    np.testing.assert_allclose(
        K.avg_pool(x, k), R.avg_pool_ref(x, k), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        K.max_pool(x, k), R.max_pool_ref(x, k), rtol=1e-5, atol=1e-5
    )


# ----------------------------------------------------------- softmax_xent


@settings(**_SETTINGS)
@given(
    b=st.integers(1, 64),
    c=st.integers(2, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed)
    z = _arr(rng, b, c, scale=3.0)
    y = jnp.asarray(rng.integers(0, c, b).astype(np.int32))
    l1, h1 = K.softmax_xent(z, y)
    l2, h2 = R.softmax_xent_ref(z, y)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h1, h2)


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_vjp_matches_ref(seed):
    rng = np.random.default_rng(seed)
    z = _arr(rng, 17, 10, scale=2.0)
    y = jnp.asarray(rng.integers(0, 10, 17).astype(np.int32))
    g1 = jax.grad(lambda z: jnp.mean(K.softmax_xent(z, y)[0]))(z)
    g2 = jax.grad(lambda z: jnp.mean(R.softmax_xent_ref(z, y)[0]))(z)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    z = jnp.array([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]], jnp.float32)
    y = jnp.array([0, 1], jnp.int32)
    loss, hit = K.softmax_xent(z, y)
    assert bool(jnp.all(jnp.isfinite(loss)))
    np.testing.assert_allclose(hit, [1.0, 1.0])


# ---------------------------------------------------------------- fedavg


@settings(**_SETTINGS)
@given(
    k=st.integers(1, 16),
    p=st.integers(1, 3000),
    seed=st.integers(0, 2**31 - 1),
)
def test_fedavg_matches_ref(k, p, seed):
    rng = np.random.default_rng(seed)
    d = _arr(rng, k, p)
    w = jnp.asarray(rng.random(k).astype(np.float32))
    w = w / jnp.sum(w)
    g = _arr(rng, p)
    np.testing.assert_allclose(
        K.fedavg_aggregate(d, w, g), R.fedavg_ref(d, w, g), rtol=1e-4, atol=1e-4
    )


@settings(**_SETTINGS)
@given(
    k=st.integers(1, 8),
    kpad=st.integers(0, 8),
    p=st.integers(10, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_fedavg_padding_invariance(k, kpad, p, seed):
    """Zero-weight padded rows must not change the result — the rust
    coordinator relies on this to compile a single K_pad artifact."""
    rng = np.random.default_rng(seed)
    d = _arr(rng, k, p)
    w = jnp.asarray(rng.random(k).astype(np.float32))
    w = w / jnp.sum(w)
    g = _arr(rng, p)
    base = K.fedavg_aggregate(d, w, g)
    dp = jnp.concatenate([d, _arr(rng, kpad, p)], axis=0) if kpad else d
    wp = jnp.concatenate([w, jnp.zeros(kpad, jnp.float32)]) if kpad else w
    padded = K.fedavg_aggregate(dp, wp, g)
    np.testing.assert_allclose(base, padded, rtol=1e-4, atol=1e-4)


def test_fedavg_zero_weights_is_identity():
    rng = np.random.default_rng(5)
    d = _arr(rng, 4, 257)
    g = _arr(rng, 257)
    out = K.fedavg_aggregate(d, jnp.zeros(4, jnp.float32), g)
    np.testing.assert_allclose(out, g, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------------ util


def test_vmem_budget_enforced():
    with pytest.raises(ValueError):
        util.assert_vmem_ok((4096, 4096))  # 64 MiB block


def test_pick_block_alignment():
    assert util.pick_block(1) == 8
    assert util.pick_block(10) == 16
    assert util.pick_block(128) == 128
    assert util.pick_block(1000) == 128
