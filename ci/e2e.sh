#!/usr/bin/env bash
# The end-to-end gate: one script, every suite, every CI matrix leg.
#
# Each suite is its own integration-test binary so a regression fails
# as a named ::group:: in the job log instead of vanishing into the
# test wall — and so the million-agent suite's VmHWM peak-RSS ceiling
# measures *only* its own process (VmHWM is a process-lifetime
# high-water mark; sharing a binary with any other test would inflate
# it past the gate).
#
#   engine_e2e          lockstep parity + async rounds (virtual time)
#   chaos_e2e           seeded fault injection + recovery replay
#   distributed_e2e     leader + 2 UDS workers, final-model bit-identity
#   byzantine_e2e       adversary replay + robust aggregation
#   registry_parity     virtual registry ≡ materialized, bit for bit
#   million_agent_e2e   10^6 agents, K=64, hard peak-RSS ceiling (VmHWM)
#
# Runs under whatever FERRISFL_SIMD the leg exports; suites must pass
# on every dispatch level and both architectures. Usage:
#   ci/e2e.sh [suite ...]     # default: all of the above

set -euo pipefail
cd "$(dirname "$0")/.."

SUITES=(
  engine_e2e
  chaos_e2e
  distributed_e2e
  byzantine_e2e
  registry_parity
  million_agent_e2e
)
if [[ $# -gt 0 ]]; then
  SUITES=("$@")
fi

# ::group:: folds each suite in the GitHub Actions log; plain headers
# elsewhere so the script stays useful locally.
group()     { if [[ -n "${GITHUB_ACTIONS:-}" ]]; then echo "::group::$1"; else echo "=== $1 ==="; fi; }
endgroup()  { if [[ -n "${GITHUB_ACTIONS:-}" ]]; then echo "::endgroup::"; fi; }

failed=()
for suite in "${SUITES[@]}"; do
  group "e2e: ${suite}"
  if cargo test --test "${suite}" -- --nocapture; then
    endgroup
  else
    endgroup
    echo "::error::e2e suite ${suite} failed"
    failed+=("${suite}")
  fi
done

if [[ ${#failed[@]} -gt 0 ]]; then
  echo "FAILED: ${failed[*]}"
  exit 1
fi
echo "all ${#SUITES[@]} e2e suites passed"
