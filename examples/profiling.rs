//! Profiling — paper §4.2.2 + §4.2.3 (Table 4 + Fig 10).
//!
//! Trains LeNet-5 on synth-mnist for one (subsampled) epoch under the
//! SimpleProfiler and the runtime memory tracker, then prints the
//! Table-4 action table and the Fig-10 per-batch byte series. Runs on
//! whichever backend the environment provides (native by default).
//!
//! Run: `cargo run --release --example profiling`

use std::sync::Arc;

use ferrisfl::datasets::{Dataset, Split};
use ferrisfl::entrypoint::worker::{evaluate, with_runtime, RuntimeKey};
use ferrisfl::profiler::{MemoryTracker, SimpleProfiler};
use ferrisfl::runtime::Manifest;
use ferrisfl::util::error::Result;

fn main() -> Result<()> {
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));
    let dataset = Dataset::load(&manifest, "synth-mnist", 42)?;
    let n = 1600.min(dataset.num_train());
    let key = RuntimeKey {
        backend: manifest.backend,
        model: "lenet5".into(),
        dataset: "synth-mnist".into(),
        optimizer: "sgd".into(),
        mode: "full".into(),
        entry_tag: String::new(),
    };

    let mut profiler = SimpleProfiler::new();
    let mut tracker = MemoryTracker::new();

    with_runtime(&manifest, &key, |rt| {
        let mut params = rt.init_params()?;
        let b = rt.train_batch_size();
        let mut scratch = rt.new_scratch();
        let mut start = 0;
        while start + b <= n {
            let idx: Vec<usize> = (start..start + b).collect();
            let batch =
                profiler.time("batch_synthesis", || dataset.batch(Split::Train, &idx));
            profiler.time("optimizer_step", || {
                rt.train_step_sgd(&mut params, &batch.x, &batch.y, 0.05, &mut scratch)
            })?;
            tracker.sample_batch();
            start += b;
        }
        profiler.time("validation", || -> Result<()> {
            evaluate(rt, &dataset)(&params)?;
            Ok(())
        })?;
        Ok(())
    })?;
    profiler.stop();

    println!("=== Table 4: SimpleProfiler (LeNet-5, 1 epoch) ===\n");
    println!("{}", profiler.report());

    println!("=== Fig 10: per-batch runtime bytes (first/last 5 batches) ===\n");
    println!("{:>6} {:>14} {:>12} {:>14}", "batch", "allocated", "freed", "in_use");
    let samples = tracker.samples();
    for m in samples.iter().take(5) {
        println!("{:>6} {:>14} {:>12} {:>14}", m.batch, m.allocated, m.freed, m.in_use);
    }
    println!("{:>6}", "...");
    for m in samples.iter().rev().take(5).rev() {
        println!("{:>6} {:>14} {:>12} {:>14}", m.batch, m.allocated, m.freed, m.in_use);
    }
    let total_alloc: u64 = samples.iter().map(|m| m.allocated).sum();
    println!(
        "\n{} batches, {:.1} MiB marshalled total, steady in-use {} B",
        samples.len(),
        total_alloc as f64 / (1024.0 * 1024.0),
        samples.last().map(|m| m.in_use).unwrap_or(0)
    );
    Ok(())
}
