//! Non-IID showcase — paper §4.1.1 (Fig 6).
//!
//! Splits the synth-cifar10 train split across 5 agents under IID and
//! non-IID (`niid_factor` 1 / 3 / 5) and renders each agent's label
//! histogram as an ASCII bar chart — the textual rendition of Fig 6,
//! plus the Dirichlet extension.
//!
//! Run: `cargo run --release --example non_iid_showcase`

use ferrisfl::datasets::{Dataset, Split};
use ferrisfl::federation::{shard, Scheme};
use ferrisfl::runtime::Manifest;
use ferrisfl::util::error::Result;
use ferrisfl::util::Rng;

fn bar(n: usize, max: usize, width: usize) -> String {
    let filled = if max == 0 { 0 } else { n * width / max };
    "█".repeat(filled)
}

fn main() -> Result<()> {
    let manifest = Manifest::load_or_native("artifacts");
    let ds = Dataset::load(&manifest, "synth-cifar10", 42)?;
    let labels = ds.labels(Split::Train);
    let classes = ds.info.num_classes;
    let mut rng = Rng::new(42);

    for scheme in [
        Scheme::Iid,
        Scheme::NonIid { niid_factor: 1 },
        Scheme::NonIid { niid_factor: 3 },
        Scheme::NonIid { niid_factor: 5 },
        Scheme::Dirichlet { alpha: 0.3 },
    ] {
        let p = shard(&labels, 5, scheme, &mut rng)?;
        let hist = p.label_histogram(&labels, classes);
        let uniq = p.unique_labels(&labels);
        let max = hist.iter().flatten().copied().max().unwrap_or(1);
        println!("\n=== split: {scheme} ===");
        for (agent, row) in hist.iter().enumerate() {
            println!(
                "agent {agent} ({} samples, {} unique labels)",
                p.shards[agent].len(),
                uniq[agent]
            );
            for (label, &count) in row.iter().enumerate() {
                if count > 0 {
                    println!("  label {label}: {:<30} {count}", bar(count, max, 30));
                }
            }
        }
    }
    println!(
        "\npaper shape check: unique labels per agent grow with niid_factor \
         (niid=1 = single-label extreme); IID is near-uniform."
    );
    Ok(())
}
