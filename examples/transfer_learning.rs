//! Transfer learning — paper §4.1.2 (Table 3 + Fig 7).
//!
//! Trains CNN-M on synth-cifar10 three ways — from scratch, finetuning
//! the synthetically-pretrained weights, and feature extraction (head
//! only) — and prints the Table-3 row for each plus the Fig-7 curves.
//!
//! Run: `cargo run --release --example transfer_learning [-- --epochs N]`

use std::sync::Arc;

use ferrisfl::entrypoint::trainer::{train, TrainConfig, TrainMode};
use ferrisfl::runtime::Manifest;
use ferrisfl::util::error::Result;

fn main() -> Result<()> {
    let epochs: usize = std::env::args()
        .skip_while(|a| a != "--epochs")
        .nth(1)
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(3);
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));

    println!("=== Transfer learning: CNN-M on synth-cifar10 ({epochs} epochs) ===\n");
    let mut rows = Vec::new();
    for mode in [TrainMode::Scratch, TrainMode::Finetune, TrainMode::FeatureExtract] {
        println!("--- {} ---", mode.label());
        let cfg = TrainConfig {
            model: "cnn-m".into(),
            dataset: "synth-cifar10".into(),
            backend: manifest.backend.name().into(),
            mode,
            epochs,
            lr: 0.03,
            optimizer: "sgd".into(),
            epoch_samples: 960, // subsampled epoch; 0 = full split
            eval_samples: 512,
            seed: 42,
            verbose: true,
        };
        let res = train(&manifest, &cfg)?;
        rows.push(res);
    }

    println!("\nTable 3 (paper: ResNet152/T4 -> ours: CNN-M/PJRT-CPU):");
    println!(
        "{:<16} {:>12} {:>14} {:>12} {:>10}",
        "Setting", "Train.Param", "NonTrain.Param", "Total", "s/epoch"
    );
    for r in &rows {
        println!(
            "{:<16} {:>12} {:>14} {:>12} {:>10.2}",
            r.mode.label(),
            r.trainable_params,
            r.non_trainable_params(),
            r.total_params,
            r.mean_epoch_secs
        );
    }

    // The paper's headline shape: warm starts begin at lower loss and
    // featext is several-x faster per epoch.
    let scratch = &rows[0];
    let featext = &rows[2];
    println!(
        "\nspeedup featext vs scratch: {:.1}x (paper: {:.1}x)",
        scratch.mean_epoch_secs / featext.mean_epoch_secs,
        1405.0 / 408.0
    );
    println!(
        "first-epoch val loss: scratch {:.3} vs finetune {:.3} vs featext {:.3}",
        scratch.epochs[0].val_loss, rows[1].epochs[0].val_loss, featext.epochs[0].val_loss
    );
    Ok(())
}
