//! Robust aggregation under a poisoning attack (paper §6.3 extension).
//!
//! The paper motivates the decoupled aggregator interface with defense
//! research (FedClean is by one of the authors). This example stages a
//! model-poisoning attack: some agents return sign-flipped, amplified
//! deltas, and we compare FedAvg against coordinate-median and trimmed-
//! mean server rules on the same rounds.
//!
//! Run: `cargo run --release --example robust_aggregation`

use std::sync::Arc;

use ferrisfl::aggregators;
use ferrisfl::config::FlParams;
use ferrisfl::datasets::{Dataset, Split};
use ferrisfl::entrypoint::worker::{self, LocalJob, RuntimeKey};
use ferrisfl::federation::{shard, Scheme};
use ferrisfl::runtime::Manifest;
use ferrisfl::util::error::Result;
use ferrisfl::util::Rng;

const POISONED: &[usize] = &[0, 1]; // agents 0 and 1 are malicious
const ROUNDS: usize = 4;

fn main() -> Result<()> {
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));
    let params = FlParams {
        model: "mlp-s".into(),
        dataset: "synth-mnist".into(),
        backend: manifest.backend,
        ..FlParams::default()
    };
    let dataset = Arc::new(Dataset::load(&manifest, &params.dataset, params.seed)?);
    let labels = dataset.labels(Split::Train);
    let mut rng = Rng::new(params.seed);
    let partition = shard(&labels, 8, Scheme::Iid, &mut rng)?;
    let key = RuntimeKey {
        backend: manifest.backend,
        model: params.model.clone(),
        dataset: params.dataset.clone(),
        optimizer: "sgd".into(),
        mode: "full".into(),
        entry_tag: String::new(),
    };
    let init = worker::with_runtime(&manifest, &key, |rt| rt.init_params())?;

    for agg_name in ["fedavg", "median", "trim:0.25"] {
        let mut aggregator = aggregators::from_name(agg_name)?;
        let mut global = init.clone();
        worker::with_runtime(&manifest, &key, |rt| {
            for round in 0..ROUNDS {
                let g = Arc::new(global.clone());
                let mut updates = Vec::new();
                for (aid, shard) in partition.shards.iter().enumerate() {
                    let job = LocalJob {
                        agent_id: aid,
                        round,
                        shard: shard.clone(),
                        global: Arc::clone(&g),
                        lr: 0.05,
                        local_epochs: 1,
                        max_steps_per_epoch: 8,
                        seed: params.seed,
                    };
                    let (mut update, _) = worker::run_local(rt, &dataset, &job)?;
                    if POISONED.contains(&aid) {
                        // Sign-flip + amplify: the classic model-poisoning
                        // attack the robust rules must survive.
                        for d in update.delta.iter_mut() {
                            *d *= -8.0;
                        }
                    }
                    updates.push(update);
                }
                global = aggregator.aggregate(&global, &updates, Some(rt))?;
            }
            Ok(())
        })?;
        // Evaluate the resulting global model.
        let eval = worker::with_runtime(&manifest, &key, |rt| {
            worker::evaluate(rt, &dataset)(&global)
        })?;
        println!(
            "{agg_name:<12} after {ROUNDS} poisoned rounds: loss {:.4} acc {:.3}",
            eval.mean_loss(),
            eval.accuracy()
        );
    }
    println!(
        "\nexpected shape: fedavg degrades under the attack; median and \
         trimmed-mean stay close to clean accuracy."
    );
    Ok(())
}
