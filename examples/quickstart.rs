//! Quickstart — the minimal end-to-end FerrisFL experiment.
//!
//! Mirrors the paper's Appendix A flow: build `FLParams`, shard a
//! dataset, initialise agents, pick a sampler + aggregator, hand it all
//! to the `Entrypoint`, and run. Everything below the `Entrypoint` is
//! a `ModelExecutor` backend — the pure-rust native executor by
//! default, or AOT-compiled HLO through PJRT — no python anywhere.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use ferrisfl::config::FlParams;
use ferrisfl::entrypoint::Entrypoint;
use ferrisfl::federation::Scheme;
use ferrisfl::loggers::ConsoleLogger;
use ferrisfl::runtime::Manifest;
use ferrisfl::util::error::Result;

fn main() -> Result<()> {
    // 1. Load the environment: the AOT manifest when artifacts are
    //    built (PJRT feature), else the hermetic native backend.
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));

    // 2. FLParams — the same hyperparameter surface as the paper's
    //    FLParams object (Fig 16 of the paper).
    let params = FlParams {
        experiment_name: "quickstart".into(),
        model: "mlp-s".into(),
        dataset: "synth-mnist".into(),
        num_agents: 10,
        sampling_ratio: 0.5,
        global_epochs: 5,
        local_epochs: 2,
        split: Scheme::NonIid { niid_factor: 3 },
        sampler: "random".into(),
        aggregator: "fedavg".into(),
        optimizer: "sgd".into(),
        mode: "full".into(),
        use_pretrained: false,
        lr: 0.05,
        seed: 42,
        workers: 4,
        fuse: false,
        eval_every: 1,
        max_local_steps: 0,
        log_dir: String::new(),
        dropout: 0.0,
        defense: "none".into(),
        compression: "none".into(),
        backend: manifest.backend.name().into(),
    };

    // 3. Entrypoint wires dataset -> sharding -> agents -> runtime.
    let mut entrypoint = Entrypoint::new(params, manifest)?;
    println!(
        "agents hold between {} and {} samples each",
        entrypoint.agents.iter().map(|a| a.num_samples()).min().unwrap(),
        entrypoint.agents.iter().map(|a| a.num_samples()).max().unwrap(),
    );

    // 4. Run, streaming per-round metrics to the console.
    let mut logger = ConsoleLogger::default();
    let result = entrypoint.run(&mut logger)?;

    println!(
        "\nquickstart done: final accuracy {:.1}% over {} test examples",
        100.0 * result.final_eval.accuracy(),
        result.final_eval.count as u64
    );
    println!("\n{}", result.profiler.report());
    Ok(())
}
