//! Quickstart — the minimal end-to-end FerrisFL experiment.
//!
//! Mirrors the paper's Appendix A flow: describe the experiment with
//! the builder, shard a dataset, initialise agents, pick a sampler +
//! aggregator, and run. Everything below the `Entrypoint` is a
//! `ModelExecutor` backend — the pure-rust native executor by default,
//! or AOT-compiled HLO through PJRT — no python anywhere.
//!
//! (Pre-builder code constructed an `FlParams` struct literal and an
//! `Entrypoint` by hand; that path still exists, but
//! `Experiment::builder()` is the supported surface.)
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use ferrisfl::prelude::*;

fn main() -> Result<()> {
    // 1. Load the environment: the AOT manifest when artifacts are
    //    built (PJRT feature), else the hermetic native backend.
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));

    // 2. Describe the experiment — the same hyperparameter surface as
    //    the paper's FLParams object (Fig 16), as typed setters over
    //    defaults. `build()` validates the whole config, shards the
    //    dataset, and initialises the agents.
    let mut experiment = Experiment::builder()
        .backend(manifest.backend)
        .manifest(manifest)
        .name("quickstart")
        .model("mlp-s")
        .dataset("synth-mnist")
        .num_agents(10)
        .sampling_ratio(0.5)
        .rounds(5)
        .local_epochs(2)
        .split(Scheme::NonIid { niid_factor: 3 })
        .sampler("random")
        .aggregator("fedavg")
        .lr(0.05)
        .seed(42)
        .workers(4)
        .build()?;

    // 3. Run, streaming per-round metrics to the console.
    let mut logger = ConsoleLogger::default();
    let result = experiment.run(&mut logger)?;

    println!(
        "\nquickstart done: final accuracy {:.1}% over {} test examples",
        100.0 * result.final_eval.accuracy(),
        result.final_eval.count as u64
    );
    println!("\n{}", result.profiler.report());
    Ok(())
}
