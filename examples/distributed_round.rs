//! Distributed execution — leader + workers, bit-identical.
//!
//! The same experiment runs twice: once single-process through the
//! round engine, once as a leader plus two workers speaking the full
//! framed wire protocol (`topology = inproc:2` — worker threads over
//! channel transports, so the example is self-contained). The wire
//! carries the streaming reduce's own 2^-40 fixed-point terms, so the
//! two final models match to the last bit — the example asserts it.
//!
//! The identical protocol runs across real processes from the CLI,
//! where the binary can respawn itself as workers over Unix sockets:
//!
//! ```text
//! ferrisfl run --config configs/quickstart.toml --topology multiprocess:2
//! ```
//!
//! or across machines with `--topology tcp:<addr>` and hand-started
//! `ferrisfl worker --connect tcp:<addr>` peers.
//!
//! Run: `cargo run --release --example distributed_round`

use ferrisfl::prelude::*;

fn build(topology: Topology, wire_retry: u32) -> Result<Experiment> {
    Experiment::builder()
        .name("distributed_round")
        .model("mlp-s")
        .dataset("synth-mnist")
        .num_agents(10)
        .sampling_ratio(0.5)
        .rounds(3)
        .local_epochs(1)
        .max_local_steps(8)
        .split(Scheme::NonIid { niid_factor: 3 })
        .seed(42)
        .topology(topology)
        // Wire resend budget for corrupt/straggling frames. Only the
        // distributed run sets it: recovered resends never change the
        // result bits, but single-process `retry` means engine chaos.
        .retry(wire_retry)
        .build()
}

fn main() -> Result<()> {
    // Single-process reference through the round engine.
    let mut single = build(Topology::Single, 0)?;
    let reference = single.run(&mut NullLogger)?;
    let reference_model = single.global_params().to_vec();

    // The identical experiment as leader + 2 workers. The workers
    // rebuild dataset + shards deterministically from the wired
    // config; only quantised deltas cross the transports.
    let mut distributed = build("inproc:2".parse()?, 2)?;
    let result = distributed.run(&mut ConsoleLogger::default())?;

    let model = distributed.global_params();
    let identical = model.len() == reference_model.len()
        && model
            .iter()
            .zip(&reference_model)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "distributed and single-process models must match bit for bit");

    println!(
        "\ndistributed accuracy {:.1}% == single-process accuracy {:.1}% \
         ({} params byte-identical)",
        100.0 * result.final_eval.accuracy(),
        100.0 * reference.final_eval.accuracy(),
        model.len()
    );
    Ok(())
}
