//! Federated MNIST — the end-to-end driver (paper §4.1.3, Fig 8(i)).
//!
//! Trains LeNet-5 on synth-mnist with FedAvg across 100 agents (10%
//! sampled per round, 5 local epochs), comparing IID against non-IID
//! sharding — the paper's flagship FL demonstration, scaled for a CPU
//! PJRT testbed via --rounds. Built with `Experiment::builder()`, the
//! typed replacement for hand-rolled `FlParams` literals.
//!
//! Run: `cargo run --release --example federated_mnist [-- --rounds N]`

use std::sync::Arc;

use ferrisfl::prelude::*;

fn main() -> Result<()> {
    let rounds: usize = std::env::args()
        .skip_while(|a| a != "--rounds")
        .nth(1)
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(10);
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));

    let mut finals = Vec::new();
    for split in [Scheme::Iid, Scheme::NonIid { niid_factor: 3 }] {
        println!("\n=== LeNet-5 FedAvg, 100 agents, 10% sampled, split {split} ===");
        let mut experiment = Experiment::builder()
            .backend(manifest.backend)
            .manifest(Arc::clone(&manifest))
            .name(format!("federated_mnist_{split}"))
            .model("lenet5")
            .dataset("synth-mnist")
            .num_agents(100)
            .sampling_ratio(0.1)
            .rounds(rounds)
            .local_epochs(5)
            .split(split)
            .lr(0.05)
            .seed(42)
            .log_dir("results/logs")
            .build()?;
        let mut logger = ConsoleLogger::default();
        let res = experiment.run(&mut logger)?;
        println!(
            "{split}: final eval loss {:.4}, accuracy {:.3}",
            res.final_eval.mean_loss(),
            res.final_eval.accuracy()
        );
        finals.push((split, res.final_eval));
    }

    println!("\nsummary (paper shape: IID converges faster than non-IID):");
    for (split, eval) in finals {
        println!(
            "  {split:<8} loss {:.4} acc {:.3}",
            eval.mean_loss(),
            eval.accuracy()
        );
    }
    Ok(())
}
