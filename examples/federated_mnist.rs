//! Federated MNIST — the end-to-end driver (paper §4.1.3, Fig 8(i)).
//!
//! Trains LeNet-5 on synth-mnist with FedAvg across 100 agents (10%
//! sampled per round, 5 local epochs), comparing IID against non-IID
//! sharding — the paper's flagship FL demonstration, scaled for a CPU
//! PJRT testbed via --rounds.
//!
//! Run: `cargo run --release --example federated_mnist [-- --rounds N]`

use std::sync::Arc;

use ferrisfl::config::FlParams;
use ferrisfl::entrypoint::Entrypoint;
use ferrisfl::federation::Scheme;
use ferrisfl::loggers::ConsoleLogger;
use ferrisfl::runtime::Manifest;
use ferrisfl::util::error::Result;

fn main() -> Result<()> {
    let rounds: usize = std::env::args()
        .skip_while(|a| a != "--rounds")
        .nth(1)
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(10);
    let manifest = Arc::new(Manifest::load_or_native("artifacts"));

    let mut finals = Vec::new();
    for split in [Scheme::Iid, Scheme::NonIid { niid_factor: 3 }] {
        println!("\n=== LeNet-5 FedAvg, 100 agents, 10% sampled, split {split} ===");
        let params = FlParams {
            experiment_name: format!("federated_mnist_{split}"),
            model: "lenet5".into(),
            dataset: "synth-mnist".into(),
            num_agents: 100,
            sampling_ratio: 0.1,
            global_epochs: rounds,
            local_epochs: 5,
            split,
            sampler: "random".into(),
            aggregator: "fedavg".into(),
            optimizer: "sgd".into(),
            mode: "full".into(),
            use_pretrained: false,
            lr: 0.05,
            seed: 42,
            workers: 0, // auto
            fuse: false,
            eval_every: 1,
            max_local_steps: 0,
            log_dir: "results/logs".into(),
            dropout: 0.0,
            defense: "none".into(),
            compression: "none".into(),
            backend: manifest.backend.name().into(),
        };
        let mut ep = Entrypoint::new(params, Arc::clone(&manifest))?;
        let mut logger = ConsoleLogger::default();
        let res = ep.run(&mut logger)?;
        println!(
            "{split}: final eval loss {:.4}, accuracy {:.3}",
            res.final_eval.mean_loss(),
            res.final_eval.accuracy()
        );
        finals.push((split, res.final_eval));
    }

    println!("\nsummary (paper shape: IID converges faster than non-IID):");
    for (split, eval) in finals {
        println!(
            "  {split:<8} loss {:.4} acc {:.3}",
            eval.mean_loss(),
            eval.accuracy()
        );
    }
    Ok(())
}
