//! Fault injection and recovery on the round engine.
//!
//! Cross-device FL means unreliable clients: phones crash mid-training,
//! uploads vanish or arrive corrupted, and availability follows the
//! day/night cycle. This example runs the same small workload twice —
//! once with faults only, once with the recovery policy switched on
//! (retries with backoff, replacement resampling, a quorum floor) —
//! and prints each round's outcome and recovery counters. The whole
//! fault schedule is seeded: rerunning this binary replays the exact
//! same crashes, drops, and churn windows.
//!
//! Run: `cargo run --release --example chaos_recovery`

use ferrisfl::prelude::*;

fn run(tag: &str, recover: bool) -> Result<()> {
    let mut builder = Experiment::builder()
        .name(format!("chaos_{tag}"))
        .model("mlp-s")
        .dataset("synth-mnist")
        .num_agents(12)
        .sampling_ratio(0.75)
        .rounds(4)
        .local_epochs(1)
        .max_local_steps(8)
        .eval_every(1)
        .workers(2)
        .latency("lognormal:0.4,0.6".parse()?)
        .deadline_secs(3.0)
        // 25% of attempts crash mid-training, 15% of deliveries are
        // lost, 10% arrive corrupted, and every client follows a
        // diurnal on/off cycle (online 60% of each 6-sim-second "day").
        .fault_plan("crash:0.25;drop:0.15;corrupt:0.1;churn:diurnal:6,0.6".parse()?);
    if recover {
        builder = builder
            .retry(2)
            .backoff("0.2,2,0.25".parse()?)
            .resample(true)
            .quorum(0.25);
    }
    let mut exp = builder.build()?;
    let res = exp.run(&mut NullLogger)?;

    println!("{tag}:");
    for r in &res.rounds {
        let s = r.recovery;
        println!(
            "  round {}: {:<20} cohort {:>2} | {} failed, {} retried, {} corrupt, {} replaced | eval loss {:.4}",
            r.round,
            r.outcome.name(),
            r.sampled.len(),
            s.failures,
            s.retries,
            s.corrupt_rejected,
            s.replacements,
            r.eval_loss,
        );
    }
    println!(
        "  final: eval loss {:.4}, accuracy {:.3}\n",
        res.final_eval.mean_loss(),
        res.final_eval.accuracy()
    );
    Ok(())
}

fn main() -> Result<()> {
    run("no_recovery", false)?;
    run("with_recovery", true)?;
    println!(
        "expected shape: without recovery, failed clients are simply lost \
         and rounds aggregate thin (or skip); with retries + resampling + \
         quorum the engine refills the cohort and converges faster."
    );
    Ok(())
}
