//! ferrisfl — CLI leader entrypoint.
//!
//! ```text
//! ferrisfl run --config configs/quickstart.toml [--backend native|pjrt]
//! ferrisfl worker --connect uds:<path>|tcp:<addr>
//! ferrisfl list [datasets|models|artifacts]
//! ferrisfl repro <table1|table2|table3|table4|fig6|...|all> [--quick]
//! ferrisfl info
//! ```

use std::sync::Arc;

use ferrisfl::config::FlParams;
use ferrisfl::entrypoint::Entrypoint;
use ferrisfl::loggers::{ConsoleLogger, CsvLogger, JsonlLogger, Logger, MultiLogger};
use ferrisfl::repro::{self, ReproOptions};
use ferrisfl::runtime::{BackendKind, Manifest};
use ferrisfl::util::error::{bail, Context, Result};
use ferrisfl::zoo;

const USAGE: &str = "\
ferrisfl — FerrisFL: bootstrap federated-learning experiments (TorchFL repro)

USAGE:
  ferrisfl run --config <file.toml> [--backend native|pjrt] [--artifacts <dir>] [--workers <n>] [--fuse]
               [--topology single|inproc:N|multiprocess:N|tcp:<addr>] [--save-model <path>]
               [--latency <model>] [--deadline <secs>] [--goal <k>] [--staleness-alpha <a>] [--clock virtual|wall]
               [--fault-plan <plan>] [--adversary <spec>] [--retry <n>] [--backoff <b[,f[,j]]>]
               [--quorum <frac>] [--resample] [--registry auto|materialized|virtual]
  ferrisfl worker --connect uds:<path>|tcp:<host:port>
  ferrisfl list [datasets|models|artifacts] [--backend native|pjrt] [--artifacts <dir>]
  ferrisfl repro <experiment|all> [--quick] [--out <dir>] [--backend native|pjrt]
  ferrisfl info [--backend native|pjrt] [--artifacts <dir>]

BACKENDS:
  native  pure-rust CPU executor, no artifacts needed (default)
  pjrt    AOT HLO artifacts via PJRT/XLA (build with --features pjrt,
          then `make artifacts` and pass --artifacts <dir>)

ROUND ENGINE (all optional; defaults reproduce the lockstep loop):
  --latency <model>       per-client latency: none | constant:SECS |
                          lognormal:MEDIAN,SIGMA | trace:S1,S2,...
  --deadline <secs>       close each round after this simulated window
  --goal <k>              finalize once k updates arrived (FedBuff)
  --staleness-alpha <a>   staleness discount exponent (default 0.5)
  --clock virtual|wall    simulated (deterministic) or measured time

DISTRIBUTED (the wire carries the streaming reduce's fixed-point terms,
so every topology lands on bits identical to single-process):
  --topology <t>          single (default) | inproc:N worker threads |
                          multiprocess:N spawned processes over Unix
                          sockets | tcp:<addr> externally started
                          workers (`ferrisfl worker --connect ...`)
  --save-model <path>     write the final global model as little-endian
                          f32 bytes (handy for byte-compare checks)

FAULTS & RECOVERY (seeded chaos; replays bit-identically):
  --fault-plan <plan>     none | TERM[;TERM...] with dropout:P crash:P
                          drop:P corrupt:P churn:flapping:PERIOD,DUTY
                          churn:diurnal:PERIOD,DUTY
  --adversary <spec>      seeded Byzantine clients: none | TERM[;TERM...]
                          with adv:signflip:P adv:scale:F,P
                          adv:noise:SIGMA,P adv:collude:F,FRAC; poisoned
                          deltas pass the integrity checks — pair with a
                          robust --aggregator (median | trim[:beta] |
                          sketch-median | sketch-trim[:beta] |
                          geomedian[:reservoir])
  --retry <n>             retry attempts per failed client (default 0)
  --backoff <b[,f[,j]]>   retry backoff BASE[,FACTOR[,JITTER]] seconds
  --quorum <frac>         skip rounds with fewer arrivals than this
                          fraction of the planned cohort
  --resample              replace permanently failed clients from the
                          available pool

CROSS-DEVICE SCALE:
  --registry <mode>       auto (default; eager agents up to 10k, then
                          virtual) | materialized | virtual — virtual
                          derives shards/weights/state lazily from
                          (seed, agent_id), so memory tracks the cohort
                          K, not the population (10^6+ agents)

EXPERIMENTS (paper artefacts):
  table1 table2 table3 table4 fig6 fig7 fig8i fig8ii fig9 fig10 | all
";

/// Tiny argv parser: positionals + --key value + --flag.
struct Args {
    positional: Vec<String>,
    options: std::collections::BTreeMap<String, String>,
    flags: std::collections::BTreeSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut options = std::collections::BTreeMap::new();
        let mut flags = std::collections::BTreeSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // Flags we know take no value.
                if matches!(name, "quick" | "verbose" | "help" | "fuse" | "resample") {
                    flags.insert(name.to_string());
                } else {
                    let v = argv
                        .get(i + 1)
                        .with_context(|| format!("--{name} needs a value"))?;
                    options.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self {
            positional,
            options,
            flags,
        })
    }

    fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
}

/// Resolve the backend: `--backend` wins, then `fallback` (a config
/// value for `run`, "native" elsewhere).
fn backend_of(args: &Args, fallback: &str) -> Result<BackendKind> {
    BackendKind::parse(args.opt("backend").unwrap_or(fallback))
}

/// Load the environment for `backend`: the in-memory native manifest, or
/// the AOT manifest from `--artifacts <dir>` for PJRT.
fn load_manifest(args: &Args, backend: BackendKind) -> Result<Arc<Manifest>> {
    match backend {
        BackendKind::Native => Ok(Arc::new(Manifest::native())),
        BackendKind::Pjrt => {
            let dir = args.opt("artifacts").unwrap_or("artifacts");
            Ok(Arc::new(Manifest::load(dir)?))
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let config = args
        .opt("config")
        .context("run requires --config <file.toml>")?;
    let mut params = FlParams::from_file(config)?;
    if let Some(w) = args.opt("workers") {
        params.workers = w.parse()?;
    }
    if args.flags.contains("fuse") {
        params.fuse = true;
    }
    if let Some(l) = args.opt("latency") {
        params.latency = l.parse()?;
    }
    if let Some(d) = args.opt("deadline") {
        params.deadline_secs = d.parse()?;
    }
    if let Some(g) = args.opt("goal") {
        params.agg_goal = g.parse()?;
    }
    if let Some(a) = args.opt("staleness-alpha") {
        params.staleness_alpha = a.parse()?;
    }
    if let Some(c) = args.opt("clock") {
        params.clock = c.parse()?;
    }
    if let Some(p) = args.opt("fault-plan") {
        params.faults = p.parse()?;
    }
    if let Some(a) = args.opt("adversary") {
        params.adversary = a.parse()?;
    }
    if let Some(r) = args.opt("retry") {
        params.retry = r.parse()?;
    }
    if let Some(b) = args.opt("backoff") {
        params.backoff = b.parse()?;
    }
    if let Some(q) = args.opt("quorum") {
        params.quorum = q.parse()?;
    }
    if args.flags.contains("resample") {
        params.resample = true;
    }
    if let Some(t) = args.opt("topology") {
        params.topology = t.parse()?;
    }
    if let Some(r) = args.opt("registry") {
        params.registry = r.parse()?;
    }
    params.validate()?;
    let backend = backend_of(args, params.backend.name())?;
    params.backend = backend;
    let manifest = load_manifest(args, backend)?;

    println!(
        "experiment {:?}: {}@{} on {} | {} agents, {:.0}% sampled, {} rounds x {} local epochs | split {} | {} + {}",
        params.experiment_name,
        params.model,
        params.dataset,
        params.backend,
        params.num_agents,
        params.sampling_ratio * 100.0,
        params.global_epochs,
        params.local_epochs,
        params.split,
        params.sampler,
        params.aggregator,
    );

    let mut sinks: Vec<Box<dyn Logger>> = vec![Box::new(ConsoleLogger {
        verbose: args.flags.contains("verbose"),
    })];
    if !params.log_dir.is_empty() {
        sinks.push(Box::new(CsvLogger::create(
            &params.log_dir,
            &params.experiment_name,
        )?));
        sinks.push(Box::new(JsonlLogger::create(
            &params.log_dir,
            &params.experiment_name,
        )?));
    }
    let mut logger = MultiLogger::new(sinks);

    let mut ep = Entrypoint::new(params, manifest)?;
    let res = ep.run(&mut logger)?;
    println!(
        "\nfinal global model: eval loss {:.4}, accuracy {:.3} ({} examples)",
        res.final_eval.mean_loss(),
        res.final_eval.accuracy(),
        res.final_eval.count as u64,
    );
    println!("\n{}", res.profiler.report());
    if let Some(path) = args.opt("save-model") {
        save_model(path, ep.global_params())?;
        println!("saved final global model to {path}");
    }
    Ok(())
}

/// Write the global model as raw little-endian f32 bytes — a stable
/// format that `cmp` can byte-compare across topologies.
fn save_model(path: &str, params: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing model to {path:?}"))
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .opt("connect")
        .context("worker requires --connect uds:<path>|tcp:<host:port>")?;
    ferrisfl::transport::worker_main(addr)
}

fn cmd_list(args: &Args) -> Result<()> {
    let manifest = load_manifest(args, backend_of(args, "native")?)?;
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    if matches!(what, "datasets" | "all") {
        println!("{}", zoo::datasets_table(&manifest));
    }
    if matches!(what, "models" | "all") {
        println!("{}", zoo::models_table(&manifest));
    }
    if matches!(what, "artifacts" | "all") {
        println!("{}", zoo::artifacts_table(&manifest));
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let exp = args
        .positional
        .get(1)
        .context("repro requires an experiment id (or `all`)")?;
    let backend = backend_of(args, "native")?;
    let manifest = load_manifest(args, backend)?;
    let opts = ReproOptions {
        quick: args.flags.contains("quick"),
        out_dir: args.opt("out").unwrap_or("results").into(),
        workers: args.opt("workers").map(|w| w.parse()).transpose()?.unwrap_or(0),
        seed: args.opt("seed").map(|s| s.parse()).transpose()?.unwrap_or(42),
        backend: backend.name().into(),
    };
    repro::run(exp, &manifest, &opts)
}

fn cmd_info(args: &Args) -> Result<()> {
    let backend = backend_of(args, "native")?;
    let manifest = load_manifest(args, backend)?;
    println!("FerrisFL — TorchFL (arXiv:2211.00735) reproduction");
    println!("backend       : {}", manifest.backend);
    #[cfg(feature = "pjrt")]
    if backend == BackendKind::Pjrt {
        let device = ferrisfl::runtime::Device::cpu()?;
        println!("PJRT platform : {}", device.platform());
    }
    println!("artifacts dir : {}", manifest.dir.display());
    println!("datasets      : {}", manifest.datasets.len());
    println!("zoo variants  : {}", manifest.zoo.len());
    println!("artifacts     : {}", manifest.artifacts.len());
    println!("train batch   : {}", manifest.train_batch);
    println!("eval batch    : {}", manifest.eval_batch);
    println!("agg K_pad     : {}", manifest.k_pad);
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    if args.flags.contains("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "run" => cmd_run(&args),
        "worker" => cmd_worker(&args),
        "list" => cmd_list(&args),
        "repro" => cmd_repro(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
