//! Poisoning defenses — server-side update filters (paper §6.3; the
//! paper's own citation [23] is FedClean, a parameter-poisoning
//! defense).
//!
//! A [`Defense`] inspects the round's updates *before* aggregation and
//! may clip or reject them:
//!
//! - [`NormClip`] — scale any delta whose L2 norm exceeds `c` down to
//!   the threshold (bounds the influence of any single client).
//! - [`CosineFilter`] — reject updates whose cosine similarity to the
//!   coordinate-median direction falls below a threshold (directional
//!   outliers; a FedClean-flavoured filter).
//! - [`NormOutlierFilter`] — reject updates whose norm exceeds
//!   `k` × median norm (magnitude outliers).
//! - [`NoDefense`] — pass-through baseline.
//!
//! Defenses compose with any aggregator: the entrypoint applies the
//! defense, then hands surviving updates to the aggregation rule.

use crate::aggregators::Update;
use crate::util::error::{bail, Result};

/// Outcome of screening one round's updates.
#[derive(Clone, Debug, Default)]
pub struct DefenseReport {
    /// Agent ids whose updates were rejected outright.
    pub rejected: Vec<usize>,
    /// Agent ids whose updates were modified (e.g. clipped).
    pub clipped: Vec<usize>,
}

/// Server-side update screen.
pub trait Defense: Send {
    /// Filter/transform `updates` in place; return what happened.
    fn screen(&mut self, updates: &mut Vec<Update>) -> DefenseReport;

    /// True when this defense never inspects or modifies updates, so
    /// the round may reduce them incrementally (streaming) instead of
    /// materializing the cohort for screening.
    fn is_passthrough(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

fn l2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Pass-through.
#[derive(Default)]
pub struct NoDefense;

impl Defense for NoDefense {
    fn screen(&mut self, _updates: &mut Vec<Update>) -> DefenseReport {
        DefenseReport::default()
    }

    fn is_passthrough(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

/// Clip every delta to L2 norm <= `c`.
pub struct NormClip {
    pub c: f64,
}

impl NormClip {
    pub fn new(c: f64) -> Self {
        assert!(c > 0.0);
        Self { c }
    }
}

impl Defense for NormClip {
    fn screen(&mut self, updates: &mut Vec<Update>) -> DefenseReport {
        let mut report = DefenseReport::default();
        for u in updates.iter_mut() {
            let n = l2(&u.delta);
            if n > self.c {
                let s = (self.c / n) as f32;
                for d in u.delta.iter_mut() {
                    *d *= s;
                }
                report.clipped.push(u.agent_id);
            }
        }
        report
    }

    fn name(&self) -> &'static str {
        "normclip"
    }
}

/// Reject deltas whose norm exceeds `k` × median norm.
pub struct NormOutlierFilter {
    pub k: f64,
}

impl NormOutlierFilter {
    pub fn new(k: f64) -> Self {
        assert!(k >= 1.0);
        Self { k }
    }
}

impl Defense for NormOutlierFilter {
    fn screen(&mut self, updates: &mut Vec<Update>) -> DefenseReport {
        let mut report = DefenseReport::default();
        if updates.len() < 3 {
            return report; // not enough context to call outliers
        }
        let mut norms: Vec<f64> = updates.iter().map(|u| l2(&u.delta)).collect();
        let mut sorted = norms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2].max(1e-12);
        let mut i = 0;
        updates.retain(|u| {
            let keep = norms[i] <= self.k * median;
            if !keep {
                report.rejected.push(u.agent_id);
            }
            i += 1;
            keep
        });
        norms.clear();
        report
    }

    fn name(&self) -> &'static str {
        "normfilter"
    }
}

/// Reject deltas pointing away from the robust (median) direction.
pub struct CosineFilter {
    /// Minimum cosine similarity to the median direction to survive.
    pub min_cos: f64,
}

impl CosineFilter {
    pub fn new(min_cos: f64) -> Self {
        assert!((-1.0..=1.0).contains(&min_cos));
        Self { min_cos }
    }
}

impl Defense for CosineFilter {
    fn screen(&mut self, updates: &mut Vec<Update>) -> DefenseReport {
        let mut report = DefenseReport::default();
        if updates.len() < 3 {
            return report;
        }
        let p = updates[0].delta.len();
        // Coordinate-median reference direction (robust to < half bad).
        let mut median = vec![0.0f32; p];
        let mut col = vec![0.0f32; updates.len()];
        for i in 0..p {
            for (j, u) in updates.iter().enumerate() {
                col[j] = u.delta[i];
            }
            col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            median[i] = col[col.len() / 2];
        }
        let mnorm = l2(&median);
        if mnorm < 1e-12 {
            return report;
        }
        let cos: Vec<f64> = updates
            .iter()
            .map(|u| {
                let dot: f64 = u
                    .delta
                    .iter()
                    .zip(&median)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                dot / (l2(&u.delta).max(1e-12) * mnorm)
            })
            .collect();
        let mut i = 0;
        updates.retain(|u| {
            let keep = cos[i] >= self.min_cos;
            if !keep {
                report.rejected.push(u.agent_id);
            }
            i += 1;
            keep
        });
        report
    }

    fn name(&self) -> &'static str {
        "cosine"
    }
}

/// Parse a config name:
/// `none | normclip:<c> | normfilter:<k> | cosine:<min_cos>`.
pub fn from_name(name: &str) -> Result<Box<dyn Defense>> {
    let t = name.trim().to_ascii_lowercase();
    if t == "none" || t.is_empty() {
        return Ok(Box::new(NoDefense));
    }
    if let Some(rest) = t.strip_prefix("normclip:") {
        return Ok(Box::new(NormClip::new(rest.parse()?)));
    }
    if let Some(rest) = t.strip_prefix("normfilter:") {
        return Ok(Box::new(NormOutlierFilter::new(rest.parse()?)));
    }
    if let Some(rest) = t.strip_prefix("cosine:") {
        return Ok(Box::new(CosineFilter::new(rest.parse()?)));
    }
    bail!(
        "unknown defense {name:?} \
         (none | normclip:<c> | normfilter:<k> | cosine:<min_cos>)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(id: usize, delta: Vec<f32>) -> Update {
        Update {
            agent_id: id,
            delta,
            num_samples: 1,
        }
    }

    #[test]
    fn normclip_scales_oversized() {
        let mut ups = vec![upd(0, vec![3.0, 4.0]), upd(1, vec![0.3, 0.4])];
        let mut d = NormClip::new(1.0);
        let rep = d.screen(&mut ups);
        assert_eq!(rep.clipped, vec![0]);
        let n0 = l2(&ups[0].delta);
        assert!((n0 - 1.0).abs() < 1e-6);
        // direction preserved
        assert!((ups[0].delta[0] / ups[0].delta[1] - 0.75).abs() < 1e-5);
        // small update untouched
        assert_eq!(ups[1].delta, vec![0.3, 0.4]);
    }

    #[test]
    fn normfilter_rejects_magnitude_outlier() {
        let mut ups: Vec<Update> =
            (0..5).map(|i| upd(i, vec![0.1, 0.1])).collect();
        ups.push(upd(5, vec![1e4, 1e4]));
        let mut d = NormOutlierFilter::new(3.0);
        let rep = d.screen(&mut ups);
        assert_eq!(rep.rejected, vec![5]);
        assert_eq!(ups.len(), 5);
    }

    #[test]
    fn cosine_rejects_signflip_attack() {
        // honest updates ~ +0.1 direction; attacker sign-flips.
        let mut ups: Vec<Update> = (0..6)
            .map(|i| upd(i, vec![0.1, 0.11, 0.09, 0.1]))
            .collect();
        ups.push(upd(6, vec![-0.8, -0.88, -0.72, -0.8]));
        let mut d = CosineFilter::new(0.0);
        let rep = d.screen(&mut ups);
        assert_eq!(rep.rejected, vec![6]);
        assert_eq!(ups.len(), 6);
    }

    #[test]
    fn defenses_pass_clean_rounds() {
        let clean: Vec<Update> = (0..5)
            .map(|i| upd(i, vec![0.1 + 0.01 * i as f32, 0.1]))
            .collect();
        for name in ["normclip:10", "normfilter:5", "cosine:0.5"] {
            let mut ups = clean.clone();
            let mut d = from_name(name).unwrap();
            let rep = d.screen(&mut ups);
            assert!(rep.rejected.is_empty(), "{name}");
            assert_eq!(ups.len(), 5, "{name}");
        }
    }

    #[test]
    fn small_rounds_are_not_filtered() {
        let mut ups = vec![upd(0, vec![1e6, 1e6]), upd(1, vec![0.1, 0.1])];
        let mut d = NormOutlierFilter::new(2.0);
        let rep = d.screen(&mut ups);
        assert!(rep.rejected.is_empty());
        assert_eq!(ups.len(), 2);
    }

    #[test]
    fn from_name_parses() {
        for n in ["none", "normclip:2.0", "normfilter:3", "cosine:0.2"] {
            assert!(from_name(n).is_ok(), "{n}");
        }
        assert!(from_name("krum").is_err());
    }

    /// Only the no-op defense may advertise passthrough — the round
    /// pipeline streams (skips cohort screening) based on this probe.
    #[test]
    fn only_nodefense_is_passthrough() {
        assert!(from_name("none").unwrap().is_passthrough());
        for n in ["normclip:2.0", "normfilter:3", "cosine:0.2"] {
            assert!(!from_name(n).unwrap().is_passthrough(), "{n}");
        }
    }
}
