//! Incentive mechanisms — contribution scoring + reward allocation
//! (paper §3.2.1 motivates the decoupled agent precisely so incentive
//! research can attach state; §6.3 lists incentive mechanisms as a
//! target extension; the paper cites Zeng et al.'s incentive survey).
//!
//! [`ContributionTracker`] scores each sampled agent per round by
//! *gradient alignment*: the projection of the agent's delta onto the
//! aggregated round delta, normalised across the cohort. Aligned,
//! large-magnitude updates earn more; orthogonal or adversarial
//! (negatively aligned) updates earn zero-floored credit. Cumulative
//! scores drive [`ContributionTracker::allocate`] (proportional payout)
//! and can feed the reputation sampler.

use std::collections::BTreeMap;

use crate::aggregators::Update;

/// Per-agent cumulative contribution state.
#[derive(Clone, Debug, Default)]
pub struct Contribution {
    /// Sum of per-round normalised alignment scores.
    pub score: f64,
    /// Rounds this agent participated in.
    pub rounds: usize,
    /// Last round's raw alignment (for diagnostics).
    pub last_alignment: f64,
}

/// Gradient-alignment contribution scoring.
#[derive(Clone, Debug, Default)]
pub struct ContributionTracker {
    pub contributions: BTreeMap<usize, Contribution>,
}

impl ContributionTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Score one round: `updates` are the cohort's deltas, `aggregated`
    /// is the round's combined delta (e.g. `global' - global`).
    ///
    /// score_i = max(0, <delta_i, aggregated>) / Σ_j max(0, <delta_j, aggregated>)
    pub fn record_round(&mut self, updates: &[Update], aggregated: &[f32]) {
        let dots: Vec<f64> = updates
            .iter()
            .map(|u| {
                u.delta
                    .iter()
                    .zip(aggregated)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
            })
            .collect();
        let positive: f64 = dots.iter().map(|&d| d.max(0.0)).sum();
        for (u, &dot) in updates.iter().zip(&dots) {
            let entry = self.contributions.entry(u.agent_id).or_default();
            entry.rounds += 1;
            entry.last_alignment = dot;
            if positive > 0.0 {
                entry.score += dot.max(0.0) / positive;
            }
        }
    }

    /// Split a reward `budget` proportionally to cumulative scores.
    /// Agents with zero (or negative-only) contribution receive nothing.
    pub fn allocate(&self, budget: f64) -> BTreeMap<usize, f64> {
        let total: f64 = self.contributions.values().map(|c| c.score).sum();
        self.contributions
            .iter()
            .map(|(&id, c)| {
                let share = if total > 0.0 {
                    budget * c.score / total
                } else {
                    0.0
                };
                (id, share)
            })
            .collect()
    }

    /// Contribution score of one agent (0 if never seen).
    pub fn score(&self, agent_id: usize) -> f64 {
        self.contributions
            .get(&agent_id)
            .map_or(0.0, |c| c.score)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(id: usize, delta: Vec<f32>) -> Update {
        Update {
            agent_id: id,
            delta,
            num_samples: 1,
        }
    }

    #[test]
    fn aligned_agents_earn_more() {
        let mut t = ContributionTracker::new();
        let agg = vec![1.0f32, 1.0];
        let ups = vec![
            upd(0, vec![1.0, 1.0]),   // perfectly aligned, big
            upd(1, vec![0.1, 0.1]),   // aligned, small
            upd(2, vec![-1.0, -1.0]), // adversarial
        ];
        t.record_round(&ups, &agg);
        assert!(t.score(0) > t.score(1));
        assert_eq!(t.score(2), 0.0);
        // Scores normalise to 1 per round (over positive contributors).
        let sum: f64 = (0..3).map(|i| t.score(i)).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn allocation_is_proportional_and_budget_preserving() {
        let mut t = ContributionTracker::new();
        let agg = vec![1.0f32];
        t.record_round(&[upd(0, vec![3.0]), upd(1, vec![1.0])], &agg);
        let pay = t.allocate(100.0);
        assert!((pay[&0] - 75.0).abs() < 1e-6);
        assert!((pay[&1] - 25.0).abs() < 1e-6);
        assert!((pay.values().sum::<f64>() - 100.0).abs() < 1e-6);
    }

    #[test]
    fn accumulates_across_rounds() {
        let mut t = ContributionTracker::new();
        let agg = vec![1.0f32];
        t.record_round(&[upd(0, vec![1.0]), upd(1, vec![1.0])], &agg);
        t.record_round(&[upd(0, vec![1.0])], &agg);
        assert_eq!(t.contributions[&0].rounds, 2);
        assert_eq!(t.contributions[&1].rounds, 1);
        assert!(t.score(0) > t.score(1));
    }

    #[test]
    fn zero_aggregate_gives_no_credit() {
        let mut t = ContributionTracker::new();
        t.record_round(&[upd(0, vec![1.0, -1.0])], &[0.0, 0.0]);
        assert_eq!(t.score(0), 0.0);
        let pay = t.allocate(10.0);
        assert_eq!(pay[&0], 0.0);
    }
}
