//! Cache-blocked GEMM drivers — the compute core of the native
//! backend's train/eval hot path.
//!
//! All matrices are row-major `f32` slices. The drivers keep the
//! blocking/tiling strategy of the original engine and delegate the
//! innermost loops to the runtime-dispatched micro-kernels in
//! [`super::simd`] (scalar, AVX2+FMA, or NEON — chosen once at
//! startup):
//!
//! - **axpy form**: the innermost loop updates independent elements of a
//!   C row (`c[j] += x · b[j]`) — vectorizable without
//!   float-reassociation permission on the scalar path, and an FMA
//!   stream on the SIMD paths;
//! - **register tiling**: each micro step updates two C rows from four
//!   rank-1 contributions at once (a 2×4 tile of scalar multipliers held
//!   in registers); where AVX2's sixteen 256-bit registers allow, the K
//!   loop takes eight contributions per step (a 2×8 tile via
//!   `axpy8_2`, one C load/store per 8 K-steps);
//! - **cache blocking**: the N dimension is walked in [`NC`]-wide panels
//!   so the active C rows and streamed B rows stay L1/L2-resident, and
//!   the K dimension in [`KC`]-deep panels so a B panel is reused across
//!   every C row before it is evicted;
//! - **zero skipping**: a micro tile whose multipliers are all zero is
//!   skipped — ReLU-masked gradients are sparse row-wise, so entire
//!   tiles of the backward pass vanish (the scalar 2×8 step preserves
//!   the original per-2×4-half skip granularity).
//!
//! Summation order differs from a naive triple loop (blocking + tile
//! fusion, FMA on the SIMD paths), so results agree with the reference
//! to ~1e-6 relative, not bit-exactly; the golden tests in
//! [`super::native`] pin the contract at 1e-5. Given the same shapes,
//! inputs, and dispatch level the kernels are fully deterministic.

use super::simd;

/// Width of one N panel (floats). Two C-row tiles of `NC` floats plus
/// four streamed B rows fit comfortably in L1 (6 × 2 KiB = 12 KiB).
const NC: usize = 512;
/// Depth of one K panel: a `KC × NC` B panel is 256 KiB — L2-resident.
/// A multiple of 8 so full panels run entirely on the 2×8 micro step.
const KC: usize = 128;

/// `c[M×N] += A[M×K] · B[K×N]` (all row-major).
///
/// Used for the forward `X·Wᵀ` pass (with `B` = the pre-transposed
/// weight view, see [`transpose`]) and the backward `dprev = dz·W` pass
/// (where `W` is already `[fan_out × fan_in]` row-major, i.e. exactly
/// the `[K×N]` operand — no transposition needed).
pub fn gemm_nn_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert!(a.len() >= m * k, "A is {} floats, want {}x{}", a.len(), m, k);
    assert!(b.len() >= k * n, "B is {} floats, want {}x{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C is {} floats, want {}x{}", c.len(), m, n);
    let kr = simd::kernels();
    let mut jc = 0;
    while jc < n {
        let nn = NC.min(n - jc);
        let mut kc = 0;
        while kc < k {
            let kk = KC.min(k - kc);
            // One (kc, jc) panel: every pair of C rows against the panel.
            let mut i = 0;
            while i + 2 <= m {
                let (r0, r1) = c[i * n..(i + 2) * n].split_at_mut(n);
                let c0 = &mut r0[jc..jc + nn];
                let c1 = &mut r1[jc..jc + nn];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let mut t = kc;
                while t + 8 <= kc + kk {
                    let bt = brows8(b, t, n, jc, nn);
                    let x0: [f32; 8] = a0[t..t + 8].try_into().unwrap();
                    let x1: [f32; 8] = a1[t..t + 8].try_into().unwrap();
                    (kr.axpy8_2)(c0, c1, bt, x0, x1);
                    t += 8;
                }
                while t + 4 <= kc + kk {
                    let bt = brows(b, t, n, jc, nn);
                    let x0 = [a0[t], a0[t + 1], a0[t + 2], a0[t + 3]];
                    let x1 = [a1[t], a1[t + 1], a1[t + 2], a1[t + 3]];
                    (kr.axpy4_2)(c0, c1, bt, x0, x1);
                    t += 4;
                }
                while t < kc + kk {
                    let b0 = &b[t * n + jc..t * n + jc + nn];
                    (kr.axpy1_2)(c0, c1, b0, a0[t], a1[t]);
                    t += 1;
                }
                i += 2;
            }
            if i < m {
                let c0 = &mut c[i * n + jc..i * n + jc + nn];
                let a0 = &a[i * k..(i + 1) * k];
                let mut t = kc;
                while t + 4 <= kc + kk {
                    let bt = brows(b, t, n, jc, nn);
                    (kr.axpy4_1)(c0, bt, [a0[t], a0[t + 1], a0[t + 2], a0[t + 3]]);
                    t += 4;
                }
                while t < kc + kk {
                    let b0 = &b[t * n + jc..t * n + jc + nn];
                    (kr.axpy1_1)(c0, b0, a0[t]);
                    t += 1;
                }
            }
            kc += kk;
        }
        jc += nn;
    }
}

/// `c[M×N] += A[K×M]ᵀ · B[K×N]` with `A` row-major `[K×M]`.
///
/// Used for the weight gradient `gW = dzᵀ·X`: `A` = dz `[batch ×
/// fan_out]`, `B` = layer input `[batch × fan_in]`, `C` = gW
/// `[fan_out × fan_in]`. `A` is read down its columns (stride `m`) —
/// only 16 strided scalar loads per 2×8 tile, so no transposition of dz
/// is worth the pass over memory.
pub fn gemm_tn_acc(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    assert!(a.len() >= k * m, "A is {} floats, want {}x{}", a.len(), k, m);
    assert!(b.len() >= k * n, "B is {} floats, want {}x{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C is {} floats, want {}x{}", c.len(), m, n);
    let kr = simd::kernels();
    let mut jc = 0;
    while jc < n {
        let nn = NC.min(n - jc);
        let mut i = 0;
        while i + 2 <= m {
            let (r0, r1) = c[i * n..(i + 2) * n].split_at_mut(n);
            let c0 = &mut r0[jc..jc + nn];
            let c1 = &mut r1[jc..jc + nn];
            let mut t = 0;
            while t + 8 <= k {
                let bt = brows8(b, t, n, jc, nn);
                let x0 = acol8(a, t, m, i);
                let x1 = acol8(a, t, m, i + 1);
                (kr.axpy8_2)(c0, c1, bt, x0, x1);
                t += 8;
            }
            while t + 4 <= k {
                let bt = brows(b, t, n, jc, nn);
                let x0 = acol4(a, t, m, i);
                let x1 = acol4(a, t, m, i + 1);
                (kr.axpy4_2)(c0, c1, bt, x0, x1);
                t += 4;
            }
            while t < k {
                let b0 = &b[t * n + jc..t * n + jc + nn];
                (kr.axpy1_2)(c0, c1, b0, a[t * m + i], a[t * m + i + 1]);
                t += 1;
            }
            i += 2;
        }
        if i < m {
            let c0 = &mut c[i * n + jc..i * n + jc + nn];
            let mut t = 0;
            while t + 4 <= k {
                let bt = brows(b, t, n, jc, nn);
                (kr.axpy4_1)(c0, bt, acol4(a, t, m, i));
                t += 4;
            }
            while t < k {
                let b0 = &b[t * n + jc..t * n + jc + nn];
                (kr.axpy1_1)(c0, b0, a[t * m + i]);
                t += 1;
            }
        }
        jc += nn;
    }
}

/// `dst[cols×rows] = src[rows×cols]ᵀ`, blocked into 8×8 tiles that run
/// on the dispatched [`simd::Kernels::transpose8`] micro-kernel (an
/// in-register shuffle network under AVX2) with scalar edge strips.
/// Runs once per layer per forward pass (the pre-transposed weight
/// view), so it shares the hot path's dispatch.
pub fn transpose(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    assert!(src.len() >= rows * cols);
    assert!(dst.len() >= rows * cols);
    let kr = simd::kernels();
    let mut rb = 0;
    while rb + 8 <= rows {
        let mut cb = 0;
        while cb + 8 <= cols {
            (kr.transpose8)(&src[rb * cols + cb..], cols, &mut dst[cb * rows + rb..], rows);
            cb += 8;
        }
        for r in rb..rb + 8 {
            let row = &src[r * cols..r * cols + cols];
            for c in cb..cols {
                dst[c * rows + r] = row[c];
            }
        }
        rb += 8;
    }
    for r in rb..rows {
        let row = &src[r * cols..r * cols + cols];
        for c in 0..cols {
            dst[c * rows + r] = row[c];
        }
    }
}

/// Four consecutive values of column `i` of row-major `a[·×m]`.
#[inline(always)]
fn acol4(a: &[f32], t: usize, m: usize, i: usize) -> [f32; 4] {
    [a[t * m + i], a[(t + 1) * m + i], a[(t + 2) * m + i], a[(t + 3) * m + i]]
}

/// Eight consecutive values of column `i` of row-major `a[·×m]`.
#[inline(always)]
fn acol8(a: &[f32], t: usize, m: usize, i: usize) -> [f32; 8] {
    std::array::from_fn(|s| a[(t + s) * m + i])
}

/// Four consecutive B rows, windowed to the current N panel.
#[inline(always)]
fn brows(b: &[f32], t: usize, n: usize, jc: usize, nn: usize) -> [&[f32]; 4] {
    std::array::from_fn(|s| &b[(t + s) * n + jc..(t + s) * n + jc + nn])
}

/// Eight consecutive B rows, windowed to the current N panel.
#[inline(always)]
fn brows8(b: &[f32], t: usize, n: usize, jc: usize, nn: usize) -> [&[f32]; 8] {
    std::array::from_fn(|s| &b[(t + s) * n + jc..(t + s) * n + jc + nn])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for t in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + t] * b[t * n + j];
                }
            }
        }
        c
    }

    fn naive_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for t in 0..k {
            for i in 0..m {
                for j in 0..n {
                    c[i * n + j] += a[t * m + i] * b[t * n + j];
                }
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.next_gaussian()).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], label: &str) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-5 * w.abs().max(1.0);
            assert!((g - w).abs() <= tol, "{label}[{i}]: {g} vs {w}");
        }
    }

    /// Odd, non-multiple-of-tile shapes — exercise every tail path
    /// (including the 8-wide K stage and its 4/1-wide remainders).
    #[test]
    fn gemm_nn_matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(0x6e);
        let shapes = [(1, 1, 1), (2, 4, 8), (3, 5, 7), (5, 13, 11), (7, 130, 515), (32, 784, 128)];
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            gemm_nn_acc(&a, &b, &mut c, m, k, n);
            assert_close(&c, &naive_nn(&a, &b, m, k, n), &format!("nn {m}x{k}x{n}"));
        }
    }

    #[test]
    fn gemm_tn_matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(0x7a);
        for &(k, m, n) in &[(1, 1, 1), (4, 2, 8), (5, 3, 7), (13, 5, 11), (32, 130, 515)] {
            let a = rand_mat(&mut rng, k * m);
            let b = rand_mat(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            gemm_tn_acc(&a, &b, &mut c, k, m, n);
            assert_close(&c, &naive_tn(&a, &b, k, m, n), &format!("tn {k}x{m}x{n}"));
        }
    }

    #[test]
    fn gemm_accumulates_instead_of_overwriting() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 0.0, 0.0, 1.0];
        let mut c = [10.0f32, 20.0, 30.0, 40.0];
        gemm_nn_acc(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let mut rng = Rng::new(0x2e0);
        let (m, k, n) = (6, 9, 17);
        let mut a = rand_mat(&mut rng, m * k);
        // Sparsify like a ReLU-masked gradient.
        for (i, v) in a.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = rand_mat(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        gemm_nn_acc(&a, &b, &mut c, m, k, n);
        assert_close(&c, &naive_nn(&a, &b, m, k, n), "sparse nn");
    }

    #[test]
    fn transpose_round_trips() {
        let mut rng = Rng::new(0x7171);
        for &(r, c) in &[(1, 1), (3, 5), (8, 8), (9, 17), (33, 65), (128, 784)] {
            let src = rand_mat(&mut rng, r * c);
            let mut t = vec![0.0f32; r * c];
            transpose(&src, &mut t, r, c);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t[j * r + i], src[i * c + j], "({i},{j})");
                }
            }
            let mut back = vec![0.0f32; r * c];
            transpose(&t, &mut back, c, r);
            assert_eq!(back, src);
        }
    }
}
