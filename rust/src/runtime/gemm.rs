//! Cache-blocked GEMM drivers — the compute core of the native
//! backend's train/eval hot path.
//!
//! All matrices are row-major `f32` slices. The drivers keep the
//! blocking/tiling strategy of the original engine and delegate the
//! innermost loops to the runtime-dispatched micro-kernels in
//! [`super::simd`] (scalar, AVX2+FMA, or NEON — chosen once at
//! startup):
//!
//! - **axpy form**: the innermost loop updates independent elements of a
//!   C row (`c[j] += x · b[j]`) — vectorizable without
//!   float-reassociation permission on the scalar path, and an FMA
//!   stream on the SIMD paths;
//! - **register tiling**: each micro step updates two C rows from four
//!   rank-1 contributions at once (a 2×4 tile of scalar multipliers held
//!   in registers); where AVX2's sixteen 256-bit registers allow, the K
//!   loop takes eight contributions per step (a 2×8 tile via
//!   `axpy8_2`, one C load/store per 8 K-steps);
//! - **cache blocking**: the N dimension is walked in [`NC`]-wide panels
//!   so the active C rows and streamed B rows stay L1/L2-resident, and
//!   the K dimension in [`KC`]-deep panels so a B panel is reused across
//!   every C row before it is evicted;
//! - **zero skipping**: a micro tile whose multipliers are all zero is
//!   skipped — ReLU-masked gradients are sparse row-wise, so entire
//!   tiles of the backward pass vanish (the scalar 2×8 step preserves
//!   the original per-2×4-half skip granularity).
//!
//! Summation order differs from a naive triple loop (blocking + tile
//! fusion, FMA on the SIMD paths), so results agree with the reference
//! to ~1e-6 relative, not bit-exactly; the golden tests in
//! [`super::native`] pin the contract at 1e-5. Given the same shapes,
//! inputs, and dispatch level the kernels are fully deterministic.
//!
//! **Panel parallelism.** Large products additionally shard their
//! output across the process-wide [`threadpool::PanelPool`]: the public
//! drivers split C into disjoint row panels (plus `NC`-wide column
//! panels for [`gemm_tn_acc`] when the row dimension alone cannot feed
//! the pool) and workers claim panels from a shared counter —
//! allocation-free waitable jobs, `FERRISFL_THREADS` caps the fan-out.
//! Row panels start on even row indices and column panels on `NC`
//! boundaries, so every output element sees *exactly* the serial
//! driver's kernel sequence: the parallel result is **bit-identical**
//! to [`gemm_nn_acc_serial`] / [`gemm_tn_acc_serial`], whatever the
//! pool size (pinned by the tests below). Products under
//! [`PAR_MIN_MACS`] multiply-accumulates stay serial — the dispatch
//! latency would outweigh the panel work. The fused `*_fused` entry
//! points batch several same-shape products (one per co-scheduled
//! agent) into a single panel-job set, so small-model cohorts fill the
//! pool without per-agent dispatch overhead.

use std::cell::Cell;

use super::simd;
use crate::util::threadpool::{self, PanelPool};

/// Width of one N panel (floats). Two C-row tiles of `NC` floats plus
/// four streamed B rows fit comfortably in L1 (6 × 2 KiB = 12 KiB).
const NC: usize = 512;
/// Depth of one K panel: a `KC × NC` B panel is 256 KiB — L2-resident.
/// A multiple of 8 so full panels run entirely on the 2×8 micro step.
const KC: usize = 128;
/// Rows per parallel panel. Even, so panel boundaries never split a
/// 2-row register tile — the pairing (and therefore the bit pattern)
/// matches the serial driver exactly.
const PAR_MR: usize = 4;
/// Minimum multiply-accumulate count (`m·k·n`, summed over fused
/// slots) before a product fans out across the panel pool. 2²² ≈ 4.2M:
/// cnn-m's 3072-wide forward/weight-grad products (25M) parallelise,
/// mlp-m's largest (3.2M) stays serial.
pub const PAR_MIN_MACS: usize = 1 << 22;

thread_local! {
    static FORCE_SERIAL: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with panel-parallel dispatch disabled on this thread — the
/// serial-vs-parallel bench rows and the golden tests A/B the two
/// drivers inside one process with this. Only the calling thread is
/// affected (the auto drivers check the flag at entry, before any
/// fan-out).
pub fn with_serial<R>(f: impl FnOnce() -> R) -> R {
    FORCE_SERIAL.with(|c| {
        let prev = c.replace(true);
        let r = f();
        c.set(prev);
        r
    })
}

/// Whether a product of `macs` multiply-accumulates may fan out.
fn par_allowed(macs: usize) -> bool {
    macs >= PAR_MIN_MACS
        && threadpool::gemm_threads() > 1
        && !FORCE_SERIAL.with(|c| c.get())
}

/// A `*mut f32` the panel closures may share: every panel writes a
/// disjoint region, which the borrow checker cannot see through a
/// `Fn`-closure shared across threads.
struct SendMutF32(*mut f32);
unsafe impl Sync for SendMutF32 {}

/// `c[M×N] += A[M×K] · B[K×N]` (all row-major).
///
/// Used for the forward `X·Wᵀ` pass (with `B` = the pre-transposed
/// weight view, see [`transpose`]) and the backward `dprev = dz·W` pass
/// (where `W` is already `[fan_out × fan_in]` row-major, i.e. exactly
/// the `[K×N]` operand — no transposition needed).
///
/// Large shapes shard M row panels across the process panel pool; the
/// result is bit-identical to [`gemm_nn_acc_serial`] either way.
pub fn gemm_nn_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    if par_allowed(m.saturating_mul(k).saturating_mul(n))
        && gemm_nn_acc_on(threadpool::panel_pool(), a, b, c, m, k, n)
    {
        return;
    }
    gemm_nn_acc_serial(a, b, c, m, k, n)
}

/// Panel-parallel [`gemm_nn_acc`] against an explicit pool: M is split
/// into [`PAR_MR`]-row panels (even boundaries, so the serial 2-row
/// pairing — and the bit pattern — is preserved) claimed by the pool's
/// workers and the calling thread. Returns `false` without touching
/// `c` when another panel job is already in flight; the caller then
/// runs the serial driver.
pub fn gemm_nn_acc_on(
    pool: &PanelPool,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) -> bool {
    assert!(a.len() >= m * k, "A is {} floats, want {}x{}", a.len(), m, k);
    assert!(b.len() >= k * n, "B is {} floats, want {}x{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C is {} floats, want {}x{}", c.len(), m, n);
    let cptr = SendMutF32(c.as_mut_ptr());
    pool.try_run(m.div_ceil(PAR_MR), &|p| {
        let lo = p * PAR_MR;
        let rows = PAR_MR.min(m - lo);
        let ap = &a[lo * k..(lo + rows) * k];
        // SAFETY: panel `p` owns rows [lo, lo+rows) of C — the row
        // ranges of distinct panels are disjoint, and `c` outlives the
        // blocking `try_run` call.
        let cp = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(lo * n), rows * n) };
        gemm_nn_acc_serial(ap, b, cp, rows, k, n);
    })
}

/// The single-thread `c += A·B` driver — the golden reference the
/// parallel path shards (and is pinned bit-identical to).
pub fn gemm_nn_acc_serial(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert!(a.len() >= m * k, "A is {} floats, want {}x{}", a.len(), m, k);
    assert!(b.len() >= k * n, "B is {} floats, want {}x{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C is {} floats, want {}x{}", c.len(), m, n);
    let kr = simd::kernels();
    let mut jc = 0;
    while jc < n {
        let nn = NC.min(n - jc);
        let mut kc = 0;
        while kc < k {
            let kk = KC.min(k - kc);
            // One (kc, jc) panel: every pair of C rows against the panel.
            let mut i = 0;
            while i + 2 <= m {
                let (r0, r1) = c[i * n..(i + 2) * n].split_at_mut(n);
                let c0 = &mut r0[jc..jc + nn];
                let c1 = &mut r1[jc..jc + nn];
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let mut t = kc;
                while t + 8 <= kc + kk {
                    let bt = brows8(b, t, n, jc, nn);
                    let x0: [f32; 8] = a0[t..t + 8].try_into().unwrap();
                    let x1: [f32; 8] = a1[t..t + 8].try_into().unwrap();
                    (kr.axpy8_2)(c0, c1, bt, x0, x1);
                    t += 8;
                }
                while t + 4 <= kc + kk {
                    let bt = brows(b, t, n, jc, nn);
                    let x0 = [a0[t], a0[t + 1], a0[t + 2], a0[t + 3]];
                    let x1 = [a1[t], a1[t + 1], a1[t + 2], a1[t + 3]];
                    (kr.axpy4_2)(c0, c1, bt, x0, x1);
                    t += 4;
                }
                while t < kc + kk {
                    let b0 = &b[t * n + jc..t * n + jc + nn];
                    (kr.axpy1_2)(c0, c1, b0, a0[t], a1[t]);
                    t += 1;
                }
                i += 2;
            }
            if i < m {
                let c0 = &mut c[i * n + jc..i * n + jc + nn];
                let a0 = &a[i * k..(i + 1) * k];
                let mut t = kc;
                while t + 4 <= kc + kk {
                    let bt = brows(b, t, n, jc, nn);
                    (kr.axpy4_1)(c0, bt, [a0[t], a0[t + 1], a0[t + 2], a0[t + 3]]);
                    t += 4;
                }
                while t < kc + kk {
                    let b0 = &b[t * n + jc..t * n + jc + nn];
                    (kr.axpy1_1)(c0, b0, a0[t]);
                    t += 1;
                }
            }
            kc += kk;
        }
        jc += nn;
    }
}

/// `c[M×N] += A[K×M]ᵀ · B[K×N]` with `A` row-major `[K×M]`.
///
/// Used for the weight gradient `gW = dzᵀ·X`: `A` = dz `[batch ×
/// fan_out]`, `B` = layer input `[batch × fan_in]`, `C` = gW
/// `[fan_out × fan_in]`. `A` is read down its columns (stride `m`) —
/// only 16 strided scalar loads per 2×8 tile, so no transposition of dz
/// is worth the pass over memory.
///
/// Large shapes shard M row panels — and, when M alone is too short to
/// feed the pool, `NC`-wide N column panels — across the process panel
/// pool; the result is bit-identical to [`gemm_tn_acc_serial`] either
/// way.
pub fn gemm_tn_acc(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    if par_allowed(k.saturating_mul(m).saturating_mul(n))
        && gemm_tn_acc_on(threadpool::panel_pool(), a, b, c, k, m, n)
    {
        return;
    }
    gemm_tn_acc_serial(a, b, c, k, m, n)
}

/// Panel-parallel [`gemm_tn_acc`] against an explicit pool. M splits
/// into [`PAR_MR`]-row panels; when those alone cannot keep the pool's
/// threads busy (fewer than two per thread), each row panel further
/// splits along N at the serial driver's own `NC` panel boundaries —
/// both cuts preserve the serial kernel sequence per output element,
/// so the result is bit-identical to [`gemm_tn_acc_serial`]. Returns
/// `false` without touching `c` when the pool is busy.
pub fn gemm_tn_acc_on(
    pool: &PanelPool,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
) -> bool {
    assert!(a.len() >= k * m, "A is {} floats, want {}x{}", a.len(), k, m);
    assert!(b.len() >= k * n, "B is {} floats, want {}x{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C is {} floats, want {}x{}", c.len(), m, n);
    if m == 0 || n == 0 {
        return true;
    }
    let kr = simd::kernels();
    let mchunks = m.div_ceil(PAR_MR);
    let nchunks = if mchunks >= 2 * (pool.workers() + 1) {
        1
    } else {
        n.div_ceil(NC)
    };
    let cptr = SendMutF32(c.as_mut_ptr());
    pool.try_run(mchunks * nchunks, &|p| {
        let (ri, ci) = (p / nchunks, p % nchunks);
        let i0 = ri * PAR_MR;
        let rows = PAR_MR.min(m - i0);
        let (jlo, jhi) = if nchunks == 1 {
            (0, n)
        } else {
            (ci * NC, (ci * NC + NC).min(n))
        };
        let mut jc = jlo;
        while jc < jhi {
            let nn = NC.min(jhi - jc);
            // SAFETY: this panel owns the (rows [i0, i0+rows) ×
            // columns [jc, jc+nn)) rectangle of C; rectangles of
            // distinct panels are disjoint, and `c` outlives the
            // blocking `try_run` call.
            unsafe { tn_rect(a, b, cptr.0, k, m, n, i0, rows, jc, nn, kr) };
            jc += nn;
        }
    })
}

/// One (row-range × one-N-panel) rectangle of the TN product, with the
/// exact kernel sequence the serial driver uses for those elements:
/// row pairs from the (even) `i0`, the full-K 8/4/1 stepping, and the
/// same `jc`-anchored panel slices.
///
/// # Safety
/// `c` must point to the full `[M×N]` output with at least `m·n` valid
/// floats, and no other slice or rectangle may alias the
/// `[i0, i0+rows) × [jc, jc+nn)` region for the duration of the call.
#[allow(clippy::too_many_arguments)]
unsafe fn tn_rect(
    a: &[f32],
    b: &[f32],
    c: *mut f32,
    k: usize,
    m: usize,
    n: usize,
    i0: usize,
    rows: usize,
    jc: usize,
    nn: usize,
    kr: &simd::Kernels,
) {
    let mut i = i0;
    let iend = i0 + rows;
    while i + 2 <= iend {
        let c0 = std::slice::from_raw_parts_mut(c.add(i * n + jc), nn);
        let c1 = std::slice::from_raw_parts_mut(c.add((i + 1) * n + jc), nn);
        let mut t = 0;
        while t + 8 <= k {
            let bt = brows8(b, t, n, jc, nn);
            let x0 = acol8(a, t, m, i);
            let x1 = acol8(a, t, m, i + 1);
            (kr.axpy8_2)(c0, c1, bt, x0, x1);
            t += 8;
        }
        while t + 4 <= k {
            let bt = brows(b, t, n, jc, nn);
            let x0 = acol4(a, t, m, i);
            let x1 = acol4(a, t, m, i + 1);
            (kr.axpy4_2)(c0, c1, bt, x0, x1);
            t += 4;
        }
        while t < k {
            let b0 = &b[t * n + jc..t * n + jc + nn];
            (kr.axpy1_2)(c0, c1, b0, a[t * m + i], a[t * m + i + 1]);
            t += 1;
        }
        i += 2;
    }
    if i < iend {
        let c0 = std::slice::from_raw_parts_mut(c.add(i * n + jc), nn);
        let mut t = 0;
        while t + 4 <= k {
            let bt = brows(b, t, n, jc, nn);
            (kr.axpy4_1)(c0, bt, acol4(a, t, m, i));
            t += 4;
        }
        while t < k {
            let b0 = &b[t * n + jc..t * n + jc + nn];
            (kr.axpy1_1)(c0, b0, a[t * m + i]);
            t += 1;
        }
    }
}

/// The single-thread `c += Aᵀ·B` driver — the golden reference the
/// parallel path shards (and is pinned bit-identical to).
pub fn gemm_tn_acc_serial(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    assert!(a.len() >= k * m, "A is {} floats, want {}x{}", a.len(), k, m);
    assert!(b.len() >= k * n, "B is {} floats, want {}x{}", b.len(), k, n);
    assert!(c.len() >= m * n, "C is {} floats, want {}x{}", c.len(), m, n);
    let kr = simd::kernels();
    let mut jc = 0;
    while jc < n {
        let nn = NC.min(n - jc);
        let mut i = 0;
        while i + 2 <= m {
            let (r0, r1) = c[i * n..(i + 2) * n].split_at_mut(n);
            let c0 = &mut r0[jc..jc + nn];
            let c1 = &mut r1[jc..jc + nn];
            let mut t = 0;
            while t + 8 <= k {
                let bt = brows8(b, t, n, jc, nn);
                let x0 = acol8(a, t, m, i);
                let x1 = acol8(a, t, m, i + 1);
                (kr.axpy8_2)(c0, c1, bt, x0, x1);
                t += 8;
            }
            while t + 4 <= k {
                let bt = brows(b, t, n, jc, nn);
                let x0 = acol4(a, t, m, i);
                let x1 = acol4(a, t, m, i + 1);
                (kr.axpy4_2)(c0, c1, bt, x0, x1);
                t += 4;
            }
            while t < k {
                let b0 = &b[t * n + jc..t * n + jc + nn];
                (kr.axpy1_2)(c0, c1, b0, a[t * m + i], a[t * m + i + 1]);
                t += 1;
            }
            i += 2;
        }
        if i < m {
            let c0 = &mut c[i * n + jc..i * n + jc + nn];
            let mut t = 0;
            while t + 4 <= k {
                let bt = brows(b, t, n, jc, nn);
                (kr.axpy4_1)(c0, bt, acol4(a, t, m, i));
                t += 4;
            }
            while t < k {
                let b0 = &b[t * n + jc..t * n + jc + nn];
                (kr.axpy1_1)(c0, b0, a[t * m + i]);
                t += 1;
            }
        }
        jc += nn;
    }
}

/// One slot of a fused multi-agent GEMM: raw operand pointers into
/// caller-owned buffers, all slots sharing one `(m, k, n)` shape. The
/// fused drivers schedule every slot's panels as **one** pool job set,
/// so a cohort of small same-shape products fills the pool with a
/// single dispatch instead of one per agent. Tables of these live in
/// `StepScratch` (grow-only, rebuilt each call — the pointers are only
/// valid inside the call that built them).
#[derive(Clone, Copy)]
pub struct GemmSlot {
    pub a: *const f32,
    pub b: *const f32,
    pub c: *mut f32,
}

// SAFETY: the pointers are only dereferenced inside a fused driver
// call, whose caller guarantees the referents outlive the call and the
// `c` regions are pairwise disjoint (see the drivers' safety docs).
unsafe impl Send for GemmSlot {}
unsafe impl Sync for GemmSlot {}

/// Fused [`gemm_nn_acc`] over several same-shape slots: per slot
/// `c += A·B`, with every slot's row panels claimed from one pool job
/// set (or a serial per-slot loop when the pool is busy, the total
/// work is small, or parallelism is off). Per-slot results are
/// bit-identical to [`gemm_nn_acc_serial`] on that slot.
///
/// # Safety
/// For every slot: `a` must be valid for `m·k` reads, `b` for `k·n`
/// reads, and `c` for `m·n` reads+writes, all for the duration of the
/// call; the slots' `c` regions must be pairwise disjoint and not
/// otherwise aliased.
pub unsafe fn gemm_nn_acc_fused(slots: &[GemmSlot], m: usize, k: usize, n: usize) {
    if slots.is_empty() || m == 0 {
        return;
    }
    let macs = m.saturating_mul(k).saturating_mul(n).saturating_mul(slots.len());
    if par_allowed(macs) {
        let mchunks = m.div_ceil(PAR_MR);
        let ok = threadpool::panel_pool().try_run(slots.len() * mchunks, &|p| {
            let slot = slots[p / mchunks];
            let lo = (p % mchunks) * PAR_MR;
            let rows = PAR_MR.min(m - lo);
            // SAFETY: panel `p` owns rows [lo, lo+rows) of its slot's
            // C; with the caller's disjointness guarantee no two
            // panels overlap.
            let (ap, bp, cp) = unsafe {
                (
                    std::slice::from_raw_parts(slot.a.add(lo * k), rows * k),
                    std::slice::from_raw_parts(slot.b, k * n),
                    std::slice::from_raw_parts_mut(slot.c.add(lo * n), rows * n),
                )
            };
            gemm_nn_acc_serial(ap, bp, cp, rows, k, n);
        });
        if ok {
            return;
        }
    }
    for slot in slots {
        let ap = std::slice::from_raw_parts(slot.a, m * k);
        let bp = std::slice::from_raw_parts(slot.b, k * n);
        let cp = std::slice::from_raw_parts_mut(slot.c, m * n);
        gemm_nn_acc_serial(ap, bp, cp, m, k, n);
    }
}

/// Fused [`gemm_tn_acc`] over several same-shape slots: per slot
/// `c += Aᵀ·B`, sharded like [`gemm_tn_acc_on`] (row panels, plus `NC`
/// column panels when the cohort's rows alone cannot feed the pool)
/// with every slot in one pool job set. Per-slot results are
/// bit-identical to [`gemm_tn_acc_serial`] on that slot.
///
/// # Safety
/// For every slot: `a` must be valid for `k·m` reads, `b` for `k·n`
/// reads, and `c` for `m·n` reads+writes, all for the duration of the
/// call; the slots' `c` regions must be pairwise disjoint and not
/// otherwise aliased.
pub unsafe fn gemm_tn_acc_fused(slots: &[GemmSlot], k: usize, m: usize, n: usize) {
    if slots.is_empty() || m == 0 || n == 0 {
        return;
    }
    let macs = k.saturating_mul(m).saturating_mul(n).saturating_mul(slots.len());
    if par_allowed(macs) {
        let kr = simd::kernels();
        let pool = threadpool::panel_pool();
        let mchunks = m.div_ceil(PAR_MR);
        let nchunks = if slots.len() * mchunks >= 2 * (pool.workers() + 1) {
            1
        } else {
            n.div_ceil(NC)
        };
        let per_slot = mchunks * nchunks;
        let ok = pool.try_run(slots.len() * per_slot, &|p| {
            let slot = slots[p / per_slot];
            let r = p % per_slot;
            let (ri, ci) = (r / nchunks, r % nchunks);
            let i0 = ri * PAR_MR;
            let rows = PAR_MR.min(m - i0);
            let (jlo, jhi) = if nchunks == 1 {
                (0, n)
            } else {
                (ci * NC, (ci * NC + NC).min(n))
            };
            // SAFETY: panel `p` owns this rectangle of its slot's C;
            // with the caller's disjointness guarantee no two panels
            // overlap, and `a`/`b` are valid shared reads.
            unsafe {
                let ap = std::slice::from_raw_parts(slot.a, k * m);
                let bp = std::slice::from_raw_parts(slot.b, k * n);
                let mut jc = jlo;
                while jc < jhi {
                    let nn = NC.min(jhi - jc);
                    tn_rect(ap, bp, slot.c, k, m, n, i0, rows, jc, nn, kr);
                    jc += nn;
                }
            }
        });
        if ok {
            return;
        }
    }
    for slot in slots {
        let ap = std::slice::from_raw_parts(slot.a, k * m);
        let bp = std::slice::from_raw_parts(slot.b, k * n);
        let cp = std::slice::from_raw_parts_mut(slot.c, m * n);
        gemm_tn_acc_serial(ap, bp, cp, k, m, n);
    }
}

/// `dst[cols×rows] = src[rows×cols]ᵀ`, blocked into 8×8 tiles that run
/// on the dispatched [`simd::Kernels::transpose8`] micro-kernel (an
/// in-register shuffle network under AVX2) with scalar edge strips.
/// Runs once per layer per forward pass (the pre-transposed weight
/// view), so it shares the hot path's dispatch.
pub fn transpose(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    assert!(src.len() >= rows * cols);
    assert!(dst.len() >= rows * cols);
    let kr = simd::kernels();
    let mut rb = 0;
    while rb + 8 <= rows {
        let mut cb = 0;
        while cb + 8 <= cols {
            (kr.transpose8)(&src[rb * cols + cb..], cols, &mut dst[cb * rows + rb..], rows);
            cb += 8;
        }
        for r in rb..rb + 8 {
            let row = &src[r * cols..r * cols + cols];
            for c in cb..cols {
                dst[c * rows + r] = row[c];
            }
        }
        rb += 8;
    }
    for r in rb..rows {
        let row = &src[r * cols..r * cols + cols];
        for c in 0..cols {
            dst[c * rows + r] = row[c];
        }
    }
}

/// Four consecutive values of column `i` of row-major `a[·×m]`.
#[inline(always)]
fn acol4(a: &[f32], t: usize, m: usize, i: usize) -> [f32; 4] {
    [a[t * m + i], a[(t + 1) * m + i], a[(t + 2) * m + i], a[(t + 3) * m + i]]
}

/// Eight consecutive values of column `i` of row-major `a[·×m]`.
#[inline(always)]
fn acol8(a: &[f32], t: usize, m: usize, i: usize) -> [f32; 8] {
    std::array::from_fn(|s| a[(t + s) * m + i])
}

/// Four consecutive B rows, windowed to the current N panel.
#[inline(always)]
fn brows(b: &[f32], t: usize, n: usize, jc: usize, nn: usize) -> [&[f32]; 4] {
    std::array::from_fn(|s| &b[(t + s) * n + jc..(t + s) * n + jc + nn])
}

/// Eight consecutive B rows, windowed to the current N panel.
#[inline(always)]
fn brows8(b: &[f32], t: usize, n: usize, jc: usize, nn: usize) -> [&[f32]; 8] {
    std::array::from_fn(|s| &b[(t + s) * n + jc..(t + s) * n + jc + nn])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for t in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + t] * b[t * n + j];
                }
            }
        }
        c
    }

    fn naive_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for t in 0..k {
            for i in 0..m {
                for j in 0..n {
                    c[i * n + j] += a[t * m + i] * b[t * n + j];
                }
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.next_gaussian()).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], label: &str) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-5 * w.abs().max(1.0);
            assert!((g - w).abs() <= tol, "{label}[{i}]: {g} vs {w}");
        }
    }

    /// Odd, non-multiple-of-tile shapes — exercise every tail path
    /// (including the 8-wide K stage and its 4/1-wide remainders).
    #[test]
    fn gemm_nn_matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(0x6e);
        let shapes = [(1, 1, 1), (2, 4, 8), (3, 5, 7), (5, 13, 11), (7, 130, 515), (32, 784, 128)];
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            gemm_nn_acc(&a, &b, &mut c, m, k, n);
            assert_close(&c, &naive_nn(&a, &b, m, k, n), &format!("nn {m}x{k}x{n}"));
        }
    }

    #[test]
    fn gemm_tn_matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(0x7a);
        for &(k, m, n) in &[(1, 1, 1), (4, 2, 8), (5, 3, 7), (13, 5, 11), (32, 130, 515)] {
            let a = rand_mat(&mut rng, k * m);
            let b = rand_mat(&mut rng, k * n);
            let mut c = vec![0.0f32; m * n];
            gemm_tn_acc(&a, &b, &mut c, k, m, n);
            assert_close(&c, &naive_tn(&a, &b, k, m, n), &format!("tn {k}x{m}x{n}"));
        }
    }

    #[test]
    fn gemm_accumulates_instead_of_overwriting() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 0.0, 0.0, 1.0];
        let mut c = [10.0f32, 20.0, 30.0, 40.0];
        gemm_nn_acc(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn zero_rows_are_skipped_without_changing_results() {
        let mut rng = Rng::new(0x2e0);
        let (m, k, n) = (6, 9, 17);
        let mut a = rand_mat(&mut rng, m * k);
        // Sparsify like a ReLU-masked gradient.
        for (i, v) in a.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let b = rand_mat(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        gemm_nn_acc(&a, &b, &mut c, m, k, n);
        assert_close(&c, &naive_nn(&a, &b, m, k, n), "sparse nn");
    }

    /// Every zoo-relevant shape (plus odd non-tile-multiples), every
    /// pool size including the 1-thread degenerate pool: the
    /// panel-parallel NN driver is **bit-identical** to the serial one
    /// (row panels start on even rows, so the 2-row pairing and the
    /// kernel sequence per element never change).
    #[test]
    fn panel_parallel_nn_is_bit_identical_to_serial() {
        let mut rng = Rng::new(0x9a11);
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (5, 13, 11),
            (7, 130, 515),
            (32, 784, 16),
            (32, 784, 64),
            (32, 784, 128),
            (32, 256, 128),
            (32, 3072, 256),
            (33, 100, 600),
        ];
        for workers in [0usize, 1, 3] {
            let pool = PanelPool::new(workers);
            for &(m, k, n) in &shapes {
                let a = rand_mat(&mut rng, m * k);
                let b = rand_mat(&mut rng, k * n);
                let base = rand_mat(&mut rng, m * n);
                let mut serial = base.clone();
                gemm_nn_acc_serial(&a, &b, &mut serial, m, k, n);
                let mut par = base.clone();
                assert!(gemm_nn_acc_on(&pool, &a, &b, &mut par, m, k, n));
                assert!(
                    par.iter().zip(&serial).all(|(p, s)| p.to_bits() == s.to_bits()),
                    "nn {m}x{k}x{n} workers={workers}"
                );
            }
        }
    }

    /// Same pin for the TN driver, including shapes short enough in M
    /// to engage the NC column split.
    #[test]
    fn panel_parallel_tn_is_bit_identical_to_serial() {
        let mut rng = Rng::new(0x7b17);
        let shapes = [
            (1usize, 1usize, 1usize),
            (4, 2, 8),
            (13, 5, 11),
            (32, 130, 515),
            (32, 256, 3072),
            (32, 10, 784),
            (32, 3, 1200), // mchunks=1: always column-split on any pool
            (32, 120, 784),
        ];
        for workers in [0usize, 1, 3] {
            let pool = PanelPool::new(workers);
            for &(k, m, n) in &shapes {
                let a = rand_mat(&mut rng, k * m);
                let b = rand_mat(&mut rng, k * n);
                let base = rand_mat(&mut rng, m * n);
                let mut serial = base.clone();
                gemm_tn_acc_serial(&a, &b, &mut serial, k, m, n);
                let mut par = base.clone();
                assert!(gemm_tn_acc_on(&pool, &a, &b, &mut par, k, m, n));
                assert!(
                    par.iter().zip(&serial).all(|(p, s)| p.to_bits() == s.to_bits()),
                    "tn {k}x{m}x{n} workers={workers}"
                );
            }
        }
    }

    /// The auto drivers (threshold + process pool + `with_serial`
    /// override) agree bit-for-bit with the serial reference on the
    /// largest zoo shape — whichever path they actually took.
    #[test]
    fn auto_dispatch_is_bit_identical_to_serial_on_large_shapes() {
        let mut rng = Rng::new(0xA070);
        let (m, k, n) = (32usize, 3072usize, 256usize);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let mut serial = vec![0.0f32; m * n];
        gemm_nn_acc_serial(&a, &b, &mut serial, m, k, n);
        let mut auto = vec![0.0f32; m * n];
        gemm_nn_acc(&a, &b, &mut auto, m, k, n);
        assert!(auto.iter().zip(&serial).all(|(p, s)| p.to_bits() == s.to_bits()));
        let mut forced = vec![0.0f32; m * n];
        with_serial(|| gemm_nn_acc(&a, &b, &mut forced, m, k, n));
        assert_eq!(forced, serial);

        let at = rand_mat(&mut rng, 32 * 256);
        let bt = rand_mat(&mut rng, 32 * 3072);
        let mut serial_t = vec![0.0f32; 256 * 3072];
        gemm_tn_acc_serial(&at, &bt, &mut serial_t, 32, 256, 3072);
        let mut auto_t = vec![0.0f32; 256 * 3072];
        gemm_tn_acc(&at, &bt, &mut auto_t, 32, 256, 3072);
        assert!(auto_t.iter().zip(&serial_t).all(|(p, s)| p.to_bits() == s.to_bits()));
    }

    /// Fused multi-slot drivers: per-slot results are bit-identical to
    /// the serial driver run on that slot alone.
    #[test]
    fn fused_slots_match_per_slot_serial() {
        let mut rng = Rng::new(0xF0Fa);
        let slots_n = 3usize;
        for &(m, k, n) in &[(5usize, 9usize, 17usize), (32, 784, 64), (32, 100, 600)] {
            let a: Vec<Vec<f32>> = (0..slots_n).map(|_| rand_mat(&mut rng, m * k)).collect();
            let b: Vec<Vec<f32>> = (0..slots_n).map(|_| rand_mat(&mut rng, k * n)).collect();
            let base: Vec<Vec<f32>> = (0..slots_n).map(|_| rand_mat(&mut rng, m * n)).collect();
            let mut serial = base.clone();
            for s in 0..slots_n {
                gemm_nn_acc_serial(&a[s], &b[s], &mut serial[s], m, k, n);
            }
            let mut fused = base.clone();
            let table: Vec<GemmSlot> = (0..slots_n)
                .map(|s| GemmSlot {
                    a: a[s].as_ptr(),
                    b: b[s].as_ptr(),
                    c: fused[s].as_mut_ptr(),
                })
                .collect();
            // SAFETY: distinct Vec allocations per slot; the table does
            // not outlive them.
            unsafe { gemm_nn_acc_fused(&table, m, k, n) };
            for s in 0..slots_n {
                assert!(
                    fused[s].iter().zip(&serial[s]).all(|(f, w)| f.to_bits() == w.to_bits()),
                    "fused nn slot {s} {m}x{k}x{n}"
                );
            }
        }
        for &(k, m, n) in &[(4usize, 2usize, 8usize), (32, 64, 784), (32, 10, 784)] {
            let a: Vec<Vec<f32>> = (0..slots_n).map(|_| rand_mat(&mut rng, k * m)).collect();
            let b: Vec<Vec<f32>> = (0..slots_n).map(|_| rand_mat(&mut rng, k * n)).collect();
            let base: Vec<Vec<f32>> = (0..slots_n).map(|_| rand_mat(&mut rng, m * n)).collect();
            let mut serial = base.clone();
            for s in 0..slots_n {
                gemm_tn_acc_serial(&a[s], &b[s], &mut serial[s], k, m, n);
            }
            let mut fused = base.clone();
            let table: Vec<GemmSlot> = (0..slots_n)
                .map(|s| GemmSlot {
                    a: a[s].as_ptr(),
                    b: b[s].as_ptr(),
                    c: fused[s].as_mut_ptr(),
                })
                .collect();
            // SAFETY: as above.
            unsafe { gemm_tn_acc_fused(&table, k, m, n) };
            for s in 0..slots_n {
                assert!(
                    fused[s].iter().zip(&serial[s]).all(|(f, w)| f.to_bits() == w.to_bits()),
                    "fused tn slot {s} {k}x{m}x{n}"
                );
            }
        }
    }

    #[test]
    fn transpose_round_trips() {
        let mut rng = Rng::new(0x7171);
        for &(r, c) in &[(1, 1), (3, 5), (8, 8), (9, 17), (33, 65), (128, 784)] {
            let src = rand_mat(&mut rng, r * c);
            let mut t = vec![0.0f32; r * c];
            transpose(&src, &mut t, r, c);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t[j * r + i], src[i * c + j], "({i},{j})");
                }
            }
            let mut back = vec![0.0f32; r * c];
            transpose(&t, &mut back, c, r);
            assert_eq!(back, src);
        }
    }
}
