//! The pre-blocking naive MLP engine, retained verbatim as the golden
//! reference.
//!
//! This is the per-example dot-product implementation the native backend
//! shipped with before the blocked-GEMM rewrite: fresh buffers for
//! activations, `dz`, `dprev`, the gradient, and logits on every call,
//! scalar inner loops, no tiling. It is deliberately **not** on any hot
//! path — it exists so that
//!
//! 1. the golden tests can pin the blocked kernels to it within 1e-5
//!    across every zoo shape, and
//! 2. `cargo bench --bench train_step_latency` can measure the blocked
//!    engine against the true pre-change baseline *in the same run* (the
//!    `naive_vs_blocked` section of `BENCH_native.json`).

use super::backend::StepStats;

/// A naive MLP forward/backward engine over the flat parameter layout
/// (`W_l` row-major `[o × i]` then `b_l [o]`, classifier head last).
pub struct NaiveMlp {
    /// (fan_in, fan_out) per layer; last layer is the classifier head.
    dims: Vec<(usize, usize)>,
    classes: usize,
    num_params: usize,
}

impl NaiveMlp {
    pub fn new(input_dim: usize, hidden: &[usize], classes: usize) -> Self {
        let mut dims = Vec::with_capacity(hidden.len() + 1);
        let mut fan_in = input_dim;
        for &h in hidden {
            dims.push((fan_in, h));
            fan_in = h;
        }
        dims.push((fan_in, classes));
        let num_params = dims.iter().map(|&(i, o)| (i + 1) * o).sum();
        Self { dims, classes, num_params }
    }

    pub fn num_params(&self) -> usize {
        self.num_params
    }

    /// Forward pass over `n` examples. Returns hidden post-relu
    /// activations (one buffer per hidden layer) plus the logits.
    pub fn forward(&self, params: &[f32], x: &[f32], n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.dims.len() - 1);
        let mut offset = 0usize;
        let mut logits = Vec::new();
        for (l, &(fan_in, fan_out)) in self.dims.iter().enumerate() {
            let w = &params[offset..offset + fan_out * fan_in];
            let b = &params[offset + fan_out * fan_in..offset + fan_out * (fan_in + 1)];
            offset += fan_out * (fan_in + 1);
            let last = l + 1 == self.dims.len();
            let mut out = vec![0.0f32; n * fan_out];
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            for i in 0..n {
                let xi = &input[i * fan_in..(i + 1) * fan_in];
                let zi = &mut out[i * fan_out..(i + 1) * fan_out];
                for (o, z) in zi.iter_mut().enumerate() {
                    let row = &w[o * fan_in..(o + 1) * fan_in];
                    let mut acc = b[o];
                    for (rw, rx) in row.iter().zip(xi) {
                        acc += rw * rx;
                    }
                    *z = if last { acc } else { acc.max(0.0) };
                }
            }
            if last {
                logits = out;
            } else {
                acts.push(out);
            }
        }
        (acts, logits)
    }

    /// Softmax cross-entropy over `n` logits rows: per-example loss and
    /// correctness, plus (optionally) `dz = (softmax - onehot) * scale`.
    pub fn softmax_xent(
        &self,
        logits: &[f32],
        y: &[i32],
        n: usize,
        dz_scale: Option<f32>,
    ) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let c = self.classes;
        let mut losses = vec![0.0f32; n];
        let mut correct = vec![false; n];
        let mut dz = if dz_scale.is_some() {
            vec![0.0f32; n * c]
        } else {
            Vec::new()
        };
        for i in 0..n {
            let z = &logits[i * c..(i + 1) * c];
            let mut max = f32::NEG_INFINITY;
            let mut argmax = 0usize;
            for (j, &v) in z.iter().enumerate() {
                if v > max {
                    max = v;
                    argmax = j;
                }
            }
            let mut sum = 0.0f32;
            for &v in z {
                sum += (v - max).exp();
            }
            let lse = max + sum.ln();
            let label = y[i] as usize;
            losses[i] = lse - z[label];
            correct[i] = argmax == label;
            if let Some(scale) = dz_scale {
                let d = &mut dz[i * c..(i + 1) * c];
                for (j, &v) in z.iter().enumerate() {
                    d[j] = ((v - lse).exp() - if j == label { 1.0 } else { 0.0 }) * scale;
                }
            }
        }
        (losses, correct, dz)
    }

    /// Backward pass: gradient of the mean batch loss wrt `params`.
    /// Under featext only the final (head) layer's gradient is produced;
    /// frozen entries stay zero.
    pub fn backward(
        &self,
        params: &[f32],
        x: &[f32],
        acts: &[Vec<f32>],
        dz_last: Vec<f32>,
        n: usize,
        featext: bool,
    ) -> Vec<f32> {
        let mut grad = vec![0.0f32; self.num_params];
        let mut offsets = Vec::with_capacity(self.dims.len());
        let mut off = 0usize;
        for &(fan_in, fan_out) in &self.dims {
            offsets.push(off);
            off += fan_out * (fan_in + 1);
        }
        let mut dz = dz_last;
        for l in (0..self.dims.len()).rev() {
            let (fan_in, fan_out) = self.dims[l];
            let off = offsets[l];
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            {
                let (gw, gb) =
                    grad[off..off + fan_out * (fan_in + 1)].split_at_mut(fan_out * fan_in);
                for i in 0..n {
                    let xi = &input[i * fan_in..(i + 1) * fan_in];
                    let di = &dz[i * fan_out..(i + 1) * fan_out];
                    for (o, &d) in di.iter().enumerate() {
                        if d != 0.0 {
                            let row = &mut gw[o * fan_in..(o + 1) * fan_in];
                            for (g, &v) in row.iter_mut().zip(xi) {
                                *g += d * v;
                            }
                        }
                        gb[o] += d;
                    }
                }
            }
            if l == 0 || (featext && l + 1 == self.dims.len()) {
                break;
            }
            let w = &params[off..off + fan_out * fan_in];
            let prev = &acts[l - 1];
            let mut dprev = vec![0.0f32; n * fan_in];
            for i in 0..n {
                let di = &dz[i * fan_out..(i + 1) * fan_out];
                let dpi = &mut dprev[i * fan_in..(i + 1) * fan_in];
                for (o, &d) in di.iter().enumerate() {
                    if d != 0.0 {
                        let row = &w[o * fan_in..(o + 1) * fan_in];
                        for (dp, &rw) in dpi.iter_mut().zip(row) {
                            *dp += d * rw;
                        }
                    }
                }
                let ai = &prev[i * fan_in..(i + 1) * fan_in];
                for (dp, &a) in dpi.iter_mut().zip(ai) {
                    if a <= 0.0 {
                        *dp = 0.0;
                    }
                }
            }
            dz = dprev;
        }
        grad
    }

    /// Forward + loss + backward: the batch gradient and step stats.
    pub fn batch_grad(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        n: usize,
        featext: bool,
    ) -> (Vec<f32>, StepStats) {
        let (acts, logits) = self.forward(params, x, n);
        let (losses, correct, dz) = self.softmax_xent(&logits, y, n, Some(1.0 / n as f32));
        let grad = self.backward(params, x, &acts, dz, n, featext);
        (
            grad,
            StepStats {
                loss: losses.iter().sum::<f32>() / n as f32,
                hits: correct.iter().filter(|&&c| c).count() as f32,
            },
        )
    }

    /// One naive full-allocation SGD step (the pre-change hot path).
    pub fn sgd_step(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        n: usize,
        lr: f32,
    ) -> StepStats {
        let (grad, stats) = self.batch_grad(params, x, y, n, false);
        for (p, g) in params.iter_mut().zip(&grad) {
            *p -= lr * g;
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_param_count_matches_layout() {
        let m = NaiveMlp::new(784, &[16], 10);
        assert_eq!(m.num_params(), (784 + 1) * 16 + (16 + 1) * 10);
    }

    #[test]
    fn naive_step_reduces_loss_on_fixed_batch() {
        let mut rng = crate::util::Rng::new(0x9a1);
        let m = NaiveMlp::new(12, &[8], 3);
        let n = 4;
        let mut params: Vec<f32> =
            (0..m.num_params()).map(|_| rng.next_gaussian() * 0.2).collect();
        let x: Vec<f32> = (0..n * 12).map(|_| rng.next_gaussian()).collect();
        let y = vec![0i32, 1, 2, 1];
        let first = m.sgd_step(&mut params, &x, &y, n, 0.1);
        let mut last = first;
        for _ in 0..40 {
            last = m.sgd_step(&mut params, &x, &y, n, 0.1);
        }
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
    }
}
