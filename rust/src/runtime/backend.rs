//! The execution-backend abstraction (the L3↔runtime contract).
//!
//! Every consumer of the runtime — the FL entrypoint, the central
//! trainer, the repro harness, benches — programs against
//! [`ModelExecutor`], which covers the five runtime operations:
//!
//! 1. model/artifact loading ([`ModelExecutor::init_params`] /
//!    [`ModelExecutor::pretrained_params`]),
//! 2. one SGD train step,
//! 3. one Adam train step,
//! 4. masked batch evaluation,
//! 5. weighted-delta FedAvg aggregation.
//!
//! Two backends implement it:
//!
//! - [`BackendKind::Native`] — `runtime::native`, a pure-rust MLP
//!   forward/backward engine. Needs no Python, XLA, or AOT artifacts;
//!   the default, and the only backend in a default-features build.
//! - [`BackendKind::Pjrt`] — `runtime::pjrt`, the original PJRT/XLA
//!   path over AOT-lowered HLO (the Pallas-kernel artifacts). Gated
//!   behind the optional `pjrt` cargo feature.

use crate::util::error::{bail, Result};

use super::gemm;
use super::stats;

/// Which execution backend drives the five runtime operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Pure-rust CPU backend (default; hermetic).
    Native,
    /// PJRT/XLA over AOT artifacts (requires the `pjrt` feature).
    Pjrt,
}

impl BackendKind {
    /// Parse a config/CLI name: `native` or `pjrt`.
    pub fn parse(text: &str) -> Result<Self> {
        match text.trim().to_ascii_lowercase().as_str() {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (native | pjrt)"),
        }
    }

    /// Canonical config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        BackendKind::parse(s)
    }
}

/// Result of one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    /// Mean cross-entropy loss over the batch.
    pub loss: f32,
    /// Number of correct predictions in the batch (a count, not a rate).
    pub hits: f32,
}

/// Aggregate eval result over a full test set.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalStats {
    pub loss_sum: f64,
    pub correct: f64,
    pub count: f64,
}

impl EvalStats {
    /// Fold another batch/shard's stats into this total.
    pub fn merge(&mut self, other: &EvalStats) {
        self.loss_sum += other.loss_sum;
        self.correct += other.correct;
        self.count += other.count;
    }

    pub fn mean_loss(&self) -> f64 {
        if self.count > 0.0 {
            self.loss_sum / self.count
        } else {
            f64::NAN
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.count > 0.0 {
            self.correct / self.count
        } else {
            f64::NAN
        }
    }
}

/// Reusable scratch arenas for the train/eval hot path.
///
/// Every buffer the step path needs — activations, `dz`, `dprev`, the
/// gradient, logits, per-example losses, the transposed-weight view, and
/// (under PJRT) the eval padding buffers — lives here instead of being
/// allocated per call. Buffers grow on first use and are never shrunk,
/// so a training loop that holds one `StepScratch` performs **zero heap
/// allocations** per step once warm (asserted by `tests/zero_alloc.rs`).
///
/// Callers create one via [`ModelExecutor::new_scratch`], keep it for
/// the lifetime of their loop, and pass it to every
/// [`ModelExecutor::train_step_sgd`] / [`ModelExecutor::train_step_adam`]
/// / [`ModelExecutor::eval_batch`] call. A scratch may be reused across
/// executors: each step re-derives its layout, growing buffers as
/// needed. Reuse never changes results — steps are bit-identical with a
/// fresh or a reused arena.
#[derive(Default)]
pub struct StepScratch {
    /// Hidden post-relu activations, all layers concatenated.
    pub(crate) acts: Vec<f32>,
    /// Final-layer logits (`n × classes`).
    pub(crate) logits: Vec<f32>,
    /// Upstream gradient of the layer being processed (`n × width`).
    pub(crate) dz: Vec<f32>,
    /// Downstream gradient ping-pong buffer (`n × width`).
    pub(crate) dprev: Vec<f32>,
    /// Flat parameter gradient (`num_params`).
    pub(crate) grad: Vec<f32>,
    /// Per-example losses (`n`).
    pub(crate) losses: Vec<f32>,
    /// Transposed weight view of the current layer (`fan_in × fan_out`).
    pub(crate) wt: Vec<f32>,
    /// Per-slot GEMM operand table of the fused multi-agent step path
    /// (grow-only capacity; the raw pointers inside are rebuilt for —
    /// and only valid within — each fused GEMM call).
    pub(crate) fused_ptrs: Vec<gemm::GemmSlot>,
    /// PJRT eval-batch padding buffers.
    #[cfg(feature = "pjrt")]
    pub(crate) xpad: Vec<f32>,
    #[cfg(feature = "pjrt")]
    pub(crate) ypad: Vec<i32>,
    #[cfg(feature = "pjrt")]
    pub(crate) mask: Vec<f32>,
}

impl StepScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow `v` to at least `len` entries, charging real growth to the
    /// runtime allocation counters. Steady-state steps grow nothing, so
    /// `stats::add_allocated` stays flat once the loop is warm.
    pub(crate) fn grow_f32(v: &mut Vec<f32>, len: usize) {
        if v.len() < len {
            stats::add_allocated(((len - v.len()) * std::mem::size_of::<f32>()) as u64);
            v.resize(len, 0.0);
        }
    }

    #[cfg(feature = "pjrt")]
    pub(crate) fn grow_i32(v: &mut Vec<i32>, len: usize) {
        if v.len() < len {
            stats::add_allocated(((len - v.len()) * std::mem::size_of::<i32>()) as u64);
            v.resize(len, 0);
        }
    }
}

/// One agent's view of a fused lockstep SGD step: its own parameters
/// and gathered batch. All slots of one
/// [`ModelExecutor::train_step_sgd_fused`] call must come from
/// executors of the same model shape (in practice: the same executor).
pub struct FusedSlot<'a> {
    pub params: &'a mut Vec<f32>,
    pub x: &'a [f32],
    pub y: &'a [i32],
}

/// Adam optimizer state held by the coordinator between local epochs.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
}

impl AdamState {
    pub fn zeros(p: usize) -> Self {
        Self {
            m: vec![0.0; p],
            v: vec![0.0; p],
            t: 0.0,
        }
    }
}

/// Everything needed to train/eval/aggregate one model@dataset on one
/// device, behind a uniform interface (see module docs for the op list).
///
/// Executors are created per worker thread by `entrypoint::worker` and
/// cached there — the PJRT implementation is `Rc`-based and must not
/// cross threads, so the trait is deliberately not `Send`.
pub trait ModelExecutor {
    /// Which backend this executor runs on.
    fn backend(&self) -> BackendKind;

    /// Total flat parameter count P.
    fn num_params(&self) -> usize;

    /// Parameters in the classifier head (the featext-trainable tail).
    fn head_size(&self) -> usize;

    /// Fixed train batch size B.
    fn train_batch_size(&self) -> usize;

    /// Fixed (maximum) eval batch size.
    fn eval_batch_size(&self) -> usize;

    /// Local optimizer this executor was built for ("sgd" | "adam").
    fn optimizer(&self) -> &str;

    /// Fresh initial parameters (op 5: model loading). Deterministic per
    /// (model, dataset) so every agent starts from the same W^0.
    fn init_params(&self) -> Result<Vec<f32>>;

    /// Pretrained parameters for finetune/featext starts.
    fn pretrained_params(&self) -> Result<Vec<f32>>;

    /// A scratch arena for this executor's step path. Hold one per
    /// training/eval loop and pass it to every step — steady-state
    /// steps then allocate nothing.
    fn new_scratch(&self) -> StepScratch {
        StepScratch::new()
    }

    /// One SGD train step. `params` is updated in place.
    fn train_step_sgd(
        &self,
        params: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        scratch: &mut StepScratch,
    ) -> Result<StepStats>;

    /// One Adam train step. `params` and `state` update in place.
    fn train_step_adam(
        &self,
        params: &mut Vec<f32>,
        state: &mut AdamState,
        x: &[f32],
        y: &[i32],
        lr: f32,
        scratch: &mut StepScratch,
    ) -> Result<StepStats>;

    /// One SGD train step for several same-shape agents in lockstep —
    /// the fused multi-agent batching path. Semantically one
    /// [`ModelExecutor::train_step_sgd`] per slot (the golden contract
    /// pins per-slot results within 1e-5 of the serial steps; the
    /// native backend is bit-identical), but backends may override it
    /// to batch the slots' compute — the native engine runs one fused
    /// panel-parallel GEMM per layer across the whole cohort. `stats`
    /// is cleared and refilled with one entry per slot (capacity is
    /// reused, so warm fused steps stay allocation-free).
    fn train_step_sgd_fused(
        &self,
        slots: &mut [FusedSlot<'_>],
        lr: f32,
        scratch: &mut StepScratch,
        stats: &mut Vec<StepStats>,
    ) -> Result<()> {
        stats.clear();
        for slot in slots.iter_mut() {
            stats.push(self.train_step_sgd(slot.params, slot.x, slot.y, lr, scratch)?);
        }
        Ok(())
    }

    /// Evaluate `params` on one (possibly short) batch; only the first
    /// `n_valid` examples count — the tail is masked out.
    fn eval_batch(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        n_valid: usize,
        scratch: &mut StepScratch,
    ) -> Result<EvalStats>;

    /// Weighted-delta FedAvg aggregation (Eq. 2):
    /// `global' = global + Σ w_i · delta_i`.
    fn aggregate(
        &self,
        global: &[f32],
        deltas: &[Vec<f32>],
        weights: &[f32],
    ) -> Result<Vec<f32>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse(" PJRT ").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Native.name(), "native");
        assert_eq!(format!("{}", BackendKind::Pjrt), "pjrt");
    }

    #[test]
    fn eval_stats_ratios() {
        let e = EvalStats {
            loss_sum: 10.0,
            correct: 8.0,
            count: 16.0,
        };
        assert!((e.mean_loss() - 0.625).abs() < 1e-12);
        assert!((e.accuracy() - 0.5).abs() < 1e-12);
        let z = EvalStats::default();
        assert!(z.mean_loss().is_nan());
        assert!(z.accuracy().is_nan());
    }

    #[test]
    fn adam_state_zeroed() {
        let s = AdamState::zeros(4);
        assert_eq!(s.m, vec![0.0; 4]);
        assert_eq!(s.v, vec![0.0; 4]);
        assert_eq!(s.t, 0.0);
    }
}
