//! Runtime: pluggable execution backends behind one executor trait.
//!
//! - [`backend`] — the [`ModelExecutor`] trait covering the five runtime
//!   ops (SGD step, Adam step, masked eval, FedAvg aggregation, model
//!   loading) plus [`BackendKind`] and the shared stat types.
//! - [`native`] — the default pure-rust CPU backend: hermetic, no
//!   Python/XLA/artifacts, multithreaded aggregation on the worker pool.
//! - [`gemm`] — the cache-blocked GEMM drivers the native step path
//!   runs on (register-tiled axpy micro-kernels, zero-skip tiles).
//! - [`simd`] — the runtime-dispatched kernel layer under the hot
//!   loops: scalar / AVX2+FMA / NEON implementations of the axpy
//!   micro-kernels, the streaming fixed-point reduce, and the
//!   counter-based synthesis noise pass, selected once at startup
//!   (`FERRISFL_SIMD` overrides).
//! - [`reference`] — the pre-blocking naive MLP engine, retained as the
//!   golden baseline for tests and the naive-vs-blocked bench.
//! - [`pjrt`] — the PJRT/XLA path over AOT artifacts (the Pallas-kernel
//!   route), behind the optional `pjrt` cargo feature.
//! - [`manifest`] — the environment descriptor: parsed from
//!   `artifacts/manifest.json` for PJRT, or synthesised in memory by
//!   [`Manifest::native`] for the native backend.
//! - [`stats`] — marshalling/memory counters feeding the profiler
//!   (paper Fig 10).

pub mod backend;
pub mod gemm;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod simd;
pub mod stats;

pub use backend::{
    AdamState, BackendKind, EvalStats, FusedSlot, ModelExecutor, StepScratch, StepStats,
};
pub use manifest::{ArtifactInfo, DatasetInfo, Manifest, ZooInfo};
pub use native::NativeExecutor;
#[cfg(feature = "pjrt")]
pub use pjrt::{Device, PjrtRuntime};
pub use stats::{snapshot, MemSnapshot};
