//! PJRT runtime: the bridge from AOT artifacts to the rust hot path.
//!
//! - [`manifest`] — parse `artifacts/manifest.json` (the L2↔L3 contract).
//! - [`executor`] — PJRT client, compile cache, train/eval/aggregate
//!   executables over the flat-parameter ABI.
//! - [`stats`] — marshalling/memory counters feeding the profiler
//!   (paper Fig 10).

pub mod executor;
pub mod manifest;
pub mod stats;

pub use executor::{AdamState, Device, EvalStats, ModelRuntime, StepStats};
pub use manifest::{ArtifactInfo, DatasetInfo, Manifest, ZooInfo};
pub use stats::{snapshot, MemSnapshot};
