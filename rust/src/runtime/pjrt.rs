//! PJRT execution backend: load HLO text, compile once, run hot.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Gated behind the `pjrt` cargo feature — enabling it requires the
//! vendored `xla` crate (see rust/Cargo.toml).
//!
//! The `xla` wrappers are `Rc`-based (not `Send`), so a `Device` and
//! everything loaded on it live on ONE thread. The worker pool gives each
//! worker its own `Device` — the simulated analogue of each FL client
//! owning its own accelerator.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use crate::util::error::{bail, err, Context, Result};

use super::backend::{AdamState, BackendKind, EvalStats, ModelExecutor, StepScratch, StepStats};
use super::manifest::{ArtifactInfo, DatasetInfo, Manifest};
use super::stats;

/// A PJRT device (CPU client) plus a compile cache keyed by HLO path.
pub struct Device {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Device {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()
                .map_err(|e| err!("creating PJRT CPU client: {e}"))?,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file, memoised per device.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let path = path.as_ref().to_path_buf();
        if let Some(exe) = self.cache.borrow().get(&path) {
            return Ok(Rc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| err!("parsing HLO {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| err!("compiling {path:?}: {e}"))?,
        );
        self.cache.borrow_mut().insert(path, Rc::clone(&exe));
        Ok(exe)
    }
}

/// Execute with literal args, unwrap the 1-tuple root into its elements,
/// and record marshalling stats.
fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let in_bytes: usize = args.iter().map(|l| l.size_bytes()).sum();
    stats::add_allocated(in_bytes as u64);
    stats::add_execution();
    let mut outs = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| err!("PJRT execute: {e}"))?;
    stats::add_freed(in_bytes as u64);
    if outs.is_empty() || outs[0].is_empty() {
        bail!("executable returned no outputs");
    }
    let root = outs
        .swap_remove(0)
        .swap_remove(0)
        .to_literal_sync()
        .map_err(|e| err!("fetching result: {e}"))?;
    // aot.py lowers with return_tuple=True: the root is always a tuple.
    // NOTE: size_bytes() must only be called on the *elements* — XLA's
    // ByteSizeOf CHECK-fails on tuple shapes (pointer_size = -1).
    let elems = root.to_tuple().map_err(|e| err!("untupling result: {e}"))?;
    let out_bytes: usize = elems.iter().map(|l| l.size_bytes()).sum();
    stats::add_allocated(out_bytes as u64);
    stats::add_freed(out_bytes as u64);
    Ok(elems)
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| err!("reshape {dims:?}: {e}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| err!("reshape {dims:?}: {e}"))
}

fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| err!("literal to f32 vec: {e}"))
}

fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>().map_err(|e| err!("scalar: {e}"))
}

/// Everything needed to train/eval one model@dataset on one device.
///
/// Loads the train entry named by (`optimizer`, `mode`) — e.g.
/// ("sgd", "full") → `train_sgd_full` — plus eval and the FedAvg
/// aggregation executable.
pub struct PjrtRuntime {
    pub train_exe: Rc<xla::PjRtLoadedExecutable>,
    pub eval_exe: Rc<xla::PjRtLoadedExecutable>,
    pub agg_exe: Rc<xla::PjRtLoadedExecutable>,
    pub num_params: usize,
    pub head_size: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub k_pad: usize,
    pub input_dims: Vec<i64>, // [H, W, C]
    pub optimizer: String,
    manifest: Arc<Manifest>,
    init_file: String,
    pretrained_file: Option<String>,
}

impl PjrtRuntime {
    /// Load the runtime for `art` on `device`. `entry_tag` selects kernel
    /// vs reference artifacts ("" or "_ref").
    pub fn load(
        device: &Device,
        manifest: &Arc<Manifest>,
        art: &ArtifactInfo,
        ds: &DatasetInfo,
        optimizer: &str,
        mode: &str,
        entry_tag: &str,
    ) -> Result<Self> {
        let train_key = format!("train_{optimizer}_{mode}{entry_tag}");
        let train_file = art.entries.get(&train_key).with_context(|| {
            format!(
                "artifact {} has no entry {train_key}; available: {:?}",
                art.id,
                art.entries.keys().collect::<Vec<_>>()
            )
        })?;
        let eval_key = format!("eval{entry_tag}");
        let eval_file = art
            .entries
            .get(&eval_key)
            .with_context(|| format!("artifact {} has no {eval_key}", art.id))?;
        Ok(Self {
            train_exe: device.load_hlo(manifest.path(train_file))?,
            eval_exe: device.load_hlo(manifest.path(eval_file))?,
            agg_exe: device.load_hlo(manifest.path(&art.agg_file))?,
            num_params: art.num_params,
            head_size: art.head_size,
            train_batch: manifest.train_batch,
            eval_batch: manifest.eval_batch,
            k_pad: manifest.k_pad,
            input_dims: vec![ds.height as i64, ds.width as i64, ds.channels as i64],
            optimizer: optimizer.to_string(),
            manifest: Arc::clone(manifest),
            init_file: art.init_file.clone(),
            pretrained_file: art.pretrained_file.clone(),
        })
    }

    fn x_dims(&self, batch: usize) -> Vec<i64> {
        let mut d = vec![batch as i64];
        d.extend_from_slice(&self.input_dims);
        d
    }
}

impl ModelExecutor for PjrtRuntime {
    fn backend(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn num_params(&self) -> usize {
        self.num_params
    }

    fn head_size(&self) -> usize {
        self.head_size
    }

    fn train_batch_size(&self) -> usize {
        self.train_batch
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }

    fn optimizer(&self) -> &str {
        &self.optimizer
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        self.manifest.read_f32(&self.init_file)
    }

    fn pretrained_params(&self) -> Result<Vec<f32>> {
        let f = self.pretrained_file.as_ref().context(
            "artifact has no pretrained weights (set pretrain=True in python/compile/aot.py)",
        )?;
        self.manifest.read_f32(f)
    }

    /// One SGD train step. `params` is updated in place. The scratch
    /// arena is unused: PJRT marshals through device literals, so the
    /// step inherently allocates on the host side.
    fn train_step_sgd(
        &self,
        params: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        _scratch: &mut StepScratch,
    ) -> Result<StepStats> {
        debug_assert_eq!(params.len(), self.num_params);
        debug_assert_eq!(y.len(), self.train_batch);
        let args = [
            lit_f32(params, &[self.num_params as i64])?,
            lit_f32(x, &self.x_dims(self.train_batch))?,
            lit_i32(y, &[self.train_batch as i64])?,
            xla::Literal::scalar(lr),
        ];
        let outs = run(&self.train_exe, &args)?;
        if outs.len() != 3 {
            bail!("train_sgd returned {} outputs, want 3", outs.len());
        }
        *params = to_f32(&outs[0])?;
        Ok(StepStats {
            loss: scalar_f32(&outs[1])?,
            hits: scalar_f32(&outs[2])?,
        })
    }

    /// One Adam train step. `params` and `state` update in place.
    fn train_step_adam(
        &self,
        params: &mut Vec<f32>,
        state: &mut AdamState,
        x: &[f32],
        y: &[i32],
        lr: f32,
        _scratch: &mut StepScratch,
    ) -> Result<StepStats> {
        let p = self.num_params as i64;
        let args = [
            lit_f32(params, &[p])?,
            lit_f32(&state.m, &[p])?,
            lit_f32(&state.v, &[p])?,
            xla::Literal::scalar(state.t),
            lit_f32(x, &self.x_dims(self.train_batch))?,
            lit_i32(y, &[self.train_batch as i64])?,
            xla::Literal::scalar(lr),
        ];
        let outs = run(&self.train_exe, &args)?;
        if outs.len() != 6 {
            bail!("train_adam returned {} outputs, want 6", outs.len());
        }
        *params = to_f32(&outs[0])?;
        state.m = to_f32(&outs[1])?;
        state.v = to_f32(&outs[2])?;
        state.t = scalar_f32(&outs[3])?;
        Ok(StepStats {
            loss: scalar_f32(&outs[4])?,
            hits: scalar_f32(&outs[5])?,
        })
    }

    /// Evaluate `params` on one (possibly short) batch; `x`/`y` may hold
    /// fewer than `eval_batch` examples — the tail is zero-padded and
    /// masked out inside the graph. Padding buffers live in the scratch
    /// arena so repeated eval batches reuse their storage.
    fn eval_batch(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        n_valid: usize,
        scratch: &mut StepScratch,
    ) -> Result<EvalStats> {
        let be = self.eval_batch;
        assert!(n_valid <= be);
        let ex_len: usize = self.input_dims.iter().product::<i64>() as usize;
        StepScratch::grow_f32(&mut scratch.xpad, be * ex_len);
        let xp = &mut scratch.xpad[..be * ex_len];
        xp[..x.len()].copy_from_slice(x);
        xp[x.len()..].fill(0.0);
        StepScratch::grow_i32(&mut scratch.ypad, be);
        let yp = &mut scratch.ypad[..be];
        yp[..y.len()].copy_from_slice(y);
        yp[y.len()..].fill(0);
        StepScratch::grow_f32(&mut scratch.mask, be);
        let mask = &mut scratch.mask[..be];
        mask[..n_valid].fill(1.0);
        mask[n_valid..].fill(0.0);
        let args = [
            lit_f32(params, &[self.num_params as i64])?,
            lit_f32(xp, &self.x_dims(be))?,
            lit_i32(yp, &[be as i64])?,
            lit_f32(mask, &[be as i64])?,
        ];
        let outs = run(&self.eval_exe, &args)?;
        if outs.len() != 3 {
            bail!("eval returned {} outputs, want 3", outs.len());
        }
        Ok(EvalStats {
            loss_sum: scalar_f32(&outs[0])? as f64,
            correct: scalar_f32(&outs[1])? as f64,
            count: scalar_f32(&outs[2])? as f64,
        })
    }

    /// FedAvg aggregation on the PJRT path (the L1 Pallas kernel):
    /// `global' = global + Σ w_i · delta_i`, with zero-padding up to
    /// `k_pad` (exact by the kernel's weighted-sum semantics).
    fn aggregate(
        &self,
        global: &[f32],
        deltas: &[Vec<f32>],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let k = deltas.len();
        if k != weights.len() {
            bail!("{k} deltas but {} weights", weights.len());
        }
        if k > self.k_pad {
            bail!(
                "{k} sampled agents exceeds the compiled K_pad={} — raise \
                 K_PAD in python/compile/aot.py and rebuild artifacts",
                self.k_pad
            );
        }
        let p = self.num_params;
        let mut dstack = vec![0.0f32; self.k_pad * p];
        for (i, d) in deltas.iter().enumerate() {
            if d.len() != p {
                bail!("delta {i} has {} params, want {p}", d.len());
            }
            dstack[i * p..(i + 1) * p].copy_from_slice(d);
        }
        let mut wpad = vec![0.0f32; self.k_pad];
        wpad[..k].copy_from_slice(weights);
        let args = [
            lit_f32(&dstack, &[self.k_pad as i64, p as i64])?,
            lit_f32(&wpad, &[self.k_pad as i64])?,
            lit_f32(global, &[p as i64])?,
        ];
        let outs = run(&self.agg_exe, &args)?;
        if outs.len() != 1 {
            bail!("agg returned {} outputs, want 1", outs.len());
        }
        to_f32(&outs[0])
    }
}
