//! Runtime-dispatched SIMD kernels for the three hot inner loops: the
//! GEMM axpy micro-kernels, the streaming aggregator's fixed-point
//! quantise-and-accumulate, and the synthesis noise pass.
//!
//! The rest of the crate calls these through [`kernels`], a table of
//! plain function pointers selected **once** per process:
//!
//! - **scalar** — safe Rust, the exact loops the blocked engine shipped
//!   with (LLVM still autovectorizes them at baseline `x86-64`, i.e.
//!   SSE2 without FMA). Always available; the reference all other
//!   implementations are pinned against.
//! - **avx2** — `x86_64` with AVX2+FMA, detected at startup via
//!   `is_x86_feature_detected!`. 8-wide f32 FMA axpy tiles, 4-wide f64
//!   quantisation, a counter-based 4-lane synthesis pass, and an 8×8
//!   in-register transpose.
//! - **neon** — `aarch64` (NEON is baseline there, so the choice is
//!   compile-time). 4-wide FMA axpy tiles and a 2-wide quantisation
//!   loop; synthesis and the transpose block stay scalar because NEON
//!   has no packed 64-bit integer multiply for the SplitMix64 mix and
//!   no cross-lane f32 shuffle network worth the surface.
//!
//! `FERRISFL_SIMD=0|scalar|avx2|neon|auto` overrides the detection (for
//! the CI matrix legs and A/B tests). Requesting an ISA the CPU does not
//! support warns and falls back to scalar — the table can never hand out
//! instructions the host will fault on.
//!
//! **Parity contracts.** The streaming-reduce and synthesis kernels are
//! **bit-identical** to scalar on every path: they use only exactly
//! rounded IEEE ops (add/mul of exact values, `max`/`min` on non-NaN
//! data, hardware sqrt, correctly rounded casts) plus per-lane calls to
//! the very same `ln`/`cos` the scalar code uses, so dispatch can never
//! change `SynthCache` contents or the order-invariant reduce. The GEMM
//! micro-kernels fuse multiply-adds (FMA rounds once, scalar rounds
//! twice), so they match scalar to ~1e-6 relative — inside the 1e-5
//! contract the golden tests pin against the naive reference. Both
//! contracts are enforced by unit tests here and by the parity
//! proptests in `tests/proptests.rs`.

use std::sync::OnceLock;

use crate::util::rng::{splitmix64_mix, SPLITMIX64_GAMMA};

/// Which kernel implementation is driving the hot loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Safe-Rust loops (autovectorized at whatever the build's baseline
    /// target features allow).
    Scalar,
    /// `x86_64` AVX2 + FMA intrinsics, runtime-detected.
    Avx2,
    /// `aarch64` NEON intrinsics (baseline on that architecture).
    Neon,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// 2×4 micro step: two C rows, four rank-1 contributions each.
pub type Axpy42 = fn(&mut [f32], &mut [f32], [&[f32]; 4], [f32; 4], [f32; 4]);
/// 1×4 micro step (M tail).
pub type Axpy41 = fn(&mut [f32], [&[f32]; 4], [f32; 4]);
/// 2×1 micro step (K tail).
pub type Axpy12 = fn(&mut [f32], &mut [f32], &[f32], f32, f32);
/// 1×1 micro step (M and K tails).
pub type Axpy11 = fn(&mut [f32], &[f32], f32);
/// 2×8 micro step: two C rows, eight rank-1 contributions — one C
/// load/store per 8 K-steps where registers allow.
pub type Axpy82 = fn(&mut [f32], &mut [f32], [&[f32]; 8], [f32; 8], [f32; 8]);
/// 8×8 block transpose: `dst[c*dst_stride + r] = src[r*src_stride + c]`
/// for `r, c in 0..8`. Both slices must cover their 8th row.
pub type Transpose8 = fn(&[f32], usize, &mut [f32], usize);
/// Fixed-point quantise-accumulate: for each `i < acc.len()`,
/// `acc[i] += ((w·delta[i] as f64).clamp(-limit, limit) * scale) as i128`.
/// Bit-identical across implementations (exact products, non-NaN
/// clamp, truncating cast).
pub type FixedAccum = fn(&mut [i128], &[f32], f64, f64, f64);
/// Synthesis noise pass: for each `k < out.len()`,
/// `out[k] = (out[k] + noise·g_k).clamp(-0.5, 1.5) - 0.5`, where `g_k`
/// is the Box–Muller gaussian built from SplitMix64 counter draws
/// `2k+1` and `2k+2` off `state` — exactly the stream a sequential
/// `Rng::new(state)` would produce via `next_gaussian()`. Bit-identical
/// across implementations.
pub type SynthNoise = fn(&mut [f32], f32, u64);

/// The dispatch table: one function pointer per hot inner loop.
///
/// Tables are `'static` and hold plain `fn` pointers, so a resolved
/// `&'static Kernels` is freely shared across threads — the
/// panel-parallel GEMM drivers resolve the table once on the
/// submitting thread and hand the same reference to every panel job,
/// keeping the dispatch level (and therefore the bit pattern)
/// identical across the panels of one product.
pub struct Kernels {
    pub name: &'static str,
    pub axpy4_2: Axpy42,
    pub axpy4_1: Axpy41,
    pub axpy1_2: Axpy12,
    pub axpy1_1: Axpy11,
    pub axpy8_2: Axpy82,
    pub transpose8: Transpose8,
    pub fixed_accumulate: FixedAccum,
    pub synth_noise: SynthNoise,
}

/// The best level this CPU supports.
#[allow(unreachable_code)]
pub fn detected() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    SimdLevel::Scalar
}

/// Resolve an optional `FERRISFL_SIMD` value against the detected
/// level. Returns the level to use plus a warning when the request
/// could not be honoured (unknown value, or an ISA this CPU lacks —
/// which falls back to scalar rather than faulting).
fn resolve(request: Option<&str>, detected: SimdLevel) -> (SimdLevel, Option<String>) {
    let Some(req) = request else {
        return (detected, None);
    };
    match req.trim().to_ascii_lowercase().as_str() {
        "" | "1" | "auto" => (detected, None),
        "0" | "off" | "scalar" => (SimdLevel::Scalar, None),
        "avx2" if detected == SimdLevel::Avx2 => (SimdLevel::Avx2, None),
        "neon" if detected == SimdLevel::Neon => (SimdLevel::Neon, None),
        known @ ("avx2" | "neon") => (
            SimdLevel::Scalar,
            Some(format!(
                "FERRISFL_SIMD={known} requested but this CPU/arch does not support it; \
                 using scalar kernels"
            )),
        ),
        other => (
            detected,
            Some(format!(
                "unknown FERRISFL_SIMD value {other:?} (want 0|scalar|avx2|neon|auto); \
                 using detected level {}",
                detected.name()
            )),
        ),
    }
}

/// The active dispatch level, chosen once per process: the detected
/// level, overridden by `FERRISFL_SIMD` when set.
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let request = crate::util::env::simd();
        let (level, warning) = resolve(request.as_deref(), detected());
        if let Some(w) = warning {
            eprintln!("warning: {w}");
        }
        level
    })
}

/// The kernel table for an explicit level, when this build/CPU can run
/// it. `Scalar` always succeeds; `Avx2`/`Neon` return `None` off their
/// architecture or when the CPU lacks the features (so handing out the
/// table is always sound). Benches and parity tests use this to compare
/// implementations inside one process.
pub fn kernels_for(level: SimdLevel) -> Option<&'static Kernels> {
    match level {
        SimdLevel::Scalar => Some(&SCALAR),
        SimdLevel::Avx2 => avx2_kernels(),
        SimdLevel::Neon => neon_kernels(),
    }
}

/// Every level runnable on this machine (scalar first).
pub fn available_levels() -> Vec<SimdLevel> {
    [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Neon]
        .into_iter()
        .filter(|&l| kernels_for(l).is_some())
        .collect()
}

/// The active kernel table — what the GEMM drivers, the streaming
/// accumulator, and dataset synthesis call through.
pub fn kernels() -> &'static Kernels {
    kernels_for(level()).unwrap_or(&SCALAR)
}

fn avx2_kernels() -> Option<&'static Kernels> {
    #[cfg(target_arch = "x86_64")]
    {
        if detected() == SimdLevel::Avx2 {
            return Some(&x86::AVX2);
        }
    }
    None
}

#[allow(unreachable_code)]
fn neon_kernels() -> Option<&'static Kernels> {
    #[cfg(target_arch = "aarch64")]
    {
        return Some(&aarch64::NEON);
    }
    None
}

// ==================================================== shared helpers

/// Counter-mode SplitMix64: the j-th upcoming draw of a generator whose
/// state is `state` (1-indexed, matching sequential `next_u64` calls).
#[inline]
fn draw(state: u64, j: u64) -> u64 {
    splitmix64_mix(state.wrapping_add(SPLITMIX64_GAMMA.wrapping_mul(j)))
}

/// Box–Muller gaussian from two raw draws — the exact expression of
/// `Rng::next_gaussian` (`u = (d >> 11) / 2⁵³`, `u1` floored at 1e-12).
#[inline]
fn gauss_from(d1: u64, d2: u64) -> f32 {
    let u1 = ((d1 >> 11) as f64 / (1u64 << 53) as f64).max(1e-12);
    let u2 = (d2 >> 11) as f64 / (1u64 << 53) as f64;
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// Gaussian `k` (0-indexed) of the stream rooted at `state`: draws
/// `2k+1` and `2k+2`, exactly what the k-th sequential
/// `next_gaussian()` would consume.
#[inline]
fn gauss_at(state: u64, k: u64) -> f32 {
    gauss_from(draw(state, 2 * k + 1), draw(state, 2 * k + 2))
}

/// One synthesis output element (shared by every scalar tail).
#[inline]
fn synth_one(v: f32, noise: f32, g: f32) -> f32 {
    (v + noise * g).clamp(-0.5, 1.5) - 0.5
}

// ==================================================== scalar kernels

/// The safe-Rust reference implementations (the pre-SIMD hot loops,
/// verbatim). Always compiled; other tables are pinned against them.
static SCALAR: Kernels = Kernels {
    name: "scalar",
    axpy4_2: scalar::axpy4_2,
    axpy4_1: scalar::axpy4_1,
    axpy1_2: scalar::axpy1_2,
    axpy1_1: scalar::axpy1_1,
    axpy8_2: scalar::axpy8_2,
    transpose8: scalar::transpose8,
    fixed_accumulate: scalar::fixed_accumulate,
    synth_noise: scalar::synth_noise,
};

mod scalar {
    use super::{gauss_at, synth_one};

    pub fn axpy4_2(c0: &mut [f32], c1: &mut [f32], b: [&[f32]; 4], x0: [f32; 4], x1: [f32; 4]) {
        if x0 == [0.0; 4] && x1 == [0.0; 4] {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let (b0, b1, b2, b3) = (&b[0][..nn], &b[1][..nn], &b[2][..nn], &b[3][..nn]);
        for j in 0..nn {
            c0[j] += x0[0] * b0[j] + x0[1] * b1[j] + x0[2] * b2[j] + x0[3] * b3[j];
            c1[j] += x1[0] * b0[j] + x1[1] * b1[j] + x1[2] * b2[j] + x1[3] * b3[j];
        }
    }

    pub fn axpy4_1(c0: &mut [f32], b: [&[f32]; 4], x: [f32; 4]) {
        if x == [0.0; 4] {
            return;
        }
        let nn = c0.len();
        let (b0, b1, b2, b3) = (&b[0][..nn], &b[1][..nn], &b[2][..nn], &b[3][..nn]);
        for j in 0..nn {
            c0[j] += x[0] * b0[j] + x[1] * b1[j] + x[2] * b2[j] + x[3] * b3[j];
        }
    }

    pub fn axpy1_2(c0: &mut [f32], c1: &mut [f32], b0: &[f32], x0: f32, x1: f32) {
        if x0 == 0.0 && x1 == 0.0 {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let b0 = &b0[..nn];
        for j in 0..nn {
            c0[j] += x0 * b0[j];
            c1[j] += x1 * b0[j];
        }
    }

    pub fn axpy1_1(c0: &mut [f32], b0: &[f32], x: f32) {
        if x == 0.0 {
            return;
        }
        let nn = c0.len();
        let b0 = &b0[..nn];
        for j in 0..nn {
            c0[j] += x * b0[j];
        }
    }

    /// Two 2×4 halves — identical results and zero-skips to stepping
    /// the K loop by 4 twice.
    pub fn axpy8_2(c0: &mut [f32], c1: &mut [f32], b: [&[f32]; 8], x0: [f32; 8], x1: [f32; 8]) {
        axpy4_2(
            c0,
            c1,
            [b[0], b[1], b[2], b[3]],
            [x0[0], x0[1], x0[2], x0[3]],
            [x1[0], x1[1], x1[2], x1[3]],
        );
        axpy4_2(
            c0,
            c1,
            [b[4], b[5], b[6], b[7]],
            [x0[4], x0[5], x0[6], x0[7]],
            [x1[4], x1[5], x1[6], x1[7]],
        );
    }

    pub fn transpose8(src: &[f32], src_stride: usize, dst: &mut [f32], dst_stride: usize) {
        assert!(src.len() >= 7 * src_stride + 8);
        assert!(dst.len() >= 7 * dst_stride + 8);
        for r in 0..8 {
            for c in 0..8 {
                dst[c * dst_stride + r] = src[r * src_stride + c];
            }
        }
    }

    pub fn fixed_accumulate(acc: &mut [i128], delta: &[f32], w: f64, limit: f64, scale: f64) {
        for (a, &d) in acc.iter_mut().zip(delta) {
            let term = (w * d as f64).clamp(-limit, limit);
            *a += (term * scale) as i128;
        }
    }

    pub fn synth_noise(out: &mut [f32], noise: f32, state: u64) {
        for (k, o) in out.iter_mut().enumerate() {
            *o = synth_one(*o, noise, gauss_at(state, k as u64));
        }
    }
}

// ====================================================== AVX2 kernels

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use super::{gauss_at, synth_one, Kernels, SPLITMIX64_GAMMA};

    /// Only handed out by `kernels_for` after `is_x86_feature_detected!`
    /// confirmed AVX2+FMA, so the safe wrappers below are sound.
    pub(super) static AVX2: Kernels = Kernels {
        name: "avx2",
        axpy4_2,
        axpy4_1,
        axpy1_2,
        axpy1_1,
        axpy8_2,
        transpose8,
        fixed_accumulate,
        synth_noise,
    };

    fn axpy4_2(c0: &mut [f32], c1: &mut [f32], b: [&[f32]; 4], x0: [f32; 4], x1: [f32; 4]) {
        // SAFETY: this table is only reachable once AVX2+FMA detection
        // succeeded (see `AVX2` above); same for every wrapper below.
        unsafe { axpy4_2_fma(c0, c1, b, x0, x1) }
    }

    fn axpy4_1(c0: &mut [f32], b: [&[f32]; 4], x: [f32; 4]) {
        unsafe { axpy4_1_fma(c0, b, x) }
    }

    fn axpy1_2(c0: &mut [f32], c1: &mut [f32], b0: &[f32], x0: f32, x1: f32) {
        unsafe { axpy1_2_fma(c0, c1, b0, x0, x1) }
    }

    fn axpy1_1(c0: &mut [f32], b0: &[f32], x: f32) {
        unsafe { axpy1_1_fma(c0, b0, x) }
    }

    fn axpy8_2(c0: &mut [f32], c1: &mut [f32], b: [&[f32]; 8], x0: [f32; 8], x1: [f32; 8]) {
        unsafe { axpy8_2_fma(c0, c1, b, x0, x1) }
    }

    fn transpose8(src: &[f32], src_stride: usize, dst: &mut [f32], dst_stride: usize) {
        assert!(src.len() >= 7 * src_stride + 8);
        assert!(dst.len() >= 7 * dst_stride + 8);
        unsafe { transpose8_avx(src, src_stride, dst, dst_stride) }
    }

    fn fixed_accumulate(acc: &mut [i128], delta: &[f32], w: f64, limit: f64, scale: f64) {
        unsafe { fixed_accumulate_avx(acc, delta, w, limit, scale) }
    }

    fn synth_noise(out: &mut [f32], noise: f32, state: u64) {
        unsafe { synth_noise_avx(out, noise, state) }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy4_2_fma(
        c0: &mut [f32],
        c1: &mut [f32],
        b: [&[f32]; 4],
        x0: [f32; 4],
        x1: [f32; 4],
    ) {
        if x0 == [0.0; 4] && x1 == [0.0; 4] {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let (b0, b1, b2, b3) = (&b[0][..nn], &b[1][..nn], &b[2][..nn], &b[3][..nn]);
        let y00 = _mm256_set1_ps(x0[0]);
        let y01 = _mm256_set1_ps(x0[1]);
        let y02 = _mm256_set1_ps(x0[2]);
        let y03 = _mm256_set1_ps(x0[3]);
        let y10 = _mm256_set1_ps(x1[0]);
        let y11 = _mm256_set1_ps(x1[1]);
        let y12 = _mm256_set1_ps(x1[2]);
        let y13 = _mm256_set1_ps(x1[3]);
        let mut j = 0usize;
        while j + 8 <= nn {
            let v0 = _mm256_loadu_ps(b0.as_ptr().add(j));
            let v1 = _mm256_loadu_ps(b1.as_ptr().add(j));
            let v2 = _mm256_loadu_ps(b2.as_ptr().add(j));
            let v3 = _mm256_loadu_ps(b3.as_ptr().add(j));
            let mut a0 = _mm256_loadu_ps(c0.as_ptr().add(j));
            a0 = _mm256_fmadd_ps(y00, v0, a0);
            a0 = _mm256_fmadd_ps(y01, v1, a0);
            a0 = _mm256_fmadd_ps(y02, v2, a0);
            a0 = _mm256_fmadd_ps(y03, v3, a0);
            _mm256_storeu_ps(c0.as_mut_ptr().add(j), a0);
            let mut a1 = _mm256_loadu_ps(c1.as_ptr().add(j));
            a1 = _mm256_fmadd_ps(y10, v0, a1);
            a1 = _mm256_fmadd_ps(y11, v1, a1);
            a1 = _mm256_fmadd_ps(y12, v2, a1);
            a1 = _mm256_fmadd_ps(y13, v3, a1);
            _mm256_storeu_ps(c1.as_mut_ptr().add(j), a1);
            j += 8;
        }
        while j < nn {
            c0[j] += x0[0] * b0[j] + x0[1] * b1[j] + x0[2] * b2[j] + x0[3] * b3[j];
            c1[j] += x1[0] * b0[j] + x1[1] * b1[j] + x1[2] * b2[j] + x1[3] * b3[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy8_2_fma(
        c0: &mut [f32],
        c1: &mut [f32],
        b: [&[f32]; 8],
        x0: [f32; 8],
        x1: [f32; 8],
    ) {
        if x0 == [0.0; 8] && x1 == [0.0; 8] {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let mut j = 0usize;
        while j + 8 <= nn {
            let mut a0 = _mm256_loadu_ps(c0.as_ptr().add(j));
            let mut a1 = _mm256_loadu_ps(c1.as_ptr().add(j));
            // Eight shared B rows against both accumulators; the
            // broadcasts are loop-invariant and hoisted by the compiler
            // (spilled ones reload as cheap 32-byte splats).
            for t in 0..8 {
                let v = _mm256_loadu_ps(b[t][..nn].as_ptr().add(j));
                a0 = _mm256_fmadd_ps(_mm256_set1_ps(x0[t]), v, a0);
                a1 = _mm256_fmadd_ps(_mm256_set1_ps(x1[t]), v, a1);
            }
            _mm256_storeu_ps(c0.as_mut_ptr().add(j), a0);
            _mm256_storeu_ps(c1.as_mut_ptr().add(j), a1);
            j += 8;
        }
        while j < nn {
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            for t in 0..8 {
                s0 += x0[t] * b[t][j];
                s1 += x1[t] * b[t][j];
            }
            c0[j] += s0;
            c1[j] += s1;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy4_1_fma(c0: &mut [f32], b: [&[f32]; 4], x: [f32; 4]) {
        if x == [0.0; 4] {
            return;
        }
        let nn = c0.len();
        let (b0, b1, b2, b3) = (&b[0][..nn], &b[1][..nn], &b[2][..nn], &b[3][..nn]);
        let y0 = _mm256_set1_ps(x[0]);
        let y1 = _mm256_set1_ps(x[1]);
        let y2 = _mm256_set1_ps(x[2]);
        let y3 = _mm256_set1_ps(x[3]);
        let mut j = 0usize;
        while j + 8 <= nn {
            let mut a = _mm256_loadu_ps(c0.as_ptr().add(j));
            a = _mm256_fmadd_ps(y0, _mm256_loadu_ps(b0.as_ptr().add(j)), a);
            a = _mm256_fmadd_ps(y1, _mm256_loadu_ps(b1.as_ptr().add(j)), a);
            a = _mm256_fmadd_ps(y2, _mm256_loadu_ps(b2.as_ptr().add(j)), a);
            a = _mm256_fmadd_ps(y3, _mm256_loadu_ps(b3.as_ptr().add(j)), a);
            _mm256_storeu_ps(c0.as_mut_ptr().add(j), a);
            j += 8;
        }
        while j < nn {
            c0[j] += x[0] * b0[j] + x[1] * b1[j] + x[2] * b2[j] + x[3] * b3[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy1_2_fma(c0: &mut [f32], c1: &mut [f32], b: &[f32], x0: f32, x1: f32) {
        if x0 == 0.0 && x1 == 0.0 {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let b = &b[..nn];
        let y0 = _mm256_set1_ps(x0);
        let y1 = _mm256_set1_ps(x1);
        let mut j = 0usize;
        while j + 8 <= nn {
            let v = _mm256_loadu_ps(b.as_ptr().add(j));
            let a0 = _mm256_fmadd_ps(y0, v, _mm256_loadu_ps(c0.as_ptr().add(j)));
            _mm256_storeu_ps(c0.as_mut_ptr().add(j), a0);
            let a1 = _mm256_fmadd_ps(y1, v, _mm256_loadu_ps(c1.as_ptr().add(j)));
            _mm256_storeu_ps(c1.as_mut_ptr().add(j), a1);
            j += 8;
        }
        while j < nn {
            c0[j] += x0 * b[j];
            c1[j] += x1 * b[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn axpy1_1_fma(c0: &mut [f32], b: &[f32], x: f32) {
        if x == 0.0 {
            return;
        }
        let nn = c0.len();
        let b = &b[..nn];
        let y = _mm256_set1_ps(x);
        let mut j = 0usize;
        while j + 8 <= nn {
            let acc = _mm256_loadu_ps(c0.as_ptr().add(j));
            let a = _mm256_fmadd_ps(y, _mm256_loadu_ps(b.as_ptr().add(j)), acc);
            _mm256_storeu_ps(c0.as_mut_ptr().add(j), a);
            j += 8;
        }
        while j < nn {
            c0[j] += x * b[j];
            j += 1;
        }
    }

    /// Canonical 8×8 f32 transpose: unpack pairs, shuffle quads, swap
    /// 128-bit halves. Pure data movement — bit-identical to scalar.
    #[target_feature(enable = "avx2")]
    unsafe fn transpose8_avx(src: &[f32], ss: usize, dst: &mut [f32], ds: usize) {
        let r0 = _mm256_loadu_ps(src.as_ptr());
        let r1 = _mm256_loadu_ps(src.as_ptr().add(ss));
        let r2 = _mm256_loadu_ps(src.as_ptr().add(2 * ss));
        let r3 = _mm256_loadu_ps(src.as_ptr().add(3 * ss));
        let r4 = _mm256_loadu_ps(src.as_ptr().add(4 * ss));
        let r5 = _mm256_loadu_ps(src.as_ptr().add(5 * ss));
        let r6 = _mm256_loadu_ps(src.as_ptr().add(6 * ss));
        let r7 = _mm256_loadu_ps(src.as_ptr().add(7 * ss));
        let t0 = _mm256_unpacklo_ps(r0, r1);
        let t1 = _mm256_unpackhi_ps(r0, r1);
        let t2 = _mm256_unpacklo_ps(r2, r3);
        let t3 = _mm256_unpackhi_ps(r2, r3);
        let t4 = _mm256_unpacklo_ps(r4, r5);
        let t5 = _mm256_unpackhi_ps(r4, r5);
        let t6 = _mm256_unpacklo_ps(r6, r7);
        let t7 = _mm256_unpackhi_ps(r6, r7);
        let s0 = _mm256_shuffle_ps::<0x44>(t0, t2);
        let s1 = _mm256_shuffle_ps::<0xEE>(t0, t2);
        let s2 = _mm256_shuffle_ps::<0x44>(t1, t3);
        let s3 = _mm256_shuffle_ps::<0xEE>(t1, t3);
        let s4 = _mm256_shuffle_ps::<0x44>(t4, t6);
        let s5 = _mm256_shuffle_ps::<0xEE>(t4, t6);
        let s6 = _mm256_shuffle_ps::<0x44>(t5, t7);
        let s7 = _mm256_shuffle_ps::<0xEE>(t5, t7);
        let o0 = _mm256_permute2f128_ps::<0x20>(s0, s4);
        let o1 = _mm256_permute2f128_ps::<0x20>(s1, s5);
        let o2 = _mm256_permute2f128_ps::<0x20>(s2, s6);
        let o3 = _mm256_permute2f128_ps::<0x20>(s3, s7);
        let o4 = _mm256_permute2f128_ps::<0x31>(s0, s4);
        let o5 = _mm256_permute2f128_ps::<0x31>(s1, s5);
        let o6 = _mm256_permute2f128_ps::<0x31>(s2, s6);
        let o7 = _mm256_permute2f128_ps::<0x31>(s3, s7);
        _mm256_storeu_ps(dst.as_mut_ptr(), o0);
        _mm256_storeu_ps(dst.as_mut_ptr().add(ds), o1);
        _mm256_storeu_ps(dst.as_mut_ptr().add(2 * ds), o2);
        _mm256_storeu_ps(dst.as_mut_ptr().add(3 * ds), o3);
        _mm256_storeu_ps(dst.as_mut_ptr().add(4 * ds), o4);
        _mm256_storeu_ps(dst.as_mut_ptr().add(5 * ds), o5);
        _mm256_storeu_ps(dst.as_mut_ptr().add(6 * ds), o6);
        _mm256_storeu_ps(dst.as_mut_ptr().add(7 * ds), o7);
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn fixed_accumulate_avx(
        acc: &mut [i128],
        delta: &[f32],
        w: f64,
        limit: f64,
        scale: f64,
    ) {
        let n = acc.len();
        assert!(delta.len() >= n);
        let wv = _mm256_set1_pd(w);
        let lo = _mm256_set1_pd(-limit);
        let hi = _mm256_set1_pd(limit);
        let sc = _mm256_set1_pd(scale);
        let mut buf = [0.0f64; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            // Exact f32→f64 widen and exact-per-op clamp/scale: every
            // lane rounds exactly like the scalar expression, and the
            // truncating i128 cast stays scalar — bit-identical reduce.
            let d = _mm256_cvtps_pd(_mm_loadu_ps(delta.as_ptr().add(i)));
            let t = _mm256_mul_pd(wv, d);
            let t = _mm256_min_pd(_mm256_max_pd(t, lo), hi);
            let t = _mm256_mul_pd(t, sc);
            _mm256_storeu_pd(buf.as_mut_ptr(), t);
            acc[i] += buf[0] as i128;
            acc[i + 1] += buf[1] as i128;
            acc[i + 2] += buf[2] as i128;
            acc[i + 3] += buf[3] as i128;
            i += 4;
        }
        while i < n {
            let term = (w * delta[i] as f64).clamp(-limit, limit);
            acc[i] += (term * scale) as i128;
            i += 1;
        }
    }

    /// `a·b mod 2⁶⁴` per 64-bit lane (AVX2 has no packed 64-bit
    /// multiply): `lo(a)·lo(b) + ((lo(a)·hi(b) + hi(a)·lo(b)) << 32)`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mullo_epi64(a: __m256i, b: __m256i) -> __m256i {
        let lo = _mm256_mul_epu32(a, b);
        let ah = _mm256_srli_epi64::<32>(a);
        let bh = _mm256_srli_epi64::<32>(b);
        let cross = _mm256_add_epi64(_mm256_mul_epu32(ah, b), _mm256_mul_epu32(a, bh));
        _mm256_add_epi64(lo, _mm256_slli_epi64::<32>(cross))
    }

    /// The SplitMix64 output mix on four lanes at once.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn splitmix4(z: __m256i) -> __m256i {
        let m1 = _mm256_set1_epi64x(0xBF58_476D_1CE4_E5B9u64 as i64);
        let m2 = _mm256_set1_epi64x(0x94D0_49BB_1331_11EBu64 as i64);
        let z = mullo_epi64(_mm256_xor_si256(z, _mm256_srli_epi64::<30>(z)), m1);
        let z = mullo_epi64(_mm256_xor_si256(z, _mm256_srli_epi64::<27>(z)), m2);
        _mm256_xor_si256(z, _mm256_srli_epi64::<31>(z))
    }

    /// Exact u64→f64 for values < 2⁵³ (after the `>>11`): convert the
    /// low/high 32-bit halves via the 2⁵² mantissa-injection trick and
    /// recombine — both steps exact, so this equals the scalar
    /// `as f64` conversion bit-for-bit.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn u53_to_f64(v: __m256i) -> __m256d {
        let magic = _mm256_set1_epi64x(0x4330_0000_0000_0000u64 as i64); // 2^52 as bits
        let two52 = _mm256_set1_pd((1u64 << 52) as f64);
        let lo32 = _mm256_and_si256(v, _mm256_set1_epi64x(0xFFFF_FFFF));
        let hi = _mm256_srli_epi64::<32>(v);
        let lof = _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(lo32, magic)), two52);
        let hif = _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(hi, magic)), two52);
        _mm256_add_pd(_mm256_mul_pd(hif, _mm256_set1_pd((1u64 << 32) as f64)), lof)
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn synth_noise_avx(out: &mut [f32], noise: f32, state: u64) {
        let n = out.len();
        let g = SPLITMIX64_GAMMA;
        // Lane l of `odd`/`even` holds the counter of draw 2(k+l)+1 /
        // 2(k+l)+2 for the current gaussian block k..k+4.
        let mut odd = _mm256_set_epi64x(
            state.wrapping_add(g.wrapping_mul(7)) as i64,
            state.wrapping_add(g.wrapping_mul(5)) as i64,
            state.wrapping_add(g.wrapping_mul(3)) as i64,
            state.wrapping_add(g) as i64,
        );
        let mut even = _mm256_set_epi64x(
            state.wrapping_add(g.wrapping_mul(8)) as i64,
            state.wrapping_add(g.wrapping_mul(6)) as i64,
            state.wrapping_add(g.wrapping_mul(4)) as i64,
            state.wrapping_add(g.wrapping_mul(2)) as i64,
        );
        let step = _mm256_set1_epi64x(g.wrapping_mul(8) as i64);
        // x·2⁻⁵³ is exact for integer x < 2⁵³, hence equal to the
        // scalar division by 2⁵³ (also exact).
        let inv53 = _mm256_set1_pd(1.0 / (1u64 << 53) as f64);
        let eps = _mm256_set1_pd(1e-12);
        let neg2 = _mm256_set1_pd(-2.0);
        let two_pi = _mm256_set1_pd(2.0 * std::f64::consts::PI);
        let noise4 = _mm_set1_ps(noise);
        let clamp_lo = _mm_set1_ps(-0.5);
        let clamp_hi = _mm_set1_ps(1.5);
        let half = _mm_set1_ps(0.5);
        let mut u1buf = [0.0f64; 4];
        let mut u2buf = [0.0f64; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            let d1 = splitmix4(odd);
            let d2 = splitmix4(even);
            odd = _mm256_add_epi64(odd, step);
            even = _mm256_add_epi64(even, step);
            let u1 = _mm256_max_pd(
                _mm256_mul_pd(u53_to_f64(_mm256_srli_epi64::<11>(d1)), inv53),
                eps,
            );
            let u2 = _mm256_mul_pd(u53_to_f64(_mm256_srli_epi64::<11>(d2)), inv53);
            // ln/cos stay per-lane calls into the same libm the scalar
            // path uses — the price of bit-parity; everything around
            // them (sqrt, muls, casts) is exactly rounded SIMD.
            _mm256_storeu_pd(u1buf.as_mut_ptr(), u1);
            for v in &mut u1buf {
                *v = v.ln();
            }
            let r = _mm256_sqrt_pd(_mm256_mul_pd(neg2, _mm256_loadu_pd(u1buf.as_ptr())));
            _mm256_storeu_pd(u2buf.as_mut_ptr(), _mm256_mul_pd(two_pi, u2));
            for v in &mut u2buf {
                *v = v.cos();
            }
            let gauss = _mm256_cvtpd_ps(_mm256_mul_pd(r, _mm256_loadu_pd(u2buf.as_ptr())));
            let o = _mm_loadu_ps(out.as_ptr().add(i));
            let t = _mm_add_ps(o, _mm_mul_ps(noise4, gauss));
            let t = _mm_sub_ps(_mm_min_ps(_mm_max_ps(t, clamp_lo), clamp_hi), half);
            _mm_storeu_ps(out.as_mut_ptr().add(i), t);
            i += 4;
        }
        while i < n {
            out[i] = synth_one(out[i], noise, gauss_at(state, i as u64));
            i += 1;
        }
    }
}

// ====================================================== NEON kernels

#[cfg(target_arch = "aarch64")]
mod aarch64 {
    use std::arch::aarch64::*;

    use super::{scalar, Kernels};

    /// NEON is baseline on aarch64, so these wrappers are always sound
    /// there. Synthesis and the 8×8 transpose reuse the scalar fns: the
    /// SplitMix64 mix needs packed 64-bit multiplies NEON lacks, and
    /// the transpose is not hot enough to justify a zip network.
    pub(super) static NEON: Kernels = Kernels {
        name: "neon",
        axpy4_2,
        axpy4_1,
        axpy1_2,
        axpy1_1,
        axpy8_2,
        transpose8: scalar::transpose8,
        fixed_accumulate,
        synth_noise: scalar::synth_noise,
    };

    fn axpy4_2(c0: &mut [f32], c1: &mut [f32], b: [&[f32]; 4], x0: [f32; 4], x1: [f32; 4]) {
        // SAFETY: NEON is a baseline aarch64 target feature.
        unsafe { axpy4_2_neon(c0, c1, b, x0, x1) }
    }

    fn axpy4_1(c0: &mut [f32], b: [&[f32]; 4], x: [f32; 4]) {
        unsafe { axpy4_1_neon(c0, b, x) }
    }

    fn axpy1_2(c0: &mut [f32], c1: &mut [f32], b0: &[f32], x0: f32, x1: f32) {
        unsafe { axpy1_2_neon(c0, c1, b0, x0, x1) }
    }

    fn axpy1_1(c0: &mut [f32], b0: &[f32], x: f32) {
        unsafe { axpy1_1_neon(c0, b0, x) }
    }

    fn axpy8_2(c0: &mut [f32], c1: &mut [f32], b: [&[f32]; 8], x0: [f32; 8], x1: [f32; 8]) {
        unsafe { axpy8_2_neon(c0, c1, b, x0, x1) }
    }

    fn fixed_accumulate(acc: &mut [i128], delta: &[f32], w: f64, limit: f64, scale: f64) {
        unsafe { fixed_accumulate_neon(acc, delta, w, limit, scale) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy4_2_neon(
        c0: &mut [f32],
        c1: &mut [f32],
        b: [&[f32]; 4],
        x0: [f32; 4],
        x1: [f32; 4],
    ) {
        if x0 == [0.0; 4] && x1 == [0.0; 4] {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let (b0, b1, b2, b3) = (&b[0][..nn], &b[1][..nn], &b[2][..nn], &b[3][..nn]);
        let mut j = 0usize;
        while j + 4 <= nn {
            let v0 = vld1q_f32(b0.as_ptr().add(j));
            let v1 = vld1q_f32(b1.as_ptr().add(j));
            let v2 = vld1q_f32(b2.as_ptr().add(j));
            let v3 = vld1q_f32(b3.as_ptr().add(j));
            let mut a0 = vld1q_f32(c0.as_ptr().add(j));
            a0 = vfmaq_n_f32(a0, v0, x0[0]);
            a0 = vfmaq_n_f32(a0, v1, x0[1]);
            a0 = vfmaq_n_f32(a0, v2, x0[2]);
            a0 = vfmaq_n_f32(a0, v3, x0[3]);
            vst1q_f32(c0.as_mut_ptr().add(j), a0);
            let mut a1 = vld1q_f32(c1.as_ptr().add(j));
            a1 = vfmaq_n_f32(a1, v0, x1[0]);
            a1 = vfmaq_n_f32(a1, v1, x1[1]);
            a1 = vfmaq_n_f32(a1, v2, x1[2]);
            a1 = vfmaq_n_f32(a1, v3, x1[3]);
            vst1q_f32(c1.as_mut_ptr().add(j), a1);
            j += 4;
        }
        while j < nn {
            c0[j] += x0[0] * b0[j] + x0[1] * b1[j] + x0[2] * b2[j] + x0[3] * b3[j];
            c1[j] += x1[0] * b0[j] + x1[1] * b1[j] + x1[2] * b2[j] + x1[3] * b3[j];
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy8_2_neon(
        c0: &mut [f32],
        c1: &mut [f32],
        b: [&[f32]; 8],
        x0: [f32; 8],
        x1: [f32; 8],
    ) {
        if x0 == [0.0; 8] && x1 == [0.0; 8] {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let mut j = 0usize;
        while j + 4 <= nn {
            let mut a0 = vld1q_f32(c0.as_ptr().add(j));
            let mut a1 = vld1q_f32(c1.as_ptr().add(j));
            for t in 0..8 {
                let v = vld1q_f32(b[t][..nn].as_ptr().add(j));
                a0 = vfmaq_n_f32(a0, v, x0[t]);
                a1 = vfmaq_n_f32(a1, v, x1[t]);
            }
            vst1q_f32(c0.as_mut_ptr().add(j), a0);
            vst1q_f32(c1.as_mut_ptr().add(j), a1);
            j += 4;
        }
        while j < nn {
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            for t in 0..8 {
                s0 += x0[t] * b[t][j];
                s1 += x1[t] * b[t][j];
            }
            c0[j] += s0;
            c1[j] += s1;
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy4_1_neon(c0: &mut [f32], b: [&[f32]; 4], x: [f32; 4]) {
        if x == [0.0; 4] {
            return;
        }
        let nn = c0.len();
        let (b0, b1, b2, b3) = (&b[0][..nn], &b[1][..nn], &b[2][..nn], &b[3][..nn]);
        let mut j = 0usize;
        while j + 4 <= nn {
            let mut a = vld1q_f32(c0.as_ptr().add(j));
            a = vfmaq_n_f32(a, vld1q_f32(b0.as_ptr().add(j)), x[0]);
            a = vfmaq_n_f32(a, vld1q_f32(b1.as_ptr().add(j)), x[1]);
            a = vfmaq_n_f32(a, vld1q_f32(b2.as_ptr().add(j)), x[2]);
            a = vfmaq_n_f32(a, vld1q_f32(b3.as_ptr().add(j)), x[3]);
            vst1q_f32(c0.as_mut_ptr().add(j), a);
            j += 4;
        }
        while j < nn {
            c0[j] += x[0] * b0[j] + x[1] * b1[j] + x[2] * b2[j] + x[3] * b3[j];
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy1_2_neon(c0: &mut [f32], c1: &mut [f32], b: &[f32], x0: f32, x1: f32) {
        if x0 == 0.0 && x1 == 0.0 {
            return;
        }
        let nn = c0.len();
        let c1 = &mut c1[..nn];
        let b = &b[..nn];
        let mut j = 0usize;
        while j + 4 <= nn {
            let v = vld1q_f32(b.as_ptr().add(j));
            vst1q_f32(c0.as_mut_ptr().add(j), vfmaq_n_f32(vld1q_f32(c0.as_ptr().add(j)), v, x0));
            vst1q_f32(c1.as_mut_ptr().add(j), vfmaq_n_f32(vld1q_f32(c1.as_ptr().add(j)), v, x1));
            j += 4;
        }
        while j < nn {
            c0[j] += x0 * b[j];
            c1[j] += x1 * b[j];
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy1_1_neon(c0: &mut [f32], b: &[f32], x: f32) {
        if x == 0.0 {
            return;
        }
        let nn = c0.len();
        let b = &b[..nn];
        let mut j = 0usize;
        while j + 4 <= nn {
            let a = vfmaq_n_f32(vld1q_f32(c0.as_ptr().add(j)), vld1q_f32(b.as_ptr().add(j)), x);
            vst1q_f32(c0.as_mut_ptr().add(j), a);
            j += 4;
        }
        while j < nn {
            c0[j] += x * b[j];
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn fixed_accumulate_neon(
        acc: &mut [i128],
        delta: &[f32],
        w: f64,
        limit: f64,
        scale: f64,
    ) {
        let n = acc.len();
        assert!(delta.len() >= n);
        let wv = vdupq_n_f64(w);
        let lo = vdupq_n_f64(-limit);
        let hi = vdupq_n_f64(limit);
        let sc = vdupq_n_f64(scale);
        let mut buf = [0.0f64; 2];
        let mut i = 0usize;
        while i + 2 <= n {
            let d = vcvt_f64_f32(vld1_f32(delta.as_ptr().add(i)));
            let t = vmulq_f64(wv, d);
            let t = vminq_f64(vmaxq_f64(t, lo), hi);
            let t = vmulq_f64(t, sc);
            vst1q_f64(buf.as_mut_ptr(), t);
            acc[i] += buf[0] as i128;
            acc[i + 1] += buf[1] as i128;
            i += 2;
        }
        while i < n {
            let term = (w * delta[i] as f64).clamp(-limit, limit);
            acc[i] += (term * scale) as i128;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn resolve_honours_requests_and_falls_back_safely() {
        use SimdLevel::*;
        assert_eq!(resolve(None, Avx2), (Avx2, None));
        assert_eq!(resolve(Some("auto"), Neon), (Neon, None));
        assert_eq!(resolve(Some("1"), Scalar), (Scalar, None));
        assert_eq!(resolve(Some("0"), Avx2), (Scalar, None));
        assert_eq!(resolve(Some("scalar"), Avx2), (Scalar, None));
        assert_eq!(resolve(Some("AVX2"), Avx2), (Avx2, None));
        assert_eq!(resolve(Some(" neon "), Neon), (Neon, None));
        // An ISA the CPU lacks degrades to scalar with a warning, never
        // an unsupported table.
        let (l, warn) = resolve(Some("avx2"), Scalar);
        assert_eq!(l, SimdLevel::Scalar);
        assert!(warn.unwrap().contains("does not support"));
        let (l, warn) = resolve(Some("neon"), Avx2);
        assert_eq!(l, SimdLevel::Scalar);
        assert!(warn.is_some());
        // Unknown values keep the detected level.
        let (l, warn) = resolve(Some("sse9"), Avx2);
        assert_eq!(l, SimdLevel::Avx2);
        assert!(warn.unwrap().contains("unknown"));
    }

    #[test]
    fn dispatch_is_always_available() {
        let levels = available_levels();
        assert!(levels.contains(&SimdLevel::Scalar));
        assert!(levels.contains(&level()), "active level must be runnable");
        assert!(kernels_for(level()).is_some());
        // kernels() never fails, whatever the env said.
        let _ = kernels();
    }

    #[test]
    fn scalar_synth_noise_matches_sequential_rng_stream() {
        // The counter-mode pin: the kernel must reproduce exactly what
        // the old per-pixel loop drew from a sequential generator.
        let mut r = Rng::new(0x5eed_cafe);
        r.next_u64(); // mid-stream state, like after jitter draws
        let state = r.state();
        let base: Vec<f32> = (0..37).map(|i| (i % 11) as f32 * 0.09).collect();
        let mut got = base.clone();
        (SCALAR.synth_noise)(&mut got, 0.15, state);
        let mut rr = Rng::new(state);
        let want: Vec<f32> = base
            .iter()
            .map(|&t| (t + 0.15 * rr.next_gaussian()).clamp(-0.5, 1.5) - 0.5)
            .collect();
        assert!(
            got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits()),
            "scalar synth kernel diverged from the sequential RNG stream"
        );
    }

    #[test]
    fn every_available_dispatch_is_bit_identical_on_exact_kernels() {
        let mut rng = Rng::new(0x51D0);
        for lvl in available_levels() {
            let k = kernels_for(lvl).unwrap();
            for n in [0usize, 1, 3, 4, 5, 16, 63, 1024] {
                // synth_noise: bit-identical, including clamp edges.
                let base = rand_vec(&mut rng, n);
                let state = rng.next_u64();
                for noise in [0.0f32, 0.15, 3.0] {
                    let mut want = base.clone();
                    (SCALAR.synth_noise)(&mut want, noise, state);
                    let mut got = base.clone();
                    (k.synth_noise)(&mut got, noise, state);
                    let same = got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits());
                    assert!(same, "{} synth_noise n={n} noise={noise}", k.name);
                }
                // fixed_accumulate: exact i128 equality, clamp hit by
                // the huge-weight case.
                let delta = rand_vec(&mut rng, n);
                for w in [1.0f64, 37.0, 1e18] {
                    let limit = (1u64 << 60) as f64;
                    let scale = (1u64 << 40) as f64;
                    let mut want = vec![3i128; n];
                    (SCALAR.fixed_accumulate)(&mut want, &delta, w, limit, scale);
                    let mut got = vec![3i128; n];
                    (k.fixed_accumulate)(&mut got, &delta, w, limit, scale);
                    assert_eq!(want, got, "{} fixed_accumulate n={n} w={w}", k.name);
                }
            }
            // transpose8: pure data movement, exact.
            let src = rand_vec(&mut rng, 8 * 11);
            let mut want = vec![0.0f32; 8 * 13];
            scalar::transpose8(&src, 11, &mut want, 13);
            let mut got = vec![0.0f32; 8 * 13];
            (k.transpose8)(&src, 11, &mut got, 13);
            assert_eq!(want, got, "{} transpose8", k.name);
        }
    }

    fn assert_close(got: &[f32], want: &[f32], label: &str) {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-5 * w.abs().max(1.0);
            assert!((g - w).abs() <= tol, "{label}[{i}]: {g} vs {w}");
        }
    }

    #[test]
    fn every_available_dispatch_matches_scalar_axpy_within_tolerance() {
        let mut rng = Rng::new(0xA4B2);
        for lvl in available_levels() {
            let k = kernels_for(lvl).unwrap();
            for nn in [1usize, 4, 7, 8, 9, 16, 129, 512] {
                let rows8: Vec<Vec<f32>> = (0..8).map(|_| rand_vec(&mut rng, nn)).collect();
                let b8: [&[f32]; 8] = std::array::from_fn(|i| rows8[i].as_slice());
                let b4: [&[f32]; 4] = std::array::from_fn(|i| rows8[i].as_slice());
                let x0: [f32; 8] = std::array::from_fn(|i| (i as f32 - 3.5) * 0.3);
                let x1: [f32; 8] = std::array::from_fn(|i| (4.0 - i as f32) * 0.2);
                let x04: [f32; 4] = x0[..4].try_into().unwrap();
                let x14: [f32; 4] = x1[..4].try_into().unwrap();
                let base0 = rand_vec(&mut rng, nn);
                let base1 = rand_vec(&mut rng, nn);

                let run2 = |f: &dyn Fn(&mut [f32], &mut [f32])| {
                    let mut c0 = base0.clone();
                    let mut c1 = base1.clone();
                    f(&mut c0, &mut c1);
                    (c0, c1)
                };
                let (w0, w1) = run2(&|c0, c1| (SCALAR.axpy4_2)(c0, c1, b4, x04, x14));
                let (g0, g1) = run2(&|c0, c1| (k.axpy4_2)(c0, c1, b4, x04, x14));
                assert_close(&g0, &w0, &format!("{} axpy4_2 nn={nn} c0", k.name));
                assert_close(&g1, &w1, &format!("{} axpy4_2 nn={nn} c1", k.name));

                let (w0, w1) = run2(&|c0, c1| (SCALAR.axpy8_2)(c0, c1, b8, x0, x1));
                let (g0, g1) = run2(&|c0, c1| (k.axpy8_2)(c0, c1, b8, x0, x1));
                assert_close(&g0, &w0, &format!("{} axpy8_2 nn={nn} c0", k.name));
                assert_close(&g1, &w1, &format!("{} axpy8_2 nn={nn} c1", k.name));

                let (w0, w1) = run2(&|c0, c1| (SCALAR.axpy1_2)(c0, c1, &rows8[0], 0.7, -1.3));
                let (g0, g1) = run2(&|c0, c1| (k.axpy1_2)(c0, c1, &rows8[0], 0.7, -1.3));
                assert_close(&g0, &w0, &format!("{} axpy1_2 nn={nn} c0", k.name));
                assert_close(&g1, &w1, &format!("{} axpy1_2 nn={nn} c1", k.name));

                let mut w = base0.clone();
                (SCALAR.axpy4_1)(&mut w, b4, x04);
                let mut g = base0.clone();
                (k.axpy4_1)(&mut g, b4, x04);
                assert_close(&g, &w, &format!("{} axpy4_1 nn={nn}", k.name));

                let mut w = base0.clone();
                (SCALAR.axpy1_1)(&mut w, &rows8[0], -0.4);
                let mut g = base0.clone();
                (k.axpy1_1)(&mut g, &rows8[0], -0.4);
                assert_close(&g, &w, &format!("{} axpy1_1 nn={nn}", k.name));
            }
            // Zero multipliers skip — the accumulators must be
            // untouched on every path.
            let b0 = rand_vec(&mut rng, 16);
            let b: [&[f32]; 4] = [&b0, &b0, &b0, &b0];
            let before = rand_vec(&mut rng, 16);
            let mut c0 = before.clone();
            let mut c1 = before.clone();
            (k.axpy4_2)(&mut c0, &mut c1, b, [0.0; 4], [0.0; 4]);
            assert_eq!(c0, before, "{} zero-skip c0", k.name);
            assert_eq!(c1, before, "{} zero-skip c1", k.name);
        }
    }

    #[test]
    fn fixed_accumulate_ignores_delta_tail_beyond_acc() {
        // The striped reduce hands each stripe a delta slice that may
        // be longer than the stripe; only acc.len() elements count.
        for lvl in available_levels() {
            let k = kernels_for(lvl).unwrap();
            let delta = [0.5f32; 10];
            let mut acc = vec![0i128; 6];
            (k.fixed_accumulate)(&mut acc, &delta, 2.0, 1e18, 4.0);
            assert!(acc.iter().all(|&a| a == 4), "{}: {acc:?}", k.name);
        }
    }
}
