//! `artifacts/manifest.json` — the L2↔L3 contract.
//!
//! The AOT pipeline (python/compile/aot.py) records every lowered
//! executable, dataset spec, weight file, and the zoo inventory here; the
//! rust side never guesses shapes — everything is read from the manifest.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};
use crate::util::Json;

use super::backend::BackendKind;

/// A dataset spec (paper Table 1 row), synthetic substitute.
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub name: String,
    pub group: String,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub real_train_n: usize,
    pub real_test_n: usize,
    pub noise: f32,
    pub jitter: i64,
    pub template_file: String,
}

impl DatasetInfo {
    /// Per-example element count (H*W*C).
    pub fn example_len(&self) -> usize {
        self.height * self.width * self.channels
    }
}

/// A zoo inventory row (paper Table 2).
#[derive(Clone, Debug)]
pub struct ZooInfo {
    pub variant: String,
    pub family: String,
    pub description: String,
    pub canonical_dataset: String,
    pub num_params: usize,
    pub head_size: usize,
    pub feature_extract: bool,
    pub finetune: bool,
}

/// One AOT-lowered model@dataset bundle.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub id: String,
    pub model: String,
    pub dataset: String,
    pub num_params: usize,
    pub head_size: usize,
    /// entry name (e.g. "train_sgd_full", "eval") -> HLO file name.
    pub entries: BTreeMap<String, String>,
    pub agg_file: String,
    pub init_file: String,
    pub pretrained_file: Option<String>,
}

/// Parsed manifest + the directory it lives in.
///
/// Describes the execution environment for either backend: loaded from
/// `artifacts/manifest.json` for PJRT, or synthesised in memory by
/// [`Manifest::native`] (procedural datasets, native MLP zoo, no files).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Which backend this manifest describes.
    pub backend: BackendKind,
    pub dir: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub k_pad: usize,
    pub datasets: BTreeMap<String, DatasetInfo>,
    pub zoo: BTreeMap<String, ZooInfo>,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// The in-memory manifest of the native backend: procedural datasets
    /// and the native MLP zoo — no files, no Python, no artifacts.
    pub fn native() -> Self {
        super::native::native_manifest()
    }

    /// Load the AOT manifest from `dir` when present (and the `pjrt`
    /// feature is compiled in); fall back to the native manifest. A
    /// present-but-unreadable manifest falls back loudly on stderr.
    pub fn load_or_native(dir: impl AsRef<Path>) -> Self {
        #[cfg(feature = "pjrt")]
        if dir.as_ref().join("manifest.json").exists() {
            match Self::load(&dir) {
                Ok(m) => return m,
                Err(e) => eprintln!(
                    "warning: ignoring unreadable manifest in {:?} ({e}); \
                     falling back to the native backend",
                    dir.as_ref()
                ),
            }
        }
        let _ = dir;
        Self::native()
    }

    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let mut datasets = BTreeMap::new();
        for (name, d) in v.req("datasets")?.as_obj()? {
            datasets.insert(
                name.clone(),
                DatasetInfo {
                    name: name.clone(),
                    group: d.req("group")?.as_str()?.to_string(),
                    height: d.req("height")?.as_usize()?,
                    width: d.req("width")?.as_usize()?,
                    channels: d.req("channels")?.as_usize()?,
                    num_classes: d.req("num_classes")?.as_usize()?,
                    train_n: d.req("train_n")?.as_usize()?,
                    test_n: d.req("test_n")?.as_usize()?,
                    real_train_n: d.req("real_train_n")?.as_usize()?,
                    real_test_n: d.req("real_test_n")?.as_usize()?,
                    noise: d.req("noise")?.as_f64()? as f32,
                    jitter: d.req("jitter")?.as_f64()? as i64,
                    template_file: d.req("template_file")?.as_str()?.to_string(),
                },
            );
        }

        let mut zoo = BTreeMap::new();
        for (name, z) in v.req("zoo")?.as_obj()? {
            zoo.insert(
                name.clone(),
                ZooInfo {
                    variant: name.clone(),
                    family: z.req("family")?.as_str()?.to_string(),
                    description: z.req("description")?.as_str()?.to_string(),
                    canonical_dataset: z
                        .req("canonical_dataset")?
                        .as_str()?
                        .to_string(),
                    num_params: z.req("num_params")?.as_usize()?,
                    head_size: z.req("head_size")?.as_usize()?,
                    feature_extract: matches!(
                        z.req("feature_extract")?,
                        Json::Bool(true)
                    ),
                    finetune: matches!(z.req("finetune")?, Json::Bool(true)),
                },
            );
        }

        let mut artifacts = Vec::new();
        for a in v.req("artifacts")?.as_arr()? {
            let mut entries = BTreeMap::new();
            for (k, f) in a.req("entries")?.as_obj()? {
                entries.insert(k.clone(), f.as_str()?.to_string());
            }
            let pre = a.req("pretrained_file")?;
            artifacts.push(ArtifactInfo {
                id: a.req("id")?.as_str()?.to_string(),
                model: a.req("model")?.as_str()?.to_string(),
                dataset: a.req("dataset")?.as_str()?.to_string(),
                num_params: a.req("num_params")?.as_usize()?,
                head_size: a.req("head_size")?.as_usize()?,
                entries,
                agg_file: a.req("agg_file")?.as_str()?.to_string(),
                init_file: a.req("init_file")?.as_str()?.to_string(),
                pretrained_file: if pre.is_null() {
                    None
                } else {
                    Some(pre.as_str()?.to_string())
                },
            });
        }

        Ok(Self {
            backend: BackendKind::Pjrt,
            dir,
            train_batch: v.req("train_batch")?.as_usize()?,
            eval_batch: v.req("eval_batch")?.as_usize()?,
            k_pad: v.req("k_pad")?.as_usize()?,
            datasets,
            zoo,
            artifacts,
        })
    }

    /// Find the artifact bundle for `model` @ `dataset`.
    pub fn artifact(&self, model: &str, dataset: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.dataset == dataset)
            .with_context(|| {
                let have: Vec<_> =
                    self.artifacts.iter().map(|a| a.id.as_str()).collect();
                format!(
                    "no artifact for {model}@{dataset}; built: {have:?} \
                     (extend ARTIFACTS in python/compile/aot.py)"
                )
            })
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetInfo> {
        self.datasets.get(name).with_context(|| {
            let have: Vec<_> = self.datasets.keys().collect();
            format!("unknown dataset {name}; available: {have:?}")
        })
    }

    /// Absolute path of a file referenced by the manifest.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Read a raw little-endian f32 file (weights, templates).
    pub fn read_f32(&self, file: &str) -> Result<Vec<f32>> {
        let path = self.path(file);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: length {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.datasets.len(), 9, "paper Table 1 has 9 dataset rows");
        assert_eq!(m.zoo.len(), 9, "zoo has 9 variants");
        assert!(!m.artifacts.is_empty());
        // Every referenced file exists.
        for a in &m.artifacts {
            for f in a.entries.values() {
                assert!(m.path(f).exists(), "missing {f}");
            }
            assert!(m.path(&a.agg_file).exists());
            assert!(m.path(&a.init_file).exists());
        }
        for d in m.datasets.values() {
            assert!(m.path(&d.template_file).exists());
        }
    }

    #[test]
    fn init_weights_match_param_count() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let m = Manifest::load(dir).unwrap();
        for a in &m.artifacts {
            let w = m.read_f32(&a.init_file).unwrap();
            assert_eq!(w.len(), a.num_params, "{}", a.id);
            if let Some(pre) = &a.pretrained_file {
                let w = m.read_f32(pre).unwrap();
                assert_eq!(w.len(), a.num_params, "{} pretrained", a.id);
            }
        }
    }

    #[test]
    fn missing_dir_is_actionable_error() {
        let err = Manifest::load("/nonexistent-ferrisfl").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn native_manifest_is_self_consistent() {
        let m = Manifest::native();
        assert_eq!(m.backend, BackendKind::Native);
        assert!(!m.datasets.is_empty());
        assert!(!m.zoo.is_empty());
        assert!(!m.artifacts.is_empty());
        for a in &m.artifacts {
            assert!(m.datasets.contains_key(&a.dataset), "{}", a.id);
            assert!(m.zoo.contains_key(&a.model), "{}", a.id);
            assert!(a.num_params > a.head_size, "{}", a.id);
        }
        // Procedural datasets carry no template files.
        for d in m.datasets.values() {
            assert!(d.template_file.is_empty(), "{}", d.name);
        }
        let art = m.artifact("mlp-s", "synth-mnist").unwrap();
        assert_eq!(art.id, "mlp-s_synth-mnist");
        assert!(m.artifact("mlp-s", "nope").is_err());
    }

    #[test]
    fn load_or_native_falls_back() {
        let m = Manifest::load_or_native("/nonexistent-ferrisfl");
        assert_eq!(m.backend, BackendKind::Native);
    }
}
