//! Global marshalling/memory counters for the runtime.
//!
//! The paper's Fig 10 tracks bytes allocated / freed / in-use on the
//! accelerator through training. PJRT CPU does not expose an allocator
//! hook through the `xla` crate, so we count what the coordinator
//! actually moves: bytes of literals marshalled host→device (alloc) and
//! device→host results dropped after consumption (free). Relaxed atomics
//! — these are observability counters, not synchronisation.

use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATED: AtomicU64 = AtomicU64::new(0);
static FREED: AtomicU64 = AtomicU64::new(0);
static EXECUTIONS: AtomicU64 = AtomicU64::new(0);

/// Record `n` bytes marshalled into device buffers.
pub fn add_allocated(n: u64) {
    ALLOCATED.fetch_add(n, Ordering::Relaxed);
}

/// Record `n` bytes of device results released.
pub fn add_freed(n: u64) {
    FREED.fetch_add(n, Ordering::Relaxed);
}

/// Record one executable invocation.
pub fn add_execution() {
    EXECUTIONS.fetch_add(1, Ordering::Relaxed);
}

/// Snapshot of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemSnapshot {
    pub allocated: u64,
    pub freed: u64,
    pub executions: u64,
}

impl MemSnapshot {
    /// Bytes currently accounted as live (allocated - freed).
    pub fn in_use(&self) -> u64 {
        self.allocated.saturating_sub(self.freed)
    }

    /// Delta between two snapshots (self - earlier).
    pub fn since(&self, earlier: &MemSnapshot) -> MemSnapshot {
        MemSnapshot {
            allocated: self.allocated - earlier.allocated,
            freed: self.freed - earlier.freed,
            executions: self.executions - earlier.executions,
        }
    }
}

/// Take a snapshot of the global counters.
pub fn snapshot() -> MemSnapshot {
    MemSnapshot {
        allocated: ALLOCATED.load(Ordering::Relaxed),
        freed: FREED.load(Ordering::Relaxed),
        executions: EXECUTIONS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let before = snapshot();
        add_allocated(100);
        add_freed(40);
        add_execution();
        let delta = snapshot().since(&before);
        assert_eq!(delta.allocated, 100);
        assert_eq!(delta.freed, 40);
        assert_eq!(delta.executions, 1);
        assert_eq!(delta.in_use(), 60);
    }
}
