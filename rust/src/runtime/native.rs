//! Pure-rust native CPU backend — hermetic execution for every runtime op.
//!
//! Implements [`ModelExecutor`] with an MLP forward/backward engine that
//! needs no Python, XLA, or AOT artifacts: parameters are a flat `f32`
//! buffer (same ABI as the PJRT path), initialisation is deterministic
//! per (model, dataset), and "pretrained" weights are synthesised by a
//! short deterministic burn-in. Conv-family zoo names (lenet5, cnn-m)
//! execute as MLP surrogates of comparable capacity — the FL control
//! plane above the executor is identical either way.
//!
//! Parallelism: local training already fans out across agents on the
//! entrypoint's `util::threadpool::WorkerPool` (one executor per worker
//! thread); the server-side FedAvg aggregation here additionally shards
//! the parameter range across a process-wide `WorkerPool` once `K × P`
//! is large enough to amortise the fan-out.
//!
//! Parameter layout per layer `l` (fan_in `i`, fan_out `o`):
//! `W_l` row-major `[o × i]`, then `b_l` `[o]`; the classifier head is
//! the final layer, so featext freezing is "tail of the flat buffer
//! trainable, rest frozen" — matching the AOT artifact convention.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::error::{bail, Context, Result};
use crate::util::{Rng, WorkerPool};

use super::backend::{AdamState, BackendKind, EvalStats, ModelExecutor, StepStats};
use super::manifest::{ArtifactInfo, DatasetInfo, Manifest, ZooInfo};
use super::stats;

/// Default train batch size of the native manifest.
pub const TRAIN_BATCH: usize = 32;
/// Default eval batch size of the native manifest.
pub const EVAL_BATCH: usize = 128;
/// Aggregations smaller than this many elements (K × P) run serially.
const PAR_MIN_ELEMS: usize = 1 << 20;
/// SGD steps of the deterministic pretraining burn-in.
const PRETRAIN_STEPS: usize = 48;
/// Learning rate of the pretraining burn-in.
const PRETRAIN_LR: f32 = 0.1;
/// Dataset seed used for pretraining data (independent of run seeds).
const PRETRAIN_SEED: u64 = 0x5eed;

/// FNV-1a, for deterministic per-(model, dataset) init streams.
pub(crate) fn fnv1a(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The native model zoo: variant name -> hidden layer widths.
///
/// Kept in sync with [`native_manifest`]; conv-family names map to MLP
/// surrogates so configs/benches written for the artifact zoo run
/// unchanged on the native backend.
pub fn hidden_layers(model: &str) -> Result<&'static [usize]> {
    Ok(match model {
        "micronet-05" => &[16],
        "mlp-s" => &[64],
        "mlp-m" => &[128, 64],
        "lenet5" => &[120, 84],
        "cnn-m" => &[256, 128],
        other => bail!(
            "native backend has no model {other:?} \
             (micronet-05 | mlp-s | mlp-m | lenet5 | cnn-m)"
        ),
    })
}

/// Flat parameter count of an MLP `input -> hidden... -> classes`.
pub fn param_count(input_dim: usize, hidden: &[usize], classes: usize) -> usize {
    layer_dims(input_dim, hidden, classes)
        .iter()
        .map(|&(i, o)| (i + 1) * o)
        .sum()
}

/// Head (final-layer) parameter count.
pub fn head_count(hidden: &[usize], classes: usize) -> usize {
    let last = hidden.last().copied().unwrap_or(0);
    (last + 1) * classes
}

fn layer_dims(input_dim: usize, hidden: &[usize], classes: usize) -> Vec<(usize, usize)> {
    let mut dims = Vec::with_capacity(hidden.len() + 1);
    let mut fan_in = input_dim;
    for &h in hidden {
        dims.push((fan_in, h));
        fan_in = h;
    }
    dims.push((fan_in, classes));
    dims
}

fn pool() -> &'static Mutex<WorkerPool> {
    static POOL: OnceLock<Mutex<WorkerPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Mutex::new(WorkerPool::new(n.clamp(2, 8)))
    })
}

/// A pure-rust MLP executor for one model@dataset.
pub struct NativeExecutor {
    model: String,
    dataset: String,
    /// (fan_in, fan_out) per layer; last layer is the classifier head.
    dims: Vec<(usize, usize)>,
    input_dim: usize,
    classes: usize,
    num_params: usize,
    head_size: usize,
    train_batch: usize,
    eval_batch: usize,
    optimizer: String,
    featext: bool,
    /// Environment handle, needed lazily by the pretraining burn-in.
    manifest: Arc<Manifest>,
    pretrained_cache: RefCell<Option<Vec<f32>>>,
}

impl NativeExecutor {
    /// Build the executor for `model@dataset` described by `manifest`.
    pub fn load(
        manifest: &Arc<Manifest>,
        model: &str,
        dataset: &str,
        optimizer: &str,
        mode: &str,
    ) -> Result<Self> {
        if !matches!(optimizer, "sgd" | "adam") {
            bail!("native backend: optimizer must be sgd or adam, got {optimizer:?}");
        }
        let featext = match mode {
            "full" => false,
            "featext" => true,
            other => bail!("native backend: mode must be full or featext, got {other:?}"),
        };
        let ds = manifest.dataset(dataset)?;
        let hidden = hidden_layers(model)?;
        let input_dim = ds.example_len();
        let classes = ds.num_classes;
        let dims = layer_dims(input_dim, hidden, classes);
        Ok(Self {
            model: model.to_string(),
            dataset: dataset.to_string(),
            num_params: param_count(input_dim, hidden, classes),
            head_size: head_count(hidden, classes),
            dims,
            input_dim,
            classes,
            train_batch: manifest.train_batch,
            eval_batch: manifest.eval_batch,
            optimizer: optimizer.to_string(),
            featext,
            manifest: Arc::clone(manifest),
            pretrained_cache: RefCell::new(None),
        })
    }

    /// Forward pass over `n` examples. Returns hidden post-relu
    /// activations (one buffer per hidden layer) plus the logits.
    fn forward(&self, params: &[f32], x: &[f32], n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.dims.len() - 1);
        let mut offset = 0usize;
        let mut logits = Vec::new();
        for (l, &(fan_in, fan_out)) in self.dims.iter().enumerate() {
            let w = &params[offset..offset + fan_out * fan_in];
            let b = &params[offset + fan_out * fan_in..offset + fan_out * (fan_in + 1)];
            offset += fan_out * (fan_in + 1);
            let last = l + 1 == self.dims.len();
            let mut out = vec![0.0f32; n * fan_out];
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            for i in 0..n {
                let xi = &input[i * fan_in..(i + 1) * fan_in];
                let zi = &mut out[i * fan_out..(i + 1) * fan_out];
                for (o, z) in zi.iter_mut().enumerate() {
                    let row = &w[o * fan_in..(o + 1) * fan_in];
                    let mut acc = b[o];
                    for (rw, rx) in row.iter().zip(xi) {
                        acc += rw * rx;
                    }
                    *z = if last { acc } else { acc.max(0.0) };
                }
            }
            if last {
                logits = out;
            } else {
                acts.push(out);
            }
        }
        (acts, logits)
    }

    /// Softmax cross-entropy over `n` logits rows: per-example loss and
    /// correctness, plus (optionally) `dz = (softmax - onehot) * scale`.
    fn softmax_xent(
        &self,
        logits: &[f32],
        y: &[i32],
        n: usize,
        dz_scale: Option<f32>,
    ) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let c = self.classes;
        let mut losses = vec![0.0f32; n];
        let mut correct = vec![false; n];
        let mut dz = if dz_scale.is_some() {
            vec![0.0f32; n * c]
        } else {
            Vec::new()
        };
        for i in 0..n {
            let z = &logits[i * c..(i + 1) * c];
            let mut max = f32::NEG_INFINITY;
            let mut argmax = 0usize;
            for (j, &v) in z.iter().enumerate() {
                if v > max {
                    max = v;
                    argmax = j;
                }
            }
            let mut sum = 0.0f32;
            for &v in z {
                sum += (v - max).exp();
            }
            let lse = max + sum.ln();
            let label = y[i] as usize;
            losses[i] = lse - z[label];
            correct[i] = argmax == label;
            if let Some(scale) = dz_scale {
                let d = &mut dz[i * c..(i + 1) * c];
                for (j, &v) in z.iter().enumerate() {
                    d[j] = ((v - lse).exp() - if j == label { 1.0 } else { 0.0 }) * scale;
                }
            }
        }
        (losses, correct, dz)
    }

    /// Backward pass: gradient of the mean batch loss wrt `params`.
    /// Under featext only the final (head) layer's gradient is produced;
    /// frozen entries stay zero.
    fn backward(
        &self,
        params: &[f32],
        x: &[f32],
        acts: &[Vec<f32>],
        dz_last: Vec<f32>,
        n: usize,
        featext: bool,
    ) -> Vec<f32> {
        let mut grad = vec![0.0f32; self.num_params];
        // Per-layer parameter offsets.
        let mut offsets = Vec::with_capacity(self.dims.len());
        let mut off = 0usize;
        for &(fan_in, fan_out) in &self.dims {
            offsets.push(off);
            off += fan_out * (fan_in + 1);
        }
        let mut dz = dz_last;
        for l in (0..self.dims.len()).rev() {
            let (fan_in, fan_out) = self.dims[l];
            let off = offsets[l];
            let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
            {
                let (gw, gb) =
                    grad[off..off + fan_out * (fan_in + 1)].split_at_mut(fan_out * fan_in);
                for i in 0..n {
                    let xi = &input[i * fan_in..(i + 1) * fan_in];
                    let di = &dz[i * fan_out..(i + 1) * fan_out];
                    for (o, &d) in di.iter().enumerate() {
                        if d != 0.0 {
                            let row = &mut gw[o * fan_in..(o + 1) * fan_in];
                            for (g, &v) in row.iter_mut().zip(xi) {
                                *g += d * v;
                            }
                        }
                        gb[o] += d;
                    }
                }
            }
            if l == 0 || (featext && l + 1 == self.dims.len()) {
                break;
            }
            // da_prev = W^T dz, masked by relu' (prev activation > 0).
            let w = &params[off..off + fan_out * fan_in];
            let prev = &acts[l - 1];
            let mut dprev = vec![0.0f32; n * fan_in];
            for i in 0..n {
                let di = &dz[i * fan_out..(i + 1) * fan_out];
                let dpi = &mut dprev[i * fan_in..(i + 1) * fan_in];
                for (o, &d) in di.iter().enumerate() {
                    if d != 0.0 {
                        let row = &w[o * fan_in..(o + 1) * fan_in];
                        for (dp, &rw) in dpi.iter_mut().zip(row) {
                            *dp += d * rw;
                        }
                    }
                }
                let ai = &prev[i * fan_in..(i + 1) * fan_in];
                for (dp, &a) in dpi.iter_mut().zip(ai) {
                    if a <= 0.0 {
                        *dp = 0.0;
                    }
                }
            }
            dz = dprev;
        }
        grad
    }

    /// Shared step core: forward + loss + backward, returning the batch
    /// gradient and stats. `featext` controls gradient masking.
    fn batch_grad(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        featext: bool,
    ) -> Result<(Vec<f32>, StepStats)> {
        let n = self.train_batch;
        self.check_batch(params, x, y, n)?;
        let (acts, logits) = self.forward(params, x, n);
        let (losses, correct, dz) = self.softmax_xent(&logits, y, n, Some(1.0 / n as f32));
        let grad = self.backward(params, x, &acts, dz, n, featext);
        let act_bytes = (acts.iter().map(|a| a.len()).sum::<usize>() + logits.len()) * 4;
        stats::add_execution();
        stats::add_allocated(act_bytes as u64);
        stats::add_freed(act_bytes as u64);
        Ok((
            grad,
            StepStats {
                loss: losses.iter().sum::<f32>() / n as f32,
                hits: correct.iter().filter(|&&c| c).count() as f32,
            },
        ))
    }

    fn check_batch(&self, params: &[f32], x: &[f32], y: &[i32], n: usize) -> Result<()> {
        if params.len() != self.num_params {
            bail!(
                "{}@{}: params has {} entries, executor wants {}",
                self.model,
                self.dataset,
                params.len(),
                self.num_params
            );
        }
        if x.len() < n * self.input_dim || y.len() < n {
            bail!(
                "{}@{}: batch holds {} examples / {} labels, step wants {n}",
                self.model,
                self.dataset,
                x.len() / self.input_dim.max(1),
                y.len()
            );
        }
        for &label in &y[..n] {
            if label < 0 || label as usize >= self.classes {
                bail!("label {label} out of range for {} classes", self.classes);
            }
        }
        Ok(())
    }

    /// First flat index the optimizer may touch (featext freezes the
    /// backbone, i.e. everything before the head).
    fn trainable_from(&self, featext: bool) -> usize {
        if featext {
            self.num_params - self.head_size
        } else {
            0
        }
    }

    /// A full-mode SGD step, independent of the executor's own mode —
    /// used by the pretraining burn-in.
    fn sgd_step(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        featext: bool,
    ) -> Result<StepStats> {
        let (grad, step) = self.batch_grad(params, x, y, featext)?;
        let from = self.trainable_from(featext);
        for (p, g) in params[from..].iter_mut().zip(&grad[from..]) {
            *p -= lr * g;
        }
        Ok(step)
    }
}

impl ModelExecutor for NativeExecutor {
    fn backend(&self) -> BackendKind {
        BackendKind::Native
    }

    fn num_params(&self) -> usize {
        self.num_params
    }

    fn head_size(&self) -> usize {
        self.head_size
    }

    fn train_batch_size(&self) -> usize {
        self.train_batch
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }

    fn optimizer(&self) -> &str {
        &self.optimizer
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        // He-normal weights, zero biases, seeded by (model, dataset) so
        // every worker/agent derives the identical W^0.
        let mut rng = Rng::new(fnv1a(&format!("{}@{}", self.model, self.dataset)) ^ 0x1217);
        let mut params = Vec::with_capacity(self.num_params);
        for &(fan_in, fan_out) in &self.dims {
            let std = (2.0 / fan_in as f32).sqrt();
            for _ in 0..fan_out * fan_in {
                params.push(rng.next_gaussian() * std);
            }
            params.resize(params.len() + fan_out, 0.0);
        }
        Ok(params)
    }

    fn pretrained_params(&self) -> Result<Vec<f32>> {
        if let Some(p) = self.pretrained_cache.borrow().as_ref() {
            return Ok(p.clone());
        }
        // Deterministic burn-in: a short full-mode SGD run over the
        // canonical synthetic data stands in for the zoo's published
        // pretrained checkpoints. The dataset is only built here, so
        // scratch-mode runs never pay for it.
        let data = crate::datasets::Dataset::load(&self.manifest, &self.dataset, PRETRAIN_SEED)
            .with_context(|| {
                format!("loading pretrain data for {}@{}", self.model, self.dataset)
            })?;
        let mut params = self.init_params()?;
        let b = self.train_batch;
        let n = data.num_train();
        for step in 0..PRETRAIN_STEPS {
            let idx: Vec<usize> = (0..b).map(|i| (step * b + i) % n).collect();
            let batch = data.batch(crate::datasets::Split::Train, &idx);
            self.sgd_step(&mut params, &batch.x, &batch.y, PRETRAIN_LR, false)?;
        }
        *self.pretrained_cache.borrow_mut() = Some(params.clone());
        Ok(params)
    }

    fn train_step_sgd(
        &self,
        params: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<StepStats> {
        self.sgd_step(params, x, y, lr, self.featext)
    }

    fn train_step_adam(
        &self,
        params: &mut Vec<f32>,
        state: &mut AdamState,
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<StepStats> {
        if state.m.len() != self.num_params || state.v.len() != self.num_params {
            bail!(
                "adam state sized {} but executor has {} params",
                state.m.len(),
                self.num_params
            );
        }
        let (grad, step) = self.batch_grad(params, x, y, self.featext)?;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        state.t += 1.0;
        let bc1 = 1.0 - b1.powf(state.t);
        let bc2 = 1.0 - b2.powf(state.t);
        let from = self.trainable_from(self.featext);
        for i in from..self.num_params {
            let g = grad[i];
            state.m[i] = b1 * state.m[i] + (1.0 - b1) * g;
            state.v[i] = b2 * state.v[i] + (1.0 - b2) * g * g;
            let mhat = state.m[i] / bc1;
            let vhat = state.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
        Ok(step)
    }

    fn eval_batch(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        n_valid: usize,
    ) -> Result<EvalStats> {
        if n_valid > self.eval_batch {
            bail!("eval batch of {n_valid} exceeds eval_batch={}", self.eval_batch);
        }
        self.check_batch(params, x, y, n_valid)?;
        // No padding needed on the host: just score the valid prefix
        // (the mask semantics of the PJRT graph, computed directly).
        let (_, logits) = self.forward(params, &x[..n_valid * self.input_dim], n_valid);
        let (losses, correct, _) = self.softmax_xent(&logits, y, n_valid, None);
        stats::add_execution();
        Ok(EvalStats {
            loss_sum: losses.iter().map(|&l| l as f64).sum(),
            correct: correct.iter().filter(|&&c| c).count() as f64,
            count: n_valid as f64,
        })
    }

    fn aggregate(
        &self,
        global: &[f32],
        deltas: &[Vec<f32>],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let k = deltas.len();
        if k != weights.len() {
            bail!("{k} deltas but {} weights", weights.len());
        }
        for (i, d) in deltas.iter().enumerate() {
            if d.len() != global.len() {
                bail!("delta {i} has {} params, global has {}", d.len(), global.len());
            }
        }
        let p = global.len();
        if k == 0 {
            return Ok(global.to_vec());
        }
        if k * p < PAR_MIN_ELEMS {
            return Ok(weighted_sum_range(global, deltas, weights, 0, p));
        }
        // Shard the parameter range across the process-wide pool. The
        // pool's jobs are 'static, so the borrowed inputs are copied
        // into Arcs here — one extra pass over memory the f64-accumulate
        // loop reads K times anyway (only paid above PAR_MIN_ELEMS).
        let pool = pool().lock().expect("aggregation pool poisoned");
        let jobs_n = pool.size().min(p);
        let chunk = p.div_ceil(jobs_n);
        let global = Arc::new(global.to_vec());
        let deltas = Arc::new(deltas.to_vec());
        let weights = Arc::new(weights.to_vec());
        let jobs: Vec<_> = (0..jobs_n)
            .map(|j| {
                let global = Arc::clone(&global);
                let deltas = Arc::clone(&deltas);
                let weights = Arc::clone(&weights);
                move |_wid: usize| {
                    let lo = (j * chunk).min(global.len());
                    let hi = ((j + 1) * chunk).min(global.len());
                    weighted_sum_range(&global, &deltas, &weights, lo, hi)
                }
            })
            .collect();
        let parts = pool.run(jobs);
        let mut out = Vec::with_capacity(p);
        for part in parts {
            out.extend_from_slice(&part);
        }
        Ok(out)
    }
}

/// `out[j] = global[j] + Σ_i w_i · delta_i[j]` over `[lo, hi)`,
/// accumulated in f64 so the result agrees with `fedavg_host` to well
/// under 1e-5 regardless of summation order.
fn weighted_sum_range(
    global: &[f32],
    deltas: &[Vec<f32>],
    weights: &[f32],
    lo: usize,
    hi: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(hi - lo);
    for j in lo..hi {
        let mut acc = global[j] as f64;
        for (d, &w) in deltas.iter().zip(weights) {
            acc += w as f64 * d[j] as f64;
        }
        out.push(acc as f32);
    }
    out
}

fn native_dataset(
    name: &str,
    group: &str,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    real: (usize, usize),
    noise: f32,
) -> DatasetInfo {
    DatasetInfo {
        name: name.to_string(),
        group: group.to_string(),
        height: h,
        width: w,
        channels: c,
        num_classes: classes,
        train_n: 2048,
        test_n: 512,
        real_train_n: real.0,
        real_test_n: real.1,
        noise,
        jitter: 2,
        // Empty => Dataset::load synthesises class templates procedurally.
        template_file: String::new(),
    }
}

/// Build the in-memory manifest of the native backend: procedural
/// datasets, the native MLP zoo, and one "artifact" per runnable
/// model@dataset pair (entry files are empty — nothing is on disk).
pub fn native_manifest() -> Manifest {
    let datasets: Vec<DatasetInfo> = vec![
        native_dataset("synth-mnist", "MNIST", 28, 28, 1, 10, (60_000, 10_000), 0.15),
        native_dataset("synth-fmnist", "FashionMNIST", 28, 28, 1, 10, (60_000, 10_000), 0.2),
        native_dataset("synth-cifar10", "CIFAR", 32, 32, 3, 10, (50_000, 10_000), 0.2),
        native_dataset("synth-cifar100", "CIFAR", 32, 32, 3, 100, (50_000, 10_000), 0.2),
    ];
    let zoo_rows: &[(&str, &str, &str, &str)] = &[
        ("micronet-05", "MicroNet", "tiny MLP head for federated transfer", "synth-mnist"),
        ("mlp-s", "MLP", "one hidden layer, MNIST-scale", "synth-mnist"),
        ("mlp-m", "MLP", "two hidden layers, MNIST-scale", "synth-mnist"),
        ("lenet5", "LeNet", "LeNet-5 capacity (MLP surrogate)", "synth-mnist"),
        ("cnn-m", "CNN", "mid-size CNN capacity (MLP surrogate)", "synth-cifar10"),
    ];
    let pairs: &[(&str, &str)] = &[
        ("micronet-05", "synth-mnist"),
        ("mlp-s", "synth-mnist"),
        ("mlp-m", "synth-mnist"),
        ("lenet5", "synth-mnist"),
        ("cnn-m", "synth-cifar10"),
    ];

    let ds_map: BTreeMap<String, DatasetInfo> =
        datasets.into_iter().map(|d| (d.name.clone(), d)).collect();

    let mut zoo = BTreeMap::new();
    for &(variant, family, description, canonical) in zoo_rows {
        let hidden = hidden_layers(variant).expect("zoo row");
        let ds = &ds_map[canonical];
        zoo.insert(
            variant.to_string(),
            ZooInfo {
                variant: variant.to_string(),
                family: family.to_string(),
                description: description.to_string(),
                canonical_dataset: canonical.to_string(),
                num_params: param_count(ds.example_len(), hidden, ds.num_classes),
                head_size: head_count(hidden, ds.num_classes),
                feature_extract: true,
                finetune: true,
            },
        );
    }

    let mut artifacts = Vec::new();
    for &(model, dataset) in pairs {
        let hidden = hidden_layers(model).expect("artifact pair");
        let ds = &ds_map[dataset];
        let entries: BTreeMap<String, String> = [
            "train_sgd_full",
            "train_adam_full",
            "train_sgd_featext",
            "train_adam_featext",
            "eval",
        ]
        .iter()
        .map(|&e| (e.to_string(), String::new()))
        .collect();
        artifacts.push(ArtifactInfo {
            id: format!("{model}_{dataset}"),
            model: model.to_string(),
            dataset: dataset.to_string(),
            num_params: param_count(ds.example_len(), hidden, ds.num_classes),
            head_size: head_count(hidden, ds.num_classes),
            entries,
            agg_file: String::new(),
            init_file: String::new(),
            pretrained_file: Some(String::new()),
        });
    }

    Manifest {
        backend: BackendKind::Native,
        dir: PathBuf::from("<native>"),
        train_batch: TRAIN_BATCH,
        eval_batch: EVAL_BATCH,
        k_pad: 64,
        datasets: ds_map,
        zoo,
        artifacts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Split;

    fn executor(model: &str, dataset: &str, optimizer: &str, mode: &str) -> NativeExecutor {
        let m = Arc::new(native_manifest());
        NativeExecutor::load(&m, model, dataset, optimizer, mode).unwrap()
    }

    #[test]
    fn param_count_matches_layout() {
        // 784 -> 16 -> 10: (784+1)*16 + (16+1)*10 = 12560 + 170.
        assert_eq!(param_count(784, &[16], 10), 12730);
        assert_eq!(head_count(&[16], 10), 170);
        let e = executor("micronet-05", "synth-mnist", "sgd", "full");
        assert_eq!(e.num_params(), 12730);
        assert_eq!(e.init_params().unwrap().len(), 12730);
    }

    #[test]
    fn manifest_artifacts_agree_with_executors() {
        let m = Arc::new(native_manifest());
        for art in &m.artifacts {
            let e = NativeExecutor::load(&m, &art.model, &art.dataset, "sgd", "full").unwrap();
            assert_eq!(e.num_params(), art.num_params, "{}", art.id);
            assert_eq!(e.head_size(), art.head_size, "{}", art.id);
        }
    }

    #[test]
    fn init_is_deterministic_and_model_specific() {
        let a = executor("mlp-s", "synth-mnist", "sgd", "full");
        let b = executor("mlp-s", "synth-mnist", "adam", "featext");
        assert_eq!(a.init_params().unwrap(), b.init_params().unwrap());
        let c = executor("lenet5", "synth-mnist", "sgd", "full");
        assert_ne!(
            a.init_params().unwrap()[..16],
            c.init_params().unwrap()[..16]
        );
    }

    #[test]
    fn sgd_overfits_one_batch() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-s", "synth-mnist", "sgd", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 1).unwrap();
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        let mut params = e.init_params().unwrap();
        let first = e.train_step_sgd(&mut params, &batch.x, &batch.y, 0.05).unwrap();
        let mut last = first;
        for _ in 0..20 {
            last = e.train_step_sgd(&mut params, &batch.x, &batch.y, 0.05).unwrap();
        }
        assert!(
            last.loss < first.loss * 0.8,
            "loss should drop when overfitting one batch: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(last.hits >= first.hits);
    }

    #[test]
    fn featext_freezes_backbone() {
        let e = executor("mlp-s", "synth-mnist", "sgd", "featext");
        let m = native_manifest();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 5).unwrap();
        let pre = e.pretrained_params().unwrap();
        let mut params = pre.clone();
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        e.train_step_sgd(&mut params, &batch.x, &batch.y, 0.1).unwrap();
        let backbone = e.num_params() - e.head_size();
        assert_eq!(params[..backbone], pre[..backbone], "backbone must stay frozen");
        assert_ne!(params[backbone..], pre[backbone..], "head must move");
    }

    #[test]
    fn adam_tracks_state() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "micronet-05", "synth-mnist", "adam", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 9).unwrap();
        let mut params = e.init_params().unwrap();
        let mut state = AdamState::zeros(params.len());
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        e.train_step_adam(&mut params, &mut state, &batch.x, &batch.y, 0.01).unwrap();
        assert_eq!(state.t, 1.0);
        e.train_step_adam(&mut params, &mut state, &batch.x, &batch.y, 0.01).unwrap();
        assert_eq!(state.t, 2.0);
        assert!(state.m.iter().any(|&v| v != 0.0), "moment must update");
    }

    #[test]
    fn eval_prefix_matches_short_batch() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-s", "synth-mnist", "sgd", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 3).unwrap();
        let params = e.init_params().unwrap();
        let idx: Vec<usize> = (0..40).collect();
        let short = ds.batch(Split::Test, &idx);
        let s = e.eval_batch(&params, &short.x, &short.y, 40).unwrap();
        let idx_full: Vec<usize> = (0..e.eval_batch_size()).collect();
        let full = ds.batch(Split::Test, &idx_full);
        let masked = e.eval_batch(&params, &full.x, &full.y, 40).unwrap();
        assert_eq!(s.count, 40.0);
        assert_eq!(s.correct, masked.correct);
        assert!((s.loss_sum - masked.loss_sum).abs() < 1e-4);
    }

    #[test]
    fn aggregate_checks_shapes() {
        let e = executor("micronet-05", "synth-mnist", "sgd", "full");
        let global = vec![0.0f32; 8];
        assert!(e.aggregate(&global, &[vec![0.0; 7]], &[1.0]).is_err());
        assert!(e.aggregate(&global, &[vec![0.0; 8]], &[1.0, 2.0]).is_err());
        let out = e.aggregate(&global, &[], &[]).unwrap();
        assert_eq!(out, global);
    }

    #[test]
    fn parallel_and_serial_aggregation_agree() {
        let e = executor("micronet-05", "synth-mnist", "sgd", "full");
        let mut rng = Rng::new(0xA66);
        // Large enough that k*p crosses PAR_MIN_ELEMS (pool path).
        let p = (PAR_MIN_ELEMS / 4) + 13;
        let global: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let deltas: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..p).map(|_| rng.next_gaussian() * 0.01).collect())
            .collect();
        let weights = [0.4f32, 0.3, 0.2, 0.1];
        let par = e.aggregate(&global, &deltas, &weights).unwrap();
        let serial = weighted_sum_range(&global, &deltas, &weights, 0, p);
        assert_eq!(par.len(), p);
        for (a, b) in par.iter().zip(&serial) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
