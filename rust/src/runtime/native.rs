//! Pure-rust native CPU backend — hermetic execution for every runtime op.
//!
//! Implements [`ModelExecutor`] with an MLP forward/backward engine that
//! needs no Python, XLA, or AOT artifacts: parameters are a flat `f32`
//! buffer (same ABI as the PJRT path), initialisation is deterministic
//! per (model, dataset), and "pretrained" weights are synthesised by a
//! short deterministic burn-in. Conv-family zoo names (lenet5, cnn-m)
//! execute as MLP surrogates of comparable capacity — the FL control
//! plane above the executor is identical either way.
//!
//! The step path runs on the cache-blocked GEMM kernels of
//! [`super::gemm`] — forward as `X·Wᵀ` through a pre-transposed weight
//! view, the backward input gradient as `dz·W` straight off the
//! row-major weights, and the weight gradient as `dzᵀ·X` — with every
//! intermediate buffer living in a caller-held [`StepScratch`] arena, so
//! a warm training loop performs **zero heap allocations per step**
//! (asserted by `tests/zero_alloc.rs`). The pre-blocking per-example
//! loops are retained verbatim in [`super::reference`] as the golden
//! baseline; the tests below pin the two engines together within 1e-5.
//!
//! Parallelism: local training fans out across agents on the
//! entrypoint's `util::threadpool::WorkerPool` (one executor per worker
//! thread); the server-side FedAvg aggregation op here shards the
//! parameter range across scoped threads writing disjoint output chunks
//! in place (no cohort copies) once `K × P` is large enough to amortise
//! the fan-out. The entrypoint's FedAvg-family rounds bypass this op
//! entirely and reduce incrementally through
//! [`crate::aggregators::StreamingAccumulator`].
//!
//! Parameter layout per layer `l` (fan_in `i`, fan_out `o`):
//! `W_l` row-major `[o × i]`, then `b_l` `[o]`; the classifier head is
//! the final layer, so featext freezing is "tail of the flat buffer
//! trainable, rest frozen" — matching the AOT artifact convention.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::util::error::{bail, Context, Result};
use crate::util::Rng;

use super::backend::{
    AdamState, BackendKind, EvalStats, FusedSlot, ModelExecutor, StepScratch, StepStats,
};
use super::gemm;
use super::manifest::{ArtifactInfo, DatasetInfo, Manifest, ZooInfo};
use super::stats;

/// Default train batch size of the native manifest.
pub const TRAIN_BATCH: usize = 32;
/// Default eval batch size of the native manifest.
pub const EVAL_BATCH: usize = 128;
/// Aggregations smaller than this many elements (K × P) run serially.
const PAR_MIN_ELEMS: usize = 1 << 20;
/// SGD steps of the deterministic pretraining burn-in.
const PRETRAIN_STEPS: usize = 48;
/// Learning rate of the pretraining burn-in.
const PRETRAIN_LR: f32 = 0.1;
/// Dataset seed used for pretraining data (independent of run seeds).
const PRETRAIN_SEED: u64 = 0x5eed;

/// FNV-1a, for deterministic per-(model, dataset) init streams.
pub(crate) fn fnv1a(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The native model zoo: variant name -> hidden layer widths.
///
/// Kept in sync with [`native_manifest`]; conv-family names map to MLP
/// surrogates so configs/benches written for the artifact zoo run
/// unchanged on the native backend.
pub fn hidden_layers(model: &str) -> Result<&'static [usize]> {
    Ok(match model {
        "micronet-05" => &[16],
        "mlp-s" => &[64],
        "mlp-m" => &[128, 64],
        "lenet5" => &[120, 84],
        "cnn-m" => &[256, 128],
        other => bail!(
            "native backend has no model {other:?} \
             (micronet-05 | mlp-s | mlp-m | lenet5 | cnn-m)"
        ),
    })
}

/// Flat parameter count of an MLP `input -> hidden... -> classes`.
pub fn param_count(input_dim: usize, hidden: &[usize], classes: usize) -> usize {
    layer_dims(input_dim, hidden, classes)
        .iter()
        .map(|&(i, o)| (i + 1) * o)
        .sum()
}

/// Head (final-layer) parameter count.
pub fn head_count(hidden: &[usize], classes: usize) -> usize {
    let last = hidden.last().copied().unwrap_or(0);
    (last + 1) * classes
}

fn layer_dims(input_dim: usize, hidden: &[usize], classes: usize) -> Vec<(usize, usize)> {
    let mut dims = Vec::with_capacity(hidden.len() + 1);
    let mut fan_in = input_dim;
    for &h in hidden {
        dims.push((fan_in, h));
        fan_in = h;
    }
    dims.push((fan_in, classes));
    dims
}

/// A pure-rust MLP executor for one model@dataset.
pub struct NativeExecutor {
    model: String,
    dataset: String,
    /// (fan_in, fan_out) per layer; last layer is the classifier head.
    dims: Vec<(usize, usize)>,
    /// Flat parameter offset of each layer's `[W_l | b_l]` block.
    offsets: Vec<usize>,
    input_dim: usize,
    classes: usize,
    num_params: usize,
    head_size: usize,
    /// Σ hidden widths — activations arena is `n × hidden_sum` floats.
    hidden_sum: usize,
    /// max(classes, hidden widths) — the widest dz/dprev row.
    max_width: usize,
    /// max layer `fan_in × fan_out` — the transposed-weight view size.
    max_wt: usize,
    train_batch: usize,
    eval_batch: usize,
    optimizer: String,
    featext: bool,
    /// Environment handle, needed lazily by the pretraining burn-in.
    manifest: Arc<Manifest>,
    pretrained_cache: RefCell<Option<Vec<f32>>>,
}

impl NativeExecutor {
    /// Build the executor for `model@dataset` described by `manifest`.
    pub fn load(
        manifest: &Arc<Manifest>,
        model: &str,
        dataset: &str,
        optimizer: &str,
        mode: &str,
    ) -> Result<Self> {
        if !matches!(optimizer, "sgd" | "adam") {
            bail!("native backend: optimizer must be sgd or adam, got {optimizer:?}");
        }
        let featext = match mode {
            "full" => false,
            "featext" => true,
            other => bail!("native backend: mode must be full or featext, got {other:?}"),
        };
        let ds = manifest.dataset(dataset)?;
        let hidden = hidden_layers(model)?;
        let input_dim = ds.example_len();
        let classes = ds.num_classes;
        let dims = layer_dims(input_dim, hidden, classes);
        let mut offsets = Vec::with_capacity(dims.len());
        let mut off = 0usize;
        for &(fan_in, fan_out) in &dims {
            offsets.push(off);
            off += fan_out * (fan_in + 1);
        }
        let hidden_sum: usize = hidden.iter().sum();
        let max_width = hidden.iter().copied().fold(classes, usize::max);
        let max_wt = dims.iter().map(|&(i, o)| i * o).max().unwrap_or(0);
        Ok(Self {
            model: model.to_string(),
            dataset: dataset.to_string(),
            num_params: param_count(input_dim, hidden, classes),
            head_size: head_count(hidden, classes),
            dims,
            offsets,
            input_dim,
            classes,
            hidden_sum,
            max_width,
            max_wt,
            train_batch: manifest.train_batch,
            eval_batch: manifest.eval_batch,
            optimizer: optimizer.to_string(),
            featext,
            manifest: Arc::clone(manifest),
            pretrained_cache: RefCell::new(None),
        })
    }

    /// Grow the scratch arenas for a step over `n` examples. Steady
    /// state this is a handful of compare-and-skip checks.
    fn prepare_scratch(&self, s: &mut StepScratch, n: usize, train: bool) {
        StepScratch::grow_f32(&mut s.acts, n * self.hidden_sum);
        StepScratch::grow_f32(&mut s.logits, n * self.classes);
        StepScratch::grow_f32(&mut s.losses, n);
        StepScratch::grow_f32(&mut s.wt, self.max_wt);
        if train {
            StepScratch::grow_f32(&mut s.dz, n * self.max_width);
            StepScratch::grow_f32(&mut s.dprev, n * self.max_width);
            StepScratch::grow_f32(&mut s.grad, self.num_params);
        }
    }

    /// Start (in floats) of hidden layer `h`'s activation region inside
    /// `scratch.acts`, for a batch of `n`.
    fn act_start(&self, h: usize, n: usize) -> usize {
        let widths: usize = self.dims[..h].iter().map(|&(_, o)| o).sum();
        n * widths
    }

    /// Forward pass over `n` examples through the blocked kernels:
    /// per layer, fill the output rows with the bias, accumulate
    /// `X · Wᵀ` via a pre-transposed weight view, relu hidden layers.
    /// Hidden activations land in `s.acts`, logits in `s.logits`.
    fn forward_into(&self, params: &[f32], x: &[f32], n: usize, s: &mut StepScratch) {
        let nlayers = self.dims.len();
        let mut offset = 0usize;
        let mut apos = 0usize;
        for (l, &(fan_in, fan_out)) in self.dims.iter().enumerate() {
            let w = &params[offset..offset + fan_out * fan_in];
            let b = &params[offset + fan_out * fan_in..offset + fan_out * (fan_in + 1)];
            offset += fan_out * (fan_in + 1);
            let last = l + 1 == nlayers;
            // Batch-major X·Wᵀ: transpose W [o×i] into a [i×o] view so
            // the GEMM inner loop is an axpy over output neurons.
            let wt = &mut s.wt[..fan_in * fan_out];
            gemm::transpose(w, wt, fan_out, fan_in);
            let (prev_acts, cur_acts) = s.acts.split_at_mut(apos);
            let input: &[f32] = if l == 0 {
                &x[..n * fan_in]
            } else {
                &prev_acts[apos - n * fan_in..]
            };
            let out: &mut [f32] = if last {
                &mut s.logits[..n * fan_out]
            } else {
                &mut cur_acts[..n * fan_out]
            };
            for row in out.chunks_exact_mut(fan_out) {
                row.copy_from_slice(b);
            }
            gemm::gemm_nn_acc(input, wt, out, n, fan_in, fan_out);
            if !last {
                for v in out.iter_mut() {
                    *v = v.max(0.0);
                }
                apos += n * fan_out;
            }
        }
    }

    /// Softmax cross-entropy over the logits in `s.logits`: fills
    /// `s.losses` (and `s.dz = (softmax - onehot) * scale` when a scale
    /// is given), returning the f64 loss sum and the hit count.
    fn softmax_xent_into(
        &self,
        y: &[i32],
        n: usize,
        dz_scale: Option<f32>,
        s: &mut StepScratch,
    ) -> (f64, usize) {
        softmax_xent_slices(y, n, self.classes, dz_scale, &s.logits, &mut s.losses, &mut s.dz)
    }

    /// Backward pass through the blocked kernels, consuming the `dz` the
    /// softmax left in `s.dz`. The weight gradient is `dzᵀ·X`
    /// ([`gemm::gemm_tn_acc`]); the input gradient is `dz·W` straight
    /// off the row-major weights, relu-masked, ping-ponged through
    /// `s.dprev`. The flat gradient lands in `s.grad`; under featext
    /// only the head block is produced.
    fn backward_into(
        &self,
        params: &[f32],
        x: &[f32],
        n: usize,
        featext: bool,
        s: &mut StepScratch,
    ) {
        let nlayers = self.dims.len();
        s.grad[..self.num_params].fill(0.0);
        for l in (0..nlayers).rev() {
            let (fan_in, fan_out) = self.dims[l];
            let off = self.offsets[l];
            let input: &[f32] = if l == 0 {
                &x[..n * fan_in]
            } else {
                let astart = self.act_start(l - 1, n);
                &s.acts[astart..astart + n * fan_in]
            };
            let dz = &s.dz[..n * fan_out];
            {
                let gl = &mut s.grad[off..off + fan_out * (fan_in + 1)];
                let (gw, gb) = gl.split_at_mut(fan_out * fan_in);
                gemm::gemm_tn_acc(dz, input, gw, n, fan_out, fan_in);
                for di in dz.chunks_exact(fan_out) {
                    for (g, &d) in gb.iter_mut().zip(di) {
                        *g += d;
                    }
                }
            }
            if l == 0 || (featext && l + 1 == nlayers) {
                break;
            }
            {
                let w = &params[off..off + fan_out * fan_in];
                let dprev = &mut s.dprev[..n * fan_in];
                dprev.fill(0.0);
                gemm::gemm_nn_acc(dz, w, dprev, n, fan_out, fan_in);
                let astart = self.act_start(l - 1, n);
                let prev = &s.acts[astart..astart + n * fan_in];
                for (dp, &a) in dprev.iter_mut().zip(prev) {
                    if a <= 0.0 {
                        *dp = 0.0;
                    }
                }
            }
            std::mem::swap(&mut s.dz, &mut s.dprev);
        }
    }

    /// Shared step core: forward + loss + backward. Leaves the batch
    /// gradient in `s.grad` and returns the step stats.
    fn step_core(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        featext: bool,
        s: &mut StepScratch,
    ) -> Result<StepStats> {
        let n = self.train_batch;
        self.check_batch(params, x, y, n)?;
        self.prepare_scratch(s, n, true);
        self.forward_into(params, x, n, s);
        let (loss_sum, hits) = self.softmax_xent_into(y, n, Some(1.0 / n as f32), s);
        self.backward_into(params, x, n, featext, s);
        stats::add_execution();
        Ok(StepStats {
            loss: (loss_sum / n as f64) as f32,
            hits: hits as f32,
        })
    }

    fn check_batch(&self, params: &[f32], x: &[f32], y: &[i32], n: usize) -> Result<()> {
        if params.len() != self.num_params {
            bail!(
                "{}@{}: params has {} entries, executor wants {}",
                self.model,
                self.dataset,
                params.len(),
                self.num_params
            );
        }
        if x.len() < n * self.input_dim || y.len() < n {
            bail!(
                "{}@{}: batch holds {} examples / {} labels, step wants {n}",
                self.model,
                self.dataset,
                x.len() / self.input_dim.max(1),
                y.len()
            );
        }
        for &label in &y[..n] {
            if label < 0 || label as usize >= self.classes {
                bail!("label {label} out of range for {} classes", self.classes);
            }
        }
        Ok(())
    }

    /// First flat index the optimizer may touch (featext freezes the
    /// backbone, i.e. everything before the head).
    fn trainable_from(&self, featext: bool) -> usize {
        if featext {
            self.num_params - self.head_size
        } else {
            0
        }
    }

    /// Largest per-layer parameter block `fan_out × (fan_in + 1)` — the
    /// per-slot gradient arena of the fused step path (which updates
    /// layer by layer instead of materialising a full flat gradient).
    fn max_layer_params(&self) -> usize {
        self.dims.iter().map(|&(i, o)| o * (i + 1)).max().unwrap_or(0)
    }

    /// Grow the scratch arenas for a fused step over `slots` agents ×
    /// `n` examples. Steady state this is a handful of compare-and-skip
    /// checks, like [`Self::prepare_scratch`].
    fn prepare_fused_scratch(&self, s: &mut StepScratch, n: usize, slots: usize) {
        StepScratch::grow_f32(&mut s.acts, slots * n * self.hidden_sum);
        StepScratch::grow_f32(&mut s.logits, slots * n * self.classes);
        StepScratch::grow_f32(&mut s.losses, n);
        StepScratch::grow_f32(&mut s.wt, slots * self.max_wt);
        StepScratch::grow_f32(&mut s.dz, slots * n * self.max_width);
        StepScratch::grow_f32(&mut s.dprev, slots * n * self.max_width);
        StepScratch::grow_f32(&mut s.grad, slots * self.max_layer_params());
        s.fused_ptrs.clear();
        if s.fused_ptrs.capacity() < slots {
            stats::add_allocated(
                ((slots - s.fused_ptrs.capacity()) * std::mem::size_of::<gemm::GemmSlot>())
                    as u64,
            );
            s.fused_ptrs.reserve(slots);
        }
    }

    /// An SGD step with explicit mode, independent of the executor's own
    /// mode — used by the trait step and the pretraining burn-in.
    fn sgd_step(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        featext: bool,
        s: &mut StepScratch,
    ) -> Result<StepStats> {
        let step = self.step_core(params, x, y, featext, s)?;
        let from = self.trainable_from(featext);
        for (p, g) in params[from..].iter_mut().zip(&s.grad[from..self.num_params]) {
            *p -= lr * g;
        }
        Ok(step)
    }
}

impl ModelExecutor for NativeExecutor {
    fn backend(&self) -> BackendKind {
        BackendKind::Native
    }

    fn num_params(&self) -> usize {
        self.num_params
    }

    fn head_size(&self) -> usize {
        self.head_size
    }

    fn train_batch_size(&self) -> usize {
        self.train_batch
    }

    fn eval_batch_size(&self) -> usize {
        self.eval_batch
    }

    fn optimizer(&self) -> &str {
        &self.optimizer
    }

    fn init_params(&self) -> Result<Vec<f32>> {
        // He-normal weights, zero biases, seeded by (model, dataset) so
        // every worker/agent derives the identical W^0.
        let mut rng = Rng::new(fnv1a(&format!("{}@{}", self.model, self.dataset)) ^ 0x1217);
        let mut params = Vec::with_capacity(self.num_params);
        for &(fan_in, fan_out) in &self.dims {
            let std = (2.0 / fan_in as f32).sqrt();
            for _ in 0..fan_out * fan_in {
                params.push(rng.next_gaussian() * std);
            }
            params.resize(params.len() + fan_out, 0.0);
        }
        Ok(params)
    }

    fn pretrained_params(&self) -> Result<Vec<f32>> {
        if let Some(p) = self.pretrained_cache.borrow().as_ref() {
            return Ok(p.clone());
        }
        // Deterministic burn-in: a short full-mode SGD run over the
        // canonical synthetic data stands in for the zoo's published
        // pretrained checkpoints. The dataset is only built here, so
        // scratch-mode runs never pay for it.
        let data = crate::datasets::Dataset::load(&self.manifest, &self.dataset, PRETRAIN_SEED)
            .with_context(|| {
                format!("loading pretrain data for {}@{}", self.model, self.dataset)
            })?;
        let mut params = self.init_params()?;
        let mut scratch = StepScratch::new();
        let b = self.train_batch;
        let n = data.num_train();
        for step in 0..PRETRAIN_STEPS {
            let idx: Vec<usize> = (0..b).map(|i| (step * b + i) % n).collect();
            let batch = data.batch(crate::datasets::Split::Train, &idx);
            self.sgd_step(&mut params, &batch.x, &batch.y, PRETRAIN_LR, false, &mut scratch)?;
        }
        *self.pretrained_cache.borrow_mut() = Some(params.clone());
        Ok(params)
    }

    fn train_step_sgd(
        &self,
        params: &mut Vec<f32>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        scratch: &mut StepScratch,
    ) -> Result<StepStats> {
        self.sgd_step(params, x, y, lr, self.featext, scratch)
    }

    fn train_step_adam(
        &self,
        params: &mut Vec<f32>,
        state: &mut AdamState,
        x: &[f32],
        y: &[i32],
        lr: f32,
        scratch: &mut StepScratch,
    ) -> Result<StepStats> {
        if state.m.len() != self.num_params || state.v.len() != self.num_params {
            bail!(
                "adam state sized {} but executor has {} params",
                state.m.len(),
                self.num_params
            );
        }
        let step = self.step_core(params, x, y, self.featext, scratch)?;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        state.t += 1.0;
        let bc1 = 1.0 - b1.powf(state.t);
        let bc2 = 1.0 - b2.powf(state.t);
        let from = self.trainable_from(self.featext);
        let grad = &scratch.grad[..self.num_params];
        for i in from..self.num_params {
            let g = grad[i];
            state.m[i] = b1 * state.m[i] + (1.0 - b1) * g;
            state.v[i] = b2 * state.v[i] + (1.0 - b2) * g * g;
            let mhat = state.m[i] / bc1;
            let vhat = state.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
        Ok(step)
    }

    /// The fused multi-agent SGD step: every layer's forward `X·Wᵀ`,
    /// backward `dz·W`, and weight-gradient `dzᵀ·X` runs as **one**
    /// fused panel-parallel GEMM across the whole cohort
    /// ([`gemm::gemm_nn_acc_fused`] / [`gemm::gemm_tn_acc_fused`]), so
    /// co-scheduled agents amortise kernel dispatch instead of
    /// contending for cores. Per-slot arithmetic is exactly the serial
    /// step's (the fused drivers are bit-identical per slot, and the
    /// per-layer in-place update reads each `W_l` only before writing
    /// it), so results are bit-identical to per-agent
    /// [`Self::train_step_sgd`] calls — pinned by the tests below.
    fn train_step_sgd_fused(
        &self,
        slots: &mut [FusedSlot<'_>],
        lr: f32,
        scratch: &mut StepScratch,
        stats_out: &mut Vec<StepStats>,
    ) -> Result<()> {
        stats_out.clear();
        if slots.is_empty() {
            return Ok(());
        }
        if slots.len() == 1 {
            let s0 = &mut slots[0];
            stats_out.push(self.sgd_step(s0.params, s0.x, s0.y, lr, self.featext, scratch)?);
            return Ok(());
        }
        let n = self.train_batch;
        for slot in slots.iter() {
            self.check_batch(slot.params, slot.x, slot.y, n)?;
        }
        let s_count = slots.len();
        self.prepare_fused_scratch(scratch, n, s_count);
        let nlayers = self.dims.len();
        let acts_stride = n * self.hidden_sum;
        let logit_stride = n * self.classes;
        let dz_stride = n * self.max_width;
        let max_layer = self.max_layer_params();

        // ---- forward: one fused X·Wᵀ per layer across the cohort.
        let mut offset = 0usize;
        let mut apos = 0usize; // per-slot activation offset of layer l
        for (l, &(fan_in, fan_out)) in self.dims.iter().enumerate() {
            let last = l + 1 == nlayers;
            let wsize = fan_out * fan_in;
            for (s, slot) in slots.iter().enumerate() {
                let w = &slot.params[offset..offset + wsize];
                let bias = &slot.params[offset + wsize..offset + wsize + fan_out];
                let wt = &mut scratch.wt[s * self.max_wt..s * self.max_wt + wsize];
                gemm::transpose(w, wt, fan_out, fan_in);
                let out = if last {
                    &mut scratch.logits[s * logit_stride..s * logit_stride + n * fan_out]
                } else {
                    let base = s * acts_stride + apos;
                    &mut scratch.acts[base..base + n * fan_out]
                };
                for row in out.chunks_exact_mut(fan_out) {
                    row.copy_from_slice(bias);
                }
            }
            let acts_ptr = scratch.acts.as_mut_ptr();
            let logits_ptr = scratch.logits.as_mut_ptr();
            let wt_ptr = scratch.wt.as_ptr();
            scratch.fused_ptrs.clear();
            for (s, slot) in slots.iter().enumerate() {
                // SAFETY (pointer arithmetic only): all offsets are in
                // bounds of the arenas grown above.
                let (a, b, c) = unsafe {
                    (
                        if l == 0 {
                            slot.x.as_ptr()
                        } else {
                            acts_ptr.add(s * acts_stride + apos - n * fan_in) as *const f32
                        },
                        wt_ptr.add(s * self.max_wt),
                        if last {
                            logits_ptr.add(s * logit_stride)
                        } else {
                            acts_ptr.add(s * acts_stride + apos)
                        },
                    )
                };
                scratch.fused_ptrs.push(gemm::GemmSlot { a, b, c });
            }
            // SAFETY: per slot, `a` reads the batch or the previous
            // layer's activation region, `b` reads that slot's
            // transposed weights, and `c` writes that slot's own
            // output region — all disjoint regions of arenas that
            // outlive the call.
            unsafe { gemm::gemm_nn_acc_fused(&scratch.fused_ptrs, n, fan_in, fan_out) };
            if !last {
                for s in 0..s_count {
                    let base = s * acts_stride + apos;
                    for v in scratch.acts[base..base + n * fan_out].iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                apos += n * fan_out;
            }
            offset += fan_out * (fan_in + 1);
        }

        // ---- loss + dz per slot.
        let scale = 1.0 / n as f32;
        for (s, slot) in slots.iter().enumerate() {
            let logits = &scratch.logits[s * logit_stride..(s + 1) * logit_stride];
            let dz = &mut scratch.dz[s * dz_stride..s * dz_stride + n * self.classes];
            let (loss_sum, hits) = softmax_xent_slices(
                slot.y,
                n,
                self.classes,
                Some(scale),
                logits,
                &mut scratch.losses,
                dz,
            );
            stats_out.push(StepStats {
                loss: (loss_sum / n as f64) as f32,
                hits: hits as f32,
            });
            stats::add_execution();
        }

        // ---- backward: fused dz·W and dzᵀ·X per layer, with the SGD
        // update applied in place per layer (each W_l is read for the
        // input gradient before it is written).
        for l in (0..nlayers).rev() {
            let (fan_in, fan_out) = self.dims[l];
            let off = self.offsets[l];
            let stop = l == 0 || (self.featext && l + 1 == nlayers);
            if !stop {
                let astart = self.act_start(l - 1, n);
                for s in 0..s_count {
                    scratch.dprev[s * dz_stride..s * dz_stride + n * fan_in].fill(0.0);
                }
                let dz_ptr = scratch.dz.as_ptr();
                let dp_ptr = scratch.dprev.as_mut_ptr();
                scratch.fused_ptrs.clear();
                for (s, slot) in slots.iter().enumerate() {
                    // SAFETY: in-bounds offsets (see above).
                    let (a, b, c) = unsafe {
                        (
                            dz_ptr.add(s * dz_stride),
                            slot.params[off..].as_ptr(),
                            dp_ptr.add(s * dz_stride),
                        )
                    };
                    scratch.fused_ptrs.push(gemm::GemmSlot { a, b, c });
                }
                // SAFETY: reads each slot's dz region and its (not yet
                // updated) layer weights, writes its disjoint dprev
                // region.
                unsafe { gemm::gemm_nn_acc_fused(&scratch.fused_ptrs, n, fan_out, fan_in) };
                for s in 0..s_count {
                    let base = s * acts_stride + astart;
                    let prev = &scratch.acts[base..base + n * fan_in];
                    let dp = &mut scratch.dprev[s * dz_stride..s * dz_stride + n * fan_in];
                    for (d, &a) in dp.iter_mut().zip(prev) {
                        if a <= 0.0 {
                            *d = 0.0;
                        }
                    }
                }
            }
            // Zero the whole per-slot layer block — weight part for the
            // TN accumulate below, bias tail for the `+=` bias loop
            // (the arena is reused across layers and steps, so a
            // weight-only fill would leak stale bias gradients in).
            let lsize = fan_out * (fan_in + 1);
            for s in 0..s_count {
                scratch.grad[s * max_layer..s * max_layer + lsize].fill(0.0);
            }
            let dz_ptr = scratch.dz.as_ptr();
            let g_ptr = scratch.grad.as_mut_ptr();
            let acts_ro = scratch.acts.as_ptr();
            scratch.fused_ptrs.clear();
            for (s, slot) in slots.iter().enumerate() {
                // SAFETY: in-bounds offsets (see above).
                let (a, b, c) = unsafe {
                    (
                        dz_ptr.add(s * dz_stride),
                        if l == 0 {
                            slot.x.as_ptr()
                        } else {
                            acts_ro.add(s * acts_stride + self.act_start(l - 1, n))
                        },
                        g_ptr.add(s * max_layer),
                    )
                };
                scratch.fused_ptrs.push(gemm::GemmSlot { a, b, c });
            }
            // SAFETY: reads each slot's dz and layer-input regions,
            // writes its disjoint layer-gradient region.
            unsafe { gemm::gemm_tn_acc_fused(&scratch.fused_ptrs, n, fan_out, fan_in) };
            for (s, slot) in slots.iter_mut().enumerate() {
                let g = &mut scratch.grad[s * max_layer..s * max_layer + lsize];
                {
                    let (_, gb) = g.split_at_mut(fan_out * fan_in);
                    let dzs = &scratch.dz[s * dz_stride..s * dz_stride + n * fan_out];
                    for di in dzs.chunks_exact(fan_out) {
                        for (gbj, &d) in gb.iter_mut().zip(di) {
                            *gbj += d;
                        }
                    }
                }
                let pl = &mut slot.params[off..off + lsize];
                for (p, &gv) in pl.iter_mut().zip(g.iter()) {
                    *p -= lr * gv;
                }
            }
            if stop {
                break;
            }
            std::mem::swap(&mut scratch.dz, &mut scratch.dprev);
        }
        Ok(())
    }

    fn eval_batch(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        n_valid: usize,
        scratch: &mut StepScratch,
    ) -> Result<EvalStats> {
        if n_valid > self.eval_batch {
            bail!("eval batch of {n_valid} exceeds eval_batch={}", self.eval_batch);
        }
        self.check_batch(params, x, y, n_valid)?;
        self.prepare_scratch(scratch, n_valid, false);
        // No padding needed on the host: just score the valid prefix
        // (the mask semantics of the PJRT graph, computed directly).
        self.forward_into(params, &x[..n_valid * self.input_dim], n_valid, scratch);
        let (loss_sum, hits) = self.softmax_xent_into(y, n_valid, None, scratch);
        stats::add_execution();
        Ok(EvalStats {
            loss_sum,
            correct: hits as f64,
            count: n_valid as f64,
        })
    }

    fn aggregate(
        &self,
        global: &[f32],
        deltas: &[Vec<f32>],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let k = deltas.len();
        if k != weights.len() {
            bail!("{k} deltas but {} weights", weights.len());
        }
        for (i, d) in deltas.iter().enumerate() {
            if d.len() != global.len() {
                bail!("delta {i} has {} params, global has {}", d.len(), global.len());
            }
        }
        let p = global.len();
        if k == 0 {
            return Ok(global.to_vec());
        }
        let mut out = vec![0.0f32; p];
        if k * p < PAR_MIN_ELEMS {
            weighted_sum_into(global, deltas, weights, 0, &mut out);
            return Ok(out);
        }
        // Shard the parameter range across scoped threads writing
        // disjoint chunks of `out` in place. Scoped borrows mean the
        // K×P cohort is never copied for the fan-out (the old path
        // cloned global + deltas + weights into Arcs to satisfy the
        // worker pool's 'static jobs).
        let jobs_n = crate::util::Parallelism::Auto
            .resolve(crate::util::Parallelism::detect())
            .clamp(2, 8)
            .min(p);
        let chunk = p.div_ceil(jobs_n);
        std::thread::scope(|s| {
            for (j, out_chunk) in out.chunks_mut(chunk).enumerate() {
                let lo = j * chunk;
                s.spawn(move || weighted_sum_into(global, deltas, weights, lo, out_chunk));
            }
        });
        Ok(out)
    }
}

/// Softmax cross-entropy over `logits[..n·classes]`: fills
/// `losses[..n]` (and `dz[i·classes..][..classes] = (softmax − onehot)
/// · scale` when a scale is given — `dz` may be empty otherwise),
/// returning the f64 loss sum and the argmax hit count. Slice-level so
/// the serial and fused step paths share one implementation.
fn softmax_xent_slices(
    y: &[i32],
    n: usize,
    classes: usize,
    dz_scale: Option<f32>,
    logits: &[f32],
    losses: &mut [f32],
    dz: &mut [f32],
) -> (f64, usize) {
    let c = classes;
    let logits = &logits[..n * c];
    let losses = &mut losses[..n];
    let mut hits = 0usize;
    for i in 0..n {
        let z = &logits[i * c..(i + 1) * c];
        let mut max = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in z.iter().enumerate() {
            if v > max {
                max = v;
                argmax = j;
            }
        }
        let mut sum = 0.0f32;
        for &v in z {
            sum += (v - max).exp();
        }
        let lse = max + sum.ln();
        let label = y[i] as usize;
        losses[i] = lse - z[label];
        if argmax == label {
            hits += 1;
        }
        if let Some(scale) = dz_scale {
            let d = &mut dz[i * c..(i + 1) * c];
            for (j, &v) in z.iter().enumerate() {
                d[j] = ((v - lse).exp() - if j == label { 1.0 } else { 0.0 }) * scale;
            }
        }
    }
    let loss_sum: f64 = losses.iter().map(|&l| l as f64).sum();
    (loss_sum, hits)
}

/// `out[i] = global[lo+i] + Σ_k w_k · delta_k[lo+i]`, accumulated in f64
/// so the result agrees with `fedavg_host` to well under 1e-5 regardless
/// of summation order.
fn weighted_sum_into(
    global: &[f32],
    deltas: &[Vec<f32>],
    weights: &[f32],
    lo: usize,
    out: &mut [f32],
) {
    for (i, o) in out.iter_mut().enumerate() {
        let j = lo + i;
        let mut acc = global[j] as f64;
        for (d, &w) in deltas.iter().zip(weights) {
            acc += w as f64 * d[j] as f64;
        }
        *o = acc as f32;
    }
}

fn native_dataset(
    name: &str,
    group: &str,
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    real: (usize, usize),
    noise: f32,
) -> DatasetInfo {
    DatasetInfo {
        name: name.to_string(),
        group: group.to_string(),
        height: h,
        width: w,
        channels: c,
        num_classes: classes,
        train_n: 2048,
        test_n: 512,
        real_train_n: real.0,
        real_test_n: real.1,
        noise,
        jitter: 2,
        // Empty => Dataset::load synthesises class templates procedurally.
        template_file: String::new(),
    }
}

/// Build the in-memory manifest of the native backend: procedural
/// datasets, the native MLP zoo, and one "artifact" per runnable
/// model@dataset pair (entry files are empty — nothing is on disk).
pub fn native_manifest() -> Manifest {
    let datasets: Vec<DatasetInfo> = vec![
        native_dataset("synth-mnist", "MNIST", 28, 28, 1, 10, (60_000, 10_000), 0.15),
        native_dataset("synth-fmnist", "FashionMNIST", 28, 28, 1, 10, (60_000, 10_000), 0.2),
        native_dataset("synth-cifar10", "CIFAR", 32, 32, 3, 10, (50_000, 10_000), 0.2),
        native_dataset("synth-cifar100", "CIFAR", 32, 32, 3, 100, (50_000, 10_000), 0.2),
    ];
    let zoo_rows: &[(&str, &str, &str, &str)] = &[
        ("micronet-05", "MicroNet", "tiny MLP head for federated transfer", "synth-mnist"),
        ("mlp-s", "MLP", "one hidden layer, MNIST-scale", "synth-mnist"),
        ("mlp-m", "MLP", "two hidden layers, MNIST-scale", "synth-mnist"),
        ("lenet5", "LeNet", "LeNet-5 capacity (MLP surrogate)", "synth-mnist"),
        ("cnn-m", "CNN", "mid-size CNN capacity (MLP surrogate)", "synth-cifar10"),
    ];
    let pairs: &[(&str, &str)] = &[
        ("micronet-05", "synth-mnist"),
        ("mlp-s", "synth-mnist"),
        ("mlp-m", "synth-mnist"),
        ("lenet5", "synth-mnist"),
        ("cnn-m", "synth-cifar10"),
    ];

    let ds_map: BTreeMap<String, DatasetInfo> =
        datasets.into_iter().map(|d| (d.name.clone(), d)).collect();

    let mut zoo = BTreeMap::new();
    for &(variant, family, description, canonical) in zoo_rows {
        let hidden = hidden_layers(variant).expect("zoo row");
        let ds = &ds_map[canonical];
        zoo.insert(
            variant.to_string(),
            ZooInfo {
                variant: variant.to_string(),
                family: family.to_string(),
                description: description.to_string(),
                canonical_dataset: canonical.to_string(),
                num_params: param_count(ds.example_len(), hidden, ds.num_classes),
                head_size: head_count(hidden, ds.num_classes),
                feature_extract: true,
                finetune: true,
            },
        );
    }

    let mut artifacts = Vec::new();
    for &(model, dataset) in pairs {
        let hidden = hidden_layers(model).expect("artifact pair");
        let ds = &ds_map[dataset];
        let entries: BTreeMap<String, String> = [
            "train_sgd_full",
            "train_adam_full",
            "train_sgd_featext",
            "train_adam_featext",
            "eval",
        ]
        .iter()
        .map(|&e| (e.to_string(), String::new()))
        .collect();
        artifacts.push(ArtifactInfo {
            id: format!("{model}_{dataset}"),
            model: model.to_string(),
            dataset: dataset.to_string(),
            num_params: param_count(ds.example_len(), hidden, ds.num_classes),
            head_size: head_count(hidden, ds.num_classes),
            entries,
            agg_file: String::new(),
            init_file: String::new(),
            pretrained_file: Some(String::new()),
        });
    }

    Manifest {
        backend: BackendKind::Native,
        dir: PathBuf::from("<native>"),
        train_batch: TRAIN_BATCH,
        eval_batch: EVAL_BATCH,
        k_pad: 64,
        datasets: ds_map,
        zoo,
        artifacts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Split;
    use crate::runtime::reference::NaiveMlp;

    fn executor(model: &str, dataset: &str, optimizer: &str, mode: &str) -> NativeExecutor {
        let m = Arc::new(native_manifest());
        NativeExecutor::load(&m, model, dataset, optimizer, mode).unwrap()
    }

    #[test]
    fn param_count_matches_layout() {
        // 784 -> 16 -> 10: (784+1)*16 + (16+1)*10 = 12560 + 170.
        assert_eq!(param_count(784, &[16], 10), 12730);
        assert_eq!(head_count(&[16], 10), 170);
        let e = executor("micronet-05", "synth-mnist", "sgd", "full");
        assert_eq!(e.num_params(), 12730);
        assert_eq!(e.init_params().unwrap().len(), 12730);
    }

    #[test]
    fn manifest_artifacts_agree_with_executors() {
        let m = Arc::new(native_manifest());
        for art in &m.artifacts {
            let e = NativeExecutor::load(&m, &art.model, &art.dataset, "sgd", "full").unwrap();
            assert_eq!(e.num_params(), art.num_params, "{}", art.id);
            assert_eq!(e.head_size(), art.head_size, "{}", art.id);
        }
    }

    #[test]
    fn init_is_deterministic_and_model_specific() {
        let a = executor("mlp-s", "synth-mnist", "sgd", "full");
        let b = executor("mlp-s", "synth-mnist", "adam", "featext");
        assert_eq!(a.init_params().unwrap(), b.init_params().unwrap());
        let c = executor("lenet5", "synth-mnist", "sgd", "full");
        assert_ne!(
            a.init_params().unwrap()[..16],
            c.init_params().unwrap()[..16]
        );
    }

    #[test]
    fn sgd_overfits_one_batch() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-s", "synth-mnist", "sgd", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 1).unwrap();
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        let mut params = e.init_params().unwrap();
        let mut s = e.new_scratch();
        let first = e.train_step_sgd(&mut params, &batch.x, &batch.y, 0.05, &mut s).unwrap();
        let mut last = first;
        for _ in 0..20 {
            last = e.train_step_sgd(&mut params, &batch.x, &batch.y, 0.05, &mut s).unwrap();
        }
        assert!(
            last.loss < first.loss * 0.8,
            "loss should drop when overfitting one batch: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(last.hits >= first.hits);
    }

    #[test]
    fn featext_freezes_backbone() {
        let e = executor("mlp-s", "synth-mnist", "sgd", "featext");
        let m = native_manifest();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 5).unwrap();
        let pre = e.pretrained_params().unwrap();
        let mut params = pre.clone();
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        let mut s = e.new_scratch();
        e.train_step_sgd(&mut params, &batch.x, &batch.y, 0.1, &mut s).unwrap();
        let backbone = e.num_params() - e.head_size();
        assert_eq!(params[..backbone], pre[..backbone], "backbone must stay frozen");
        assert_ne!(params[backbone..], pre[backbone..], "head must move");
    }

    #[test]
    fn adam_tracks_state() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "micronet-05", "synth-mnist", "adam", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 9).unwrap();
        let mut params = e.init_params().unwrap();
        let mut state = AdamState::zeros(params.len());
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        let mut s = e.new_scratch();
        e.train_step_adam(&mut params, &mut state, &batch.x, &batch.y, 0.01, &mut s)
            .unwrap();
        assert_eq!(state.t, 1.0);
        e.train_step_adam(&mut params, &mut state, &batch.x, &batch.y, 0.01, &mut s)
            .unwrap();
        assert_eq!(state.t, 2.0);
        assert!(state.m.iter().any(|&v| v != 0.0), "moment must update");
    }

    #[test]
    fn eval_prefix_matches_short_batch() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-s", "synth-mnist", "sgd", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 3).unwrap();
        let params = e.init_params().unwrap();
        let mut s = e.new_scratch();
        let idx: Vec<usize> = (0..40).collect();
        let short = ds.batch(Split::Test, &idx);
        let st = e.eval_batch(&params, &short.x, &short.y, 40, &mut s).unwrap();
        let idx_full: Vec<usize> = (0..e.eval_batch_size()).collect();
        let full = ds.batch(Split::Test, &idx_full);
        let masked = e.eval_batch(&params, &full.x, &full.y, 40, &mut s).unwrap();
        assert_eq!(st.count, 40.0);
        assert_eq!(st.correct, masked.correct);
        assert!((st.loss_sum - masked.loss_sum).abs() < 1e-4);
    }

    #[test]
    fn aggregate_checks_shapes() {
        let e = executor("micronet-05", "synth-mnist", "sgd", "full");
        let global = vec![0.0f32; 8];
        assert!(e.aggregate(&global, &[vec![0.0; 7]], &[1.0]).is_err());
        assert!(e.aggregate(&global, &[vec![0.0; 8]], &[1.0, 2.0]).is_err());
        let out = e.aggregate(&global, &[], &[]).unwrap();
        assert_eq!(out, global);
    }

    #[test]
    fn parallel_and_serial_aggregation_agree() {
        let e = executor("micronet-05", "synth-mnist", "sgd", "full");
        let mut rng = Rng::new(0xA66);
        // Large enough that k*p crosses PAR_MIN_ELEMS (pool path).
        let p = (PAR_MIN_ELEMS / 4) + 13;
        let global: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let deltas: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..p).map(|_| rng.next_gaussian() * 0.01).collect())
            .collect();
        let weights = [0.4f32, 0.3, 0.2, 0.1];
        let par = e.aggregate(&global, &deltas, &weights).unwrap();
        let mut serial = vec![0.0f32; p];
        weighted_sum_into(&global, &deltas, &weights, 0, &mut serial);
        assert_eq!(par.len(), p);
        for (a, b) in par.iter().zip(&serial) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    // ---------------------------------------- blocked-vs-naive goldens

    /// Max |a-b| scaled by value magnitude must stay under 1e-5.
    fn assert_within(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-5 * w.abs().max(1.0);
            assert!((g - w).abs() <= tol, "{what}[{i}]: blocked {g} vs naive {w}");
        }
    }

    /// The blocked SGD step matches the retained naive reference within
    /// 1e-5 across every zoo shape (classes=10 exercises the K/N tile
    /// tails; gemm.rs covers arbitrary odd shapes at the kernel level).
    #[test]
    fn blocked_sgd_step_matches_naive_reference_across_zoo() {
        let m = Arc::new(native_manifest());
        for art in &m.artifacts {
            let e = NativeExecutor::load(&m, &art.model, &art.dataset, "sgd", "full").unwrap();
            let ds = crate::datasets::Dataset::load(&m, &art.dataset, 7).unwrap();
            let naive = NaiveMlp::new(
                e.input_dim,
                hidden_layers(&art.model).unwrap(),
                e.classes,
            );
            let n = e.train_batch_size();
            let idx: Vec<usize> = (0..n).collect();
            let batch = ds.batch(Split::Train, &idx);
            let p0 = e.init_params().unwrap();

            let mut pb = p0.clone();
            let mut scratch = e.new_scratch();
            let sb = e.train_step_sgd(&mut pb, &batch.x, &batch.y, 0.5, &mut scratch).unwrap();
            let mut pn = p0.clone();
            let sn = naive.sgd_step(&mut pn, &batch.x, &batch.y, n, 0.5);

            // Loss stat sums in f64 (blocked) vs f32 (naive); argmax can
            // flip on a near-tie — the params are the strict golden.
            assert!((sb.loss - sn.loss).abs() < 1e-4, "{}: loss", art.id);
            assert!((sb.hits - sn.hits).abs() <= 1.0, "{}: hits", art.id);
            assert_within(&pb, &pn, &art.id);
        }
    }

    /// Forward parity: blocked logits (recovered through the eval op)
    /// agree with the naive forward pass within 1e-5.
    #[test]
    fn blocked_forward_matches_naive_reference() {
        let m = Arc::new(native_manifest());
        for art in &m.artifacts {
            let e = NativeExecutor::load(&m, &art.model, &art.dataset, "sgd", "full").unwrap();
            let ds = crate::datasets::Dataset::load(&m, &art.dataset, 11).unwrap();
            let naive = NaiveMlp::new(
                e.input_dim,
                hidden_layers(&art.model).unwrap(),
                e.classes,
            );
            let n = 17; // deliberately not a tile multiple
            let idx: Vec<usize> = (0..n).collect();
            let batch = ds.batch(Split::Test, &idx);
            let params = e.init_params().unwrap();
            let mut scratch = e.new_scratch();
            e.prepare_scratch(&mut scratch, n, false);
            e.forward_into(&params, &batch.x, n, &mut scratch);
            let (_, logits) = naive.forward(&params, &batch.x, n);
            assert_within(&scratch.logits[..n * e.classes], &logits, &art.id);
        }
    }

    /// Featext parity: the blocked head gradient matches the naive
    /// reference and the backbone gradient stays exactly zero.
    #[test]
    fn blocked_featext_grad_matches_naive_reference() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-m", "synth-mnist", "sgd", "featext").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 13).unwrap();
        let naive = NaiveMlp::new(e.input_dim, hidden_layers("mlp-m").unwrap(), e.classes);
        let n = e.train_batch_size();
        let idx: Vec<usize> = (0..n).collect();
        let batch = ds.batch(Split::Train, &idx);
        let pre = e.pretrained_params().unwrap();

        let mut pb = pre.clone();
        let mut scratch = e.new_scratch();
        e.train_step_sgd(&mut pb, &batch.x, &batch.y, 1.0, &mut scratch).unwrap();
        let grad_blocked: Vec<f32> = pre.iter().zip(&pb).map(|(a, b)| a - b).collect();
        let (grad_naive, _) = naive.batch_grad(&pre, &batch.x, &batch.y, n, true);

        let backbone = e.num_params() - e.head_size();
        assert!(grad_blocked[..backbone].iter().all(|&g| g == 0.0), "backbone frozen");
        assert_within(&grad_blocked[backbone..], &grad_naive[backbone..], "head grad");
    }

    /// Adam parity: the blocked Adam step equals the Adam formula
    /// applied to the naive reference gradient.
    #[test]
    fn blocked_adam_step_matches_naive_reference() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-s", "synth-mnist", "adam", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 17).unwrap();
        let naive = NaiveMlp::new(e.input_dim, hidden_layers("mlp-s").unwrap(), e.classes);
        let n = e.train_batch_size();
        let idx: Vec<usize> = (0..n).collect();
        let batch = ds.batch(Split::Train, &idx);
        let p0 = e.init_params().unwrap();

        let mut pb = p0.clone();
        let mut state = AdamState::zeros(p0.len());
        let mut scratch = e.new_scratch();
        let lr = 0.01f32;
        e.train_step_adam(&mut pb, &mut state, &batch.x, &batch.y, lr, &mut scratch)
            .unwrap();

        let (grad, _) = naive.batch_grad(&p0, &batch.x, &batch.y, n, false);
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powf(1.0);
        let bc2 = 1.0 - b2.powf(1.0);
        // `m̂/(√v̂+ε)` amplifies rounding noise without bound as g → 0,
        // so (like the finite-difference golden) only coordinates with a
        // usable gradient are compared.
        let mut checked = 0usize;
        for (j, &g) in grad.iter().enumerate() {
            if g.abs() < 1e-3 {
                continue;
            }
            let mhat = (1.0 - b1) * g / bc1;
            let vhat = (1.0 - b2) * g * g / bc2;
            let expect = p0[j] - lr * mhat / (vhat.sqrt() + eps);
            assert!(
                (pb[j] - expect).abs() < 1e-4,
                "coord {j}: blocked adam {} vs naive-grad formula {expect}",
                pb[j]
            );
            checked += 1;
        }
        assert!(checked > 50, "only {checked} coords had usable gradients");
    }

    /// The fused multi-agent step is bit-identical to per-agent serial
    /// steps across every zoo shape — including the second lockstep
    /// step, where the slots' weights have already diverged (the fused
    /// path must handle per-slot weights, not just a shared W^t).
    #[test]
    fn fused_steps_match_per_agent_serial_steps_across_zoo() {
        let m = Arc::new(native_manifest());
        for art in &m.artifacts {
            let e = NativeExecutor::load(&m, &art.model, &art.dataset, "sgd", "full").unwrap();
            let ds = crate::datasets::Dataset::load(&m, &art.dataset, 19).unwrap();
            let n = e.train_batch_size();
            let s_count = 3usize;
            let batches: Vec<_> = (0..s_count)
                .map(|s| {
                    let idx: Vec<usize> =
                        (0..n).map(|i| (s * 7 + i * 3) % ds.num_train()).collect();
                    ds.batch(Split::Train, &idx)
                })
                .collect();
            let p0 = e.init_params().unwrap();

            let mut serial: Vec<Vec<f32>> = (0..s_count).map(|_| p0.clone()).collect();
            let mut sref = e.new_scratch();
            let mut serial_stats = Vec::new();
            for step in 0..2 {
                for s in 0..s_count {
                    let bt = &batches[s];
                    let st = e
                        .train_step_sgd(&mut serial[s], &bt.x, &bt.y, 0.1, &mut sref)
                        .unwrap();
                    if step == 1 {
                        serial_stats.push(st);
                    }
                }
            }

            let mut fused: Vec<Vec<f32>> = (0..s_count).map(|_| p0.clone()).collect();
            let mut scratch = e.new_scratch();
            let mut stats = Vec::new();
            for _ in 0..2 {
                let mut slots: Vec<FusedSlot> = fused
                    .iter_mut()
                    .zip(&batches)
                    .map(|(p, b)| FusedSlot { params: p, x: &b.x, y: &b.y })
                    .collect();
                e.train_step_sgd_fused(&mut slots, 0.1, &mut scratch, &mut stats).unwrap();
            }
            assert_eq!(stats.len(), s_count);
            for s in 0..s_count {
                assert_eq!(serial[s], fused[s], "{} slot {s}: params", art.id);
                assert_eq!(stats[s].loss, serial_stats[s].loss, "{} slot {s}: loss", art.id);
                assert_eq!(stats[s].hits, serial_stats[s].hits, "{} slot {s}: hits", art.id);
            }
        }
    }

    /// Fused featext: backbone frozen on every slot, head bit-identical
    /// to the per-agent serial featext steps.
    #[test]
    fn fused_featext_matches_serial_and_freezes_backbone() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-m", "synth-mnist", "sgd", "featext").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 23).unwrap();
        let n = e.train_batch_size();
        let pre = e.pretrained_params().unwrap();
        let batches: Vec<_> = (0..2usize)
            .map(|s| {
                let idx: Vec<usize> = (0..n).map(|i| (s * 11 + i) % ds.num_train()).collect();
                ds.batch(Split::Train, &idx)
            })
            .collect();

        let mut serial: Vec<Vec<f32>> = (0..2).map(|_| pre.clone()).collect();
        let mut sref = e.new_scratch();
        for s in 0..2 {
            e.train_step_sgd(&mut serial[s], &batches[s].x, &batches[s].y, 0.1, &mut sref)
                .unwrap();
        }

        let mut fused: Vec<Vec<f32>> = (0..2).map(|_| pre.clone()).collect();
        let mut scratch = e.new_scratch();
        let mut stats = Vec::new();
        let mut slots: Vec<FusedSlot> = fused
            .iter_mut()
            .zip(&batches)
            .map(|(p, b)| FusedSlot { params: p, x: &b.x, y: &b.y })
            .collect();
        e.train_step_sgd_fused(&mut slots, 0.1, &mut scratch, &mut stats).unwrap();

        let backbone = e.num_params() - e.head_size();
        for s in 0..2 {
            assert_eq!(fused[s][..backbone], pre[..backbone], "slot {s}: backbone frozen");
            assert_eq!(serial[s], fused[s], "slot {s}: fused == serial");
        }
    }

    /// A single-slot fused call degenerates to the plain serial step.
    #[test]
    fn fused_single_slot_equals_serial_step() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-s", "synth-mnist", "sgd", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 29).unwrap();
        let idx: Vec<usize> = (0..e.train_batch_size()).collect();
        let batch = ds.batch(Split::Train, &idx);
        let p0 = e.init_params().unwrap();

        let mut ps = p0.clone();
        let mut sref = e.new_scratch();
        let want = e.train_step_sgd(&mut ps, &batch.x, &batch.y, 0.05, &mut sref).unwrap();

        let mut pf = p0.clone();
        let mut scratch = e.new_scratch();
        let mut stats = Vec::new();
        let mut slots = [FusedSlot { params: &mut pf, x: &batch.x, y: &batch.y }];
        e.train_step_sgd_fused(&mut slots, 0.05, &mut scratch, &mut stats).unwrap();
        assert_eq!(ps, pf);
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].loss, want.loss);
    }

    /// A reused scratch arena produces bit-identical results to a fresh
    /// one — including when the arena was previously used at a larger
    /// batch size by a different op.
    #[test]
    fn reused_scratch_is_bit_identical_to_fresh() {
        let m = Arc::new(native_manifest());
        let e = NativeExecutor::load(&m, "mlp-m", "synth-mnist", "sgd", "full").unwrap();
        let ds = crate::datasets::Dataset::load(&m, "synth-mnist", 3).unwrap();
        let n = e.train_batch_size();
        let idx: Vec<usize> = (0..n).collect();
        let batch = ds.batch(Split::Train, &idx);
        let p0 = e.init_params().unwrap();

        // One arena reused across steps — pre-dirtied by a larger eval.
        let mut reused = e.new_scratch();
        let eidx: Vec<usize> = (0..e.eval_batch_size()).collect();
        let ebatch = ds.batch(Split::Test, &eidx);
        e.eval_batch(&p0, &ebatch.x, &ebatch.y, e.eval_batch_size(), &mut reused)
            .unwrap();
        let mut p_reused = p0.clone();
        for _ in 0..5 {
            e.train_step_sgd(&mut p_reused, &batch.x, &batch.y, 0.05, &mut reused)
                .unwrap();
        }

        // Fresh arena every step.
        let mut p_fresh = p0.clone();
        for _ in 0..5 {
            let mut fresh = e.new_scratch();
            e.train_step_sgd(&mut p_fresh, &batch.x, &batch.y, 0.05, &mut fresh)
                .unwrap();
        }
        assert_eq!(p_reused, p_fresh, "scratch reuse must be bit-exact");
    }
}
