//! Zoo listings — formatted views of the model/dataset registries
//! (paper Tables 1 and 2), backed by the AOT manifest.

use crate::runtime::Manifest;

/// Render the dataset registry as a paper-Table-1-style text table.
pub fn datasets_table(manifest: &Manifest) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<14} {:<22} {:>7} {:>8} {:>8} {:>5} {:>8}\n",
        "Group", "Dataset", "Classes", "Train", "Test", "IID", "Non-IID"
    ));
    s.push_str(&"-".repeat(80));
    s.push('\n');
    for d in manifest.datasets.values() {
        s.push_str(&format!(
            "{:<14} {:<22} {:>7} {:>8} {:>8} {:>5} {:>8}\n",
            d.group, d.name, d.num_classes, d.train_n, d.test_n, "yes", "yes"
        ));
    }
    s
}

/// Render the model zoo as a paper-Table-2-style text table.
pub fn models_table(manifest: &Manifest) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<12} {:<14} {:>10} {:>9} {:>9} {:>9}\n",
        "Family", "Variant", "Params", "Head", "FeatExt", "Finetune"
    ));
    s.push_str(&"-".repeat(70));
    s.push('\n');
    for z in manifest.zoo.values() {
        s.push_str(&format!(
            "{:<12} {:<14} {:>10} {:>9} {:>9} {:>9}\n",
            z.family,
            z.variant,
            z.num_params,
            z.head_size,
            if z.feature_extract { "yes" } else { "no" },
            if z.finetune { "yes" } else { "no" },
        ));
    }
    s
}

/// Render the built artifact bundles (what can actually run).
pub fn artifacts_table(manifest: &Manifest) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<28} {:>10} {:<12} entries\n",
        "Artifact", "Params", "Pretrained"
    ));
    s.push_str(&"-".repeat(96));
    s.push('\n');
    for a in &manifest.artifacts {
        let entries: Vec<&str> = a.entries.keys().map(|k| k.as_str()).collect();
        s.push_str(&format!(
            "{:<28} {:>10} {:<12} {}\n",
            a.id,
            a.num_params,
            if a.pretrained_file.is_some() {
                "yes"
            } else {
                "no"
            },
            entries.join(", ")
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| Manifest::load(dir).unwrap())
    }

    #[test]
    fn tables_render_all_rows() {
        let Some(m) = manifest() else { return };
        let t1 = datasets_table(&m);
        assert_eq!(t1.lines().count(), 2 + m.datasets.len());
        assert!(t1.contains("synth-cifar10"));
        let t2 = models_table(&m);
        assert_eq!(t2.lines().count(), 2 + m.zoo.len());
        assert!(t2.contains("lenet5"));
        let t3 = artifacts_table(&m);
        assert!(t3.contains("lenet5_synth-mnist"));
    }

    #[test]
    fn tables_render_native_manifest() {
        let m = Manifest::native();
        let t1 = datasets_table(&m);
        assert_eq!(t1.lines().count(), 2 + m.datasets.len());
        assert!(t1.contains("synth-mnist"));
        let t2 = models_table(&m);
        assert!(t2.contains("mlp-s"));
        let t3 = artifacts_table(&m);
        assert!(t3.contains("lenet5_synth-mnist"));
    }
}
