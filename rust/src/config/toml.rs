//! Minimal TOML parser — the experiment-config substrate.
//!
//! Supports the subset experiment configs need: `[section]` and
//! `[section.sub]` headers, `key = value` with strings, integers,
//! floats, booleans, and flat arrays, plus `#` comments. Nested keys
//! flatten to dotted paths: `[fl] agents = 10` → `"fl.agents"`.

use std::collections::BTreeMap;

use crate::util::error::{bail, Context, Result};

/// A TOML scalar or flat array.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// Parsed document: dotted-path -> value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("line {}: {raw:?}", lineno + 1);
            if let Some(inner) = line.strip_prefix('[') {
                let inner = inner
                    .strip_suffix(']')
                    .with_context(|| format!("unterminated section, {}", ctx()))?;
                let name = inner.trim();
                if name.is_empty() || !name.chars().all(is_key_char_dotted) {
                    bail!("bad section name, {}", ctx());
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .with_context(|| format!("expected key = value, {}", ctx()))?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(is_key_char_dotted) {
                bail!("bad key {key:?}, {}", ctx());
            }
            let value = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("bad value, {}", ctx()))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.values.insert(path.clone(), value).is_some() {
                bail!("duplicate key {path:?}, {}", ctx());
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.values.get(path)
    }

    pub fn get_str(&self, path: &str, default: &str) -> Result<String> {
        match self.values.get(path) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn get_int(&self, path: &str, default: i64) -> Result<i64> {
        match self.values.get(path) {
            Some(v) => v.as_int(),
            None => Ok(default),
        }
    }

    pub fn get_float(&self, path: &str, default: f64) -> Result<f64> {
        match self.values.get(path) {
            Some(v) => v.as_float(),
            None => Ok(default),
        }
    }

    pub fn get_bool(&self, path: &str, default: bool) -> Result<bool> {
        match self.values.get(path) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }
}

fn is_key_char_dotted(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = text.strip_prefix('"') {
        let end = rest
            .find('"')
            .with_context(|| "unterminated string".to_string())?;
        if !rest[end + 1..].trim().is_empty() {
            bail!("trailing characters after string");
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .with_context(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {text:?}")
}

/// Split array items on commas outside quotes.
fn split_top_level(text: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in text.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&text[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_experiment_config_shape() {
        let doc = TomlDoc::parse(
            r#"
            # quickstart config
            name = "demo"
            [fl]
            num_agents = 10          # inline comment
            sampling_ratio = 0.5
            split = "niid:3"
            [train]
            lr = 0.05
            use_pretrained = true
            tags = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name", "").unwrap(), "demo");
        assert_eq!(doc.get_int("fl.num_agents", 0).unwrap(), 10);
        assert!((doc.get_float("fl.sampling_ratio", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(doc.get_str("fl.split", "").unwrap(), "niid:3");
        assert!(doc.get_bool("train.use_pretrained", false).unwrap());
        assert_eq!(
            doc.get("train.tags").unwrap(),
            &TomlValue::Array(vec![
                TomlValue::Str("a".into()),
                TomlValue::Str("b".into())
            ])
        );
    }

    #[test]
    fn defaults_apply_when_missing() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.get_int("x", 7).unwrap(), 7);
        assert_eq!(doc.get_str("y", "d").unwrap(), "d");
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = TomlDoc::parse("lr = 1").unwrap();
        assert_eq!(doc.get_float("lr", 0.0).unwrap(), 1.0);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.get_str("s", "").unwrap(), "a#b");
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("= 3").is_err());
        assert!(TomlDoc::parse("x = \"open").is_err());
        assert!(TomlDoc::parse("x = 1\nx = 2").is_err());
        assert!(TomlDoc::parse("bad key = 1").is_err());
    }

    #[test]
    fn sectioned_duplicate_between_sections_ok() {
        let doc =
            TomlDoc::parse("[a]\nx = 1\n[b]\nx = 2\n").unwrap();
        assert_eq!(doc.get_int("a.x", 0).unwrap(), 1);
        assert_eq!(doc.get_int("b.x", 0).unwrap(), 2);
    }
}
