//! Experiment configuration (paper §3.2.4: `FLParams` + config files).
//!
//! TorchFL wraps all FL hyperparameters in an `FLParams` object fed to
//! the entrypoint; we mirror that, parsed from a TOML file (see
//! `configs/*.toml`) with CLI overrides applied on top.

pub mod toml;

use std::str::FromStr;

use crate::agents::RegistryMode;
use crate::engine::{
    AdversaryPlan, Backoff, ClockKind, FaultPlan, LatencyModel, RecoveryPolicy, RoundPolicy,
    SimTime,
};
use crate::federation::Scheme;
use crate::runtime::BackendKind;
use crate::util::error::{bail, Context, Error, Result};
pub use toml::{TomlDoc, TomlValue};

/// The local optimizer every sampled agent runs (paper §3.2.2).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Optimizer {
    /// Plain SGD; the default (and the only optimizer the fused
    /// lockstep path supports).
    #[default]
    Sgd,
    /// Adam with the runtime's built-in moment state.
    Adam,
}

impl Optimizer {
    /// Canonical config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Optimizer::Sgd => "sgd",
            Optimizer::Adam => "adam",
        }
    }
}

impl FromStr for Optimizer {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sgd" => Ok(Optimizer::Sgd),
            "adam" => Ok(Optimizer::Adam),
            other => bail!("unknown optimizer {other:?} (sgd | adam)"),
        }
    }
}

impl std::fmt::Display for Optimizer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which parameters local training updates (paper §3.2.2's model modes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Train the full model (from scratch, or finetune when
    /// `use_pretrained` is set); the default.
    #[default]
    Full,
    /// Feature extraction: freeze the backbone, train the head
    /// (requires `use_pretrained`).
    Featext,
}

impl Mode {
    /// Canonical config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Mode::Full => "full",
            Mode::Featext => "featext",
        }
    }
}

impl FromStr for Mode {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "full" => Ok(Mode::Full),
            "featext" => Ok(Mode::Featext),
            other => bail!("unknown mode {other:?} (full | featext)"),
        }
    }
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Where the round's cohort executes (config `transport.topology`,
/// CLI `--topology`, builder `Experiment::builder().topology(...)`).
///
/// Everything but [`Topology::Single`] runs the distributed executor
/// ([`crate::transport`]): the leader drives the round loop and
/// streams framed, fixed-point-quantised deltas back from workers.
/// The reduce is order-invariant integer math, so every topology
/// produces a final model byte-identical to `single` at the same seed.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Everything in one process (the in-process worker pool); default.
    #[default]
    Single,
    /// N worker *threads* in this process, each speaking the full wire
    /// protocol over an in-memory channel transport — the codec and
    /// leader/worker roles without process-spawning cost.
    InProc { workers: usize },
    /// N spawned worker *processes* on this host, connected over Unix
    /// domain sockets.
    MultiProcess { workers: usize },
    /// Listen on `addr` (e.g. `127.0.0.1:7070`) and wait for N workers
    /// to connect over TCP (`ferrisfl worker --connect tcp:<addr>`,
    /// possibly from other machines).
    Tcp { addr: String, workers: usize },
}

impl Topology {
    /// Stable family tag: `single | inproc | multiprocess | tcp`.
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Single => "single",
            Topology::InProc { .. } => "inproc",
            Topology::MultiProcess { .. } => "multiprocess",
            Topology::Tcp { .. } => "tcp",
        }
    }

    /// True for the in-process (non-distributed) topology.
    pub fn is_single(&self) -> bool {
        matches!(self, Topology::Single)
    }

    /// Transport worker endpoints (0 for `single`).
    pub fn num_workers(&self) -> usize {
        match self {
            Topology::Single => 0,
            Topology::InProc { workers }
            | Topology::MultiProcess { workers }
            | Topology::Tcp { workers, .. } => *workers,
        }
    }

    /// Range checks (workers ≥ 1, well-formed address).
    pub fn validate(&self) -> Result<()> {
        if !self.is_single() && self.num_workers() == 0 {
            bail!("topology {self} needs at least 1 worker");
        }
        if let Topology::Tcp { addr, .. } = self {
            if addr.is_empty() || !addr.contains(':') {
                bail!("tcp topology needs host:port, got {addr:?}");
            }
        }
        Ok(())
    }
}

impl FromStr for Topology {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        let parse_n = |rest: &str, what: &str| -> Result<usize> {
            let n: usize = rest
                .parse()
                .map_err(|_| crate::err!("bad worker count {rest:?} in {what} topology"))?;
            Ok(n)
        };
        if t == "single" {
            return Ok(Topology::Single);
        }
        if let Some(rest) = t.strip_prefix("inproc:") {
            return Ok(Topology::InProc { workers: parse_n(rest, "inproc")? });
        }
        if let Some(rest) = t.strip_prefix("multiprocess:") {
            return Ok(Topology::MultiProcess { workers: parse_n(rest, "multiprocess")? });
        }
        if let Some(rest) = s.trim().strip_prefix("tcp:") {
            let (addr, workers) = match rest.rsplit_once('/') {
                Some((addr, n)) => (addr, parse_n(n, "tcp")?),
                None => (rest, 1),
            };
            return Ok(Topology::Tcp { addr: addr.to_string(), workers });
        }
        bail!("unknown topology {s:?} (single | inproc:N | multiprocess:N | tcp:<addr>[/N])")
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Topology::Single => f.write_str("single"),
            Topology::InProc { workers } => write!(f, "inproc:{workers}"),
            Topology::MultiProcess { workers } => write!(f, "multiprocess:{workers}"),
            Topology::Tcp { addr, workers } => write!(f, "tcp:{addr}/{workers}"),
        }
    }
}

/// All hyperparameters of one FL experiment — the paper's `FLParams`.
#[derive(Clone, Debug)]
pub struct FlParams {
    /// Experiment name (log file prefix).
    pub experiment_name: String,
    /// Zoo model variant (must have an AOT artifact for `dataset`).
    pub model: String,
    /// Dataset registry entry.
    pub dataset: String,
    /// Total number of agents K.
    pub num_agents: usize,
    /// Fraction of agents sampled per round (paper: sampling_ratio).
    pub sampling_ratio: f64,
    /// Global federation rounds T (paper: global_epochs).
    pub global_epochs: usize,
    /// Local epochs per sampled agent per round.
    pub local_epochs: usize,
    /// Data distribution across agents.
    pub split: Scheme,
    /// Sampler name (see samplers::from_name).
    pub sampler: String,
    /// Aggregator name (see aggregators::from_name).
    pub aggregator: String,
    /// Local optimizer.
    pub optimizer: Optimizer,
    /// Training mode.
    pub mode: Mode,
    /// Start from the pretrained weights (finetune / featext)?
    pub use_pretrained: bool,
    /// Local learning rate.
    pub lr: f32,
    /// RNG seed for the whole experiment.
    pub seed: u64,
    /// Worker threads simulating parallel clients (0 = auto).
    pub workers: usize,
    /// Run each round's sampled cohort as one fused lockstep step
    /// stream on the leader (SGD only): every layer of every agent's
    /// step becomes one fused panel-parallel GEMM instead of per-agent
    /// pool jobs. Identical results; faster for small-model cohorts.
    pub fuse: bool,
    /// Evaluate the global model every N rounds (0 = only at the end).
    pub eval_every: usize,
    /// Optional cap on per-agent local steps per epoch (0 = full shard).
    pub max_local_steps: usize,
    /// Directory for CSV/JSONL logs (empty = no file logs).
    pub log_dir: String,
    /// Probability a sampled agent drops out of the round (cross-device
    /// FL straggler/failure simulation; 0 = nobody drops).
    pub dropout: f64,
    /// Server-side update defense (see defense::from_name).
    pub defense: String,
    /// Client update compression (see compression::from_name).
    pub compression: String,
    /// Execution backend: native (pure rust, default) or pjrt
    /// (AOT artifacts; requires the `pjrt` cargo feature).
    pub backend: BackendKind,
    /// Per-client latency model driving the round engine (config
    /// `engine.latency`; `none` = the lockstep degenerate policy).
    pub latency: LatencyModel,
    /// Round collection window in simulated seconds (`engine.deadline_secs`;
    /// 0 = no deadline, wait for every arrival).
    pub deadline_secs: f64,
    /// Buffered-aggregation goal count (`engine.agg_goal`; 0 = wait for
    /// the whole cohort): finalize the round once this many updates —
    /// fresh or stale — have arrived, FedBuff's buffer size K.
    pub agg_goal: usize,
    /// Staleness discount exponent for buffered updates
    /// (`engine.staleness_alpha`): weight ∝ `(1 + staleness)^-alpha`.
    pub staleness_alpha: f64,
    /// Engine clock (`engine.clock`): deterministic virtual time
    /// (default) or measured wall time.
    pub clock: ClockKind,
    /// Seeded fault plan for the engine (`faults.plan`): crash /
    /// delta-loss / delta-corruption probabilities and a churn trace.
    /// `fl.dropout` folds in as its crash-before-delivery term.
    pub faults: FaultPlan,
    /// Seeded Byzantine adversary plan (`faults.adversary`): sign-flip
    /// / scale / noise perturbations and a colluding fixed set, drawn
    /// from a dedicated salt stream keyed by `(seed, agent, round)` —
    /// the attack replays bit-identically at any worker count and in
    /// any topology. Unlike `faults.plan` casualties, a poisoned delta
    /// is *well-formed*: it passes the integrity checksum and must be
    /// defeated by the aggregation rule.
    pub adversary: AdversaryPlan,
    /// Max retry attempts per failed client per round (`faults.retry`;
    /// 0 = failures are final).
    pub retry: u32,
    /// Exponential retry backoff with seeded jitter (`faults.backoff`,
    /// `BASE[,FACTOR[,JITTER]]` in simulated seconds).
    pub backoff: Backoff,
    /// Minimum fraction of the planned cohort that must arrive for the
    /// round to aggregate (`faults.quorum`; 0 = no quorum). Below it the
    /// round is skipped with the global model unchanged.
    pub quorum: f64,
    /// Resample a replacement client from the available pool when one
    /// fails permanently (`faults.resample`).
    pub resample: bool,
    /// How agent state is materialized (`run.registry`, CLI
    /// `--registry`): `auto` (default) keeps the legacy eager
    /// scheme-partitioned agents for populations up to
    /// [`crate::agents::AUTO_VIRTUAL_THRESHOLD`] and virtualizes above
    /// it; `materialized` / `virtual` force the closed-form
    /// range-sharded registry (bit-identical pair, iid split only).
    pub registry: RegistryMode,
    /// Execution topology (`transport.topology`): single process
    /// (default) or the distributed leader/worker executor.
    pub topology: Topology,
    /// Straggler timeout in wall seconds for distributed rounds
    /// (`transport.timeout_secs`): how long the leader waits for a
    /// worker's delta before counting a failure against the
    /// `faults.retry` budget.
    pub transport_timeout_secs: f64,
}

impl Default for FlParams {
    fn default() -> Self {
        Self {
            experiment_name: "experiment".into(),
            model: "lenet5".into(),
            dataset: "synth-mnist".into(),
            num_agents: 10,
            sampling_ratio: 0.5,
            global_epochs: 10,
            local_epochs: 2,
            split: Scheme::Iid,
            sampler: "random".into(),
            aggregator: "fedavg".into(),
            optimizer: Optimizer::Sgd,
            mode: Mode::Full,
            use_pretrained: false,
            lr: 0.05,
            seed: 42,
            workers: 0,
            fuse: false,
            eval_every: 1,
            max_local_steps: 0,
            log_dir: String::new(),
            dropout: 0.0,
            defense: "none".into(),
            compression: "none".into(),
            backend: BackendKind::Native,
            latency: LatencyModel::None,
            deadline_secs: 0.0,
            agg_goal: 0,
            staleness_alpha: 0.5,
            clock: ClockKind::Virtual,
            faults: FaultPlan::default(),
            adversary: AdversaryPlan::default(),
            retry: 0,
            backoff: Backoff::default(),
            quorum: 0.0,
            resample: false,
            registry: RegistryMode::Auto,
            topology: Topology::Single,
            transport_timeout_secs: 30.0,
        }
    }
}

impl FlParams {
    /// Number of agents sampled per round (at least 1).
    pub fn sampled_per_round(&self) -> usize {
        ((self.num_agents as f64 * self.sampling_ratio).round() as usize)
            .clamp(1, self.num_agents)
    }

    /// Parse from TOML text (section `[fl]` + top-level `name`).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        Self::from_doc(&doc)
    }

    /// Parse from an already-parsed document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = FlParams::default();
        let p = FlParams {
            experiment_name: doc.get_str("name", &d.experiment_name)?,
            model: doc.get_str("fl.model", &d.model)?,
            dataset: doc.get_str("fl.dataset", &d.dataset)?,
            num_agents: doc.get_int("fl.num_agents", d.num_agents as i64)? as usize,
            sampling_ratio: doc.get_float("fl.sampling_ratio", d.sampling_ratio)?,
            global_epochs: doc.get_int("fl.global_epochs", d.global_epochs as i64)?
                as usize,
            local_epochs: doc.get_int("fl.local_epochs", d.local_epochs as i64)?
                as usize,
            split: Scheme::parse(&doc.get_str("fl.split", "iid")?)?,
            sampler: doc.get_str("fl.sampler", &d.sampler)?,
            aggregator: doc.get_str("fl.aggregator", &d.aggregator)?,
            optimizer: doc.get_str("train.optimizer", d.optimizer.name())?.parse()?,
            mode: doc.get_str("train.mode", d.mode.name())?.parse()?,
            use_pretrained: doc.get_bool("train.use_pretrained", d.use_pretrained)?,
            lr: doc.get_float("train.lr", d.lr as f64)? as f32,
            seed: doc.get_int("fl.seed", d.seed as i64)? as u64,
            workers: doc.get_int("run.workers", d.workers as i64)? as usize,
            fuse: doc.get_bool("run.fuse", d.fuse)?,
            eval_every: doc.get_int("run.eval_every", d.eval_every as i64)? as usize,
            max_local_steps: doc.get_int("run.max_local_steps", 0)? as usize,
            log_dir: doc.get_str("run.log_dir", &d.log_dir)?,
            dropout: doc.get_float("fl.dropout", 0.0)?,
            defense: doc.get_str("fl.defense", "none")?,
            compression: doc.get_str("fl.compression", "none")?,
            backend: doc.get_str("run.backend", d.backend.name())?.parse()?,
            latency: doc.get_str("engine.latency", &d.latency.to_string())?.parse()?,
            deadline_secs: doc.get_float("engine.deadline_secs", d.deadline_secs)?,
            agg_goal: doc.get_int("engine.agg_goal", d.agg_goal as i64)? as usize,
            staleness_alpha: doc
                .get_float("engine.staleness_alpha", d.staleness_alpha)?,
            clock: doc.get_str("engine.clock", d.clock.name())?.parse()?,
            faults: doc.get_str("faults.plan", &d.faults.to_string())?.parse()?,
            adversary: doc
                .get_str("faults.adversary", &d.adversary.to_string())?
                .parse()?,
            retry: doc.get_int("faults.retry", d.retry as i64)? as u32,
            backoff: doc.get_str("faults.backoff", &d.backoff.to_string())?.parse()?,
            quorum: doc.get_float("faults.quorum", d.quorum)?,
            resample: doc.get_bool("faults.resample", d.resample)?,
            registry: doc.get_str("run.registry", d.registry.name())?.parse()?,
            topology: doc
                .get_str("transport.topology", &d.topology.to_string())?
                .parse()?,
            transport_timeout_secs: doc
                .get_float("transport.timeout_secs", d.transport_timeout_secs)?,
        };
        p.validate()?;
        Ok(p)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml(&text)
    }

    /// Sanity-check ranges and enums.
    pub fn validate(&self) -> Result<()> {
        if self.num_agents == 0 {
            bail!("num_agents must be >= 1");
        }
        let r = self.sampling_ratio;
        if r.is_nan() || r <= 0.0 || r > 1.0 {
            bail!("sampling_ratio must be in (0, 1]");
        }
        if self.global_epochs == 0 || self.local_epochs == 0 {
            bail!("global_epochs and local_epochs must be >= 1");
        }
        if self.mode == Mode::Featext && !self.use_pretrained {
            bail!("featext mode requires use_pretrained = true");
        }
        if !self.lr.is_finite() || self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if self.fuse && self.optimizer != Optimizer::Sgd {
            bail!("fuse = true requires optimizer = sgd (the fused lockstep path is SGD-only)");
        }
        if !(0.0..=1.0).contains(&self.dropout) {
            bail!("dropout must be in [0, 1] (1 = every sampled agent drops, rounds skip)");
        }
        self.latency.validate()?;
        if !self.deadline_secs.is_finite() || self.deadline_secs < 0.0 {
            bail!("deadline_secs must be finite and >= 0 (0 = no deadline)");
        }
        if !self.staleness_alpha.is_finite() || self.staleness_alpha < 0.0 {
            bail!("staleness_alpha must be finite and >= 0");
        }
        if !self.registry.uses_legacy_partition(self.num_agents)
            && self.split != Scheme::Iid
        {
            bail!(
                "registry = {} with {} agents uses closed-form range shards, \
                 which requires split = iid (got {}); use registry = auto with \
                 <= {} agents for partitioned splits",
                self.registry,
                self.num_agents,
                self.split,
                crate::agents::AUTO_VIRTUAL_THRESHOLD
            );
        }
        self.faults.validate()?;
        self.adversary.validate()?;
        self.recovery_policy().validate()?;
        self.topology.validate()?;
        if !self.topology.is_single() {
            // Distributed rounds replicate the *degenerate* engine path
            // bit-for-bit; knobs that change simulation semantics (sim
            // latency, deadlines, buffering, injected faults beyond
            // dropout, replacement resampling, quorum skips) have no
            // wire equivalent yet, so reject them loudly rather than
            // diverge silently. `retry`/`backoff` stay legal: in
            // distributed mode they are the wire-level resend budget.
            if self.backend != BackendKind::Native {
                bail!("topology {} requires the native backend", self.topology);
            }
            if self.fuse {
                bail!("fuse = true is incompatible with topology {}", self.topology);
            }
            if self.latency != LatencyModel::None
                || self.deadline_secs > 0.0
                || self.agg_goal > 0
                || self.clock != ClockKind::Virtual
            {
                bail!(
                    "topology {} supports only the lockstep engine policy \
                     (no latency model, deadline, agg_goal, or wall clock)",
                    self.topology
                );
            }
            if !self.fault_plan().is_vanilla() || self.resample || self.quorum > 0.0 {
                bail!(
                    "topology {} supports dropout but not injected faults, \
                     resampling, or quorum",
                    self.topology
                );
            }
            let t = self.transport_timeout_secs;
            if !t.is_finite() || t <= 0.0 {
                bail!("transport.timeout_secs must be finite and > 0, got {t}");
            }
        }
        Ok(())
    }

    /// Serialize the fields a remote worker needs into TOML text — the
    /// payload of the wire `Init` frame. The worker parses it with
    /// [`FlParams::from_toml`] and rebuilds dataset, shards, and
    /// runtime deterministically from the seed; leader-only concerns
    /// (topology, logging, eval cadence, pool size) are pinned to
    /// worker-appropriate values rather than forwarded.
    pub fn to_wire_toml(&self) -> String {
        // TOML floats must contain a dot or exponent; Rust's shortest
        // round-trip `Display` for finite floats always prints a dot
        // for integral values except via `{}` on e.g. 1.0 -> "1", so
        // append ".0" when needed.
        fn float(v: f64) -> String {
            let s = v.to_string();
            if s.contains('.') || s.contains('e') || s.contains("inf") || s.contains("NaN") {
                s
            } else {
                format!("{s}.0")
            }
        }
        // The first-party TOML parser has no escape sequences: a string
        // ends at the first `"`. Registry names never contain quotes;
        // a quoted experiment name degrades to `'` rather than
        // producing an unparseable frame.
        fn quote(s: &str) -> String {
            format!("\"{}\"", s.replace('"', "'").replace('\n', " "))
        }
        let mut out = String::new();
        out.push_str(&format!("name = {}\n", quote(&self.experiment_name)));
        out.push_str("[fl]\n");
        out.push_str(&format!("model = {}\n", quote(&self.model)));
        out.push_str(&format!("dataset = {}\n", quote(&self.dataset)));
        out.push_str(&format!("num_agents = {}\n", self.num_agents));
        out.push_str(&format!("sampling_ratio = {}\n", float(self.sampling_ratio)));
        out.push_str(&format!("global_epochs = {}\n", self.global_epochs));
        out.push_str(&format!("local_epochs = {}\n", self.local_epochs));
        out.push_str(&format!("split = {}\n", quote(&self.split.to_string())));
        out.push_str(&format!("sampler = {}\n", quote(&self.sampler)));
        out.push_str(&format!("aggregator = {}\n", quote(&self.aggregator)));
        out.push_str(&format!("seed = {}\n", self.seed as i64));
        out.push_str(&format!("dropout = {}\n", float(self.dropout)));
        out.push_str(&format!("defense = {}\n", quote(&self.defense)));
        out.push_str(&format!("compression = {}\n", quote(&self.compression)));
        out.push_str("[train]\n");
        out.push_str(&format!("optimizer = {}\n", quote(self.optimizer.name())));
        out.push_str(&format!("mode = {}\n", quote(self.mode.name())));
        out.push_str(&format!("use_pretrained = {}\n", self.use_pretrained));
        out.push_str(&format!("lr = {}\n", float(self.lr as f64)));
        out.push_str("[run]\n");
        out.push_str("workers = 1\n");
        out.push_str("eval_every = 0\n");
        out.push_str(&format!("max_local_steps = {}\n", self.max_local_steps));
        out.push_str("backend = \"native\"\n");
        // The registry mode must ride the wire: both sides resolve the
        // agent→shard map as a pure function of (num_agents, mode,
        // train size), so leader and worker must agree on the mode.
        out.push_str(&format!("registry = {}\n", quote(self.registry.name())));
        // The adversary plan must ride the wire: workers poison their
        // own deltas *before* quantize+frame, so the leader-side
        // checksum passes and only the aggregation rule stands between
        // the attack and the global model.
        out.push_str("[faults]\n");
        out.push_str(&format!("adversary = {}\n", quote(&self.adversary.to_string())));
        out
    }

    /// The engine scheduling policy this config asks for (with the
    /// defaults — zero latency, no deadline, no goal — this is the
    /// degenerate policy, i.e. the bit-exact lockstep loop).
    pub fn round_policy(&self) -> RoundPolicy {
        RoundPolicy {
            latency: self.latency.clone(),
            deadline: (self.deadline_secs > 0.0)
                .then(|| SimTime::from_secs_f64(self.deadline_secs)),
            goal: (self.agg_goal > 0).then_some(self.agg_goal),
            staleness_alpha: self.staleness_alpha,
            clock: self.clock,
            faults: self.fault_plan(),
            recovery: self.recovery_policy(),
        }
    }

    /// The effective fault plan: `faults.plan` with `fl.dropout` folded
    /// in as the crash-before-delivery probability (the legacy knob
    /// takes precedence so existing configs keep their exact draws).
    pub fn fault_plan(&self) -> FaultPlan {
        let mut plan = self.faults.clone();
        if self.dropout > 0.0 {
            plan.dropout = self.dropout;
        }
        plan
    }

    /// The failure-recovery policy (`faults.retry` / `faults.backoff` /
    /// `faults.quorum` / `faults.resample`).
    pub fn recovery_policy(&self) -> RecoveryPolicy {
        RecoveryPolicy {
            max_retries: self.retry,
            backoff: self.backoff,
            resample: self.resample,
            quorum: self.quorum,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        FlParams::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let p = FlParams::from_toml(
            r#"
            name = "fig8i"
            [fl]
            model = "lenet5"
            dataset = "synth-mnist"
            num_agents = 100
            sampling_ratio = 0.1
            global_epochs = 50
            local_epochs = 5
            split = "niid:2"
            sampler = "random"
            aggregator = "fedavg"
            seed = 7
            [train]
            optimizer = "sgd"
            lr = 0.05
            [run]
            workers = 4
            eval_every = 5
            "#,
        )
        .unwrap();
        assert_eq!(p.experiment_name, "fig8i");
        assert_eq!(p.num_agents, 100);
        assert_eq!(p.sampled_per_round(), 10);
        assert_eq!(p.split, Scheme::NonIid { niid_factor: 2 });
        assert_eq!(p.eval_every, 5);
    }

    #[test]
    fn sampled_per_round_clamps() {
        let mut p = FlParams::default();
        p.num_agents = 3;
        p.sampling_ratio = 0.01;
        assert_eq!(p.sampled_per_round(), 1);
        p.sampling_ratio = 1.0;
        assert_eq!(p.sampled_per_round(), 3);
    }

    #[test]
    fn rejects_invalid() {
        let mut p = FlParams::default();
        p.sampling_ratio = 0.0;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.sampling_ratio = f64::NAN;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.mode = Mode::Featext;
        p.use_pretrained = false;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.deadline_secs = -1.0;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.staleness_alpha = f64::NAN;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.latency = LatencyModel::Constant(f64::INFINITY);
        assert!(p.validate().is_err());
    }

    #[test]
    fn enums_parse_and_display() {
        // Stringly-typed fields became enums; TOML/CLI names round-trip.
        assert_eq!("sgd".parse::<Optimizer>().unwrap(), Optimizer::Sgd);
        assert_eq!(" Adam ".parse::<Optimizer>().unwrap(), Optimizer::Adam);
        assert!("rmsprop".parse::<Optimizer>().is_err());
        assert_eq!(Optimizer::Adam.to_string(), "adam");

        assert_eq!("full".parse::<Mode>().unwrap(), Mode::Full);
        assert_eq!("featext".parse::<Mode>().unwrap(), Mode::Featext);
        assert!("partial".parse::<Mode>().is_err());
        assert_eq!(Mode::Featext.to_string(), "featext");

        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("tpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn bad_enum_values_fail_toml_parse() {
        for toml in [
            "name = \"x\"\n[train]\noptimizer = \"rmsprop\"\n",
            "name = \"x\"\n[train]\nmode = \"partial\"\n",
            "name = \"x\"\n[run]\nbackend = \"tpu\"\n",
            "name = \"x\"\n[engine]\nclock = \"cuckoo\"\n",
            "name = \"x\"\n[engine]\nlatency = \"warp:9\"\n",
            "name = \"x\"\n[faults]\nplan = \"warp:0.1\"\n",
            "name = \"x\"\n[faults]\nadversary = \"adv:warp:0.1\"\n",
            "name = \"x\"\n[faults]\nadversary = \"adv:signflip:1.5\"\n",
            "name = \"x\"\n[faults]\nbackoff = \"1,0.5\"\n",
            "name = \"x\"\n[transport]\ntopology = \"mesh:3\"\n",
            "name = \"x\"\n[transport]\ntopology = \"multiprocess:zero\"\n",
        ] {
            assert!(FlParams::from_toml(toml).is_err(), "{toml}");
        }
    }

    #[test]
    fn topology_parses_displays_and_validates() {
        assert_eq!("single".parse::<Topology>().unwrap(), Topology::Single);
        assert_eq!(
            " InProc:3 ".parse::<Topology>().unwrap(),
            Topology::InProc { workers: 3 }
        );
        assert_eq!(
            "multiprocess:2".parse::<Topology>().unwrap(),
            Topology::MultiProcess { workers: 2 }
        );
        assert_eq!(
            "tcp:127.0.0.1:7070".parse::<Topology>().unwrap(),
            Topology::Tcp { addr: "127.0.0.1:7070".into(), workers: 1 }
        );
        assert_eq!(
            "tcp:127.0.0.1:7070/4".parse::<Topology>().unwrap(),
            Topology::Tcp { addr: "127.0.0.1:7070".into(), workers: 4 }
        );
        assert!("ring:4".parse::<Topology>().is_err());
        assert!("multiprocess:".parse::<Topology>().is_err());
        // Display round-trips through FromStr.
        for t in [
            Topology::Single,
            Topology::InProc { workers: 2 },
            Topology::MultiProcess { workers: 8 },
            Topology::Tcp { addr: "10.0.0.2:9000".into(), workers: 3 },
        ] {
            assert_eq!(t.to_string().parse::<Topology>().unwrap(), t);
        }
        // validate(): zero workers and bad addresses are rejected.
        assert!(Topology::MultiProcess { workers: 0 }.validate().is_err());
        assert!(Topology::Tcp { addr: "nohost".into(), workers: 1 }.validate().is_err());
        assert!(Topology::Single.validate().is_ok());
        assert_eq!(Topology::InProc { workers: 5 }.num_workers(), 5);
        assert!(Topology::Single.is_single());
    }

    #[test]
    fn transport_section_parses_and_gates_engine_knobs() {
        let p = FlParams::from_toml(
            r#"
            name = "dist"
            [transport]
            topology = "multiprocess:2"
            timeout_secs = 5.0
            "#,
        )
        .unwrap();
        assert_eq!(p.topology, Topology::MultiProcess { workers: 2 });
        assert_eq!(p.transport_timeout_secs, 5.0);
        assert_eq!(FlParams::default().topology, Topology::Single);

        // Wire retries are legal — they are the resend budget…
        let mut p = p;
        p.retry = 2;
        p.validate().unwrap();
        // …but sim-semantics knobs have no distributed equivalent.
        let base = p.clone();
        let mut q = base.clone();
        q.latency = "lognormal:0.5,0.8".parse().unwrap();
        assert!(q.validate().is_err());
        let mut q = base.clone();
        q.agg_goal = 4;
        assert!(q.validate().is_err());
        let mut q = base.clone();
        q.fuse = true;
        assert!(q.validate().is_err());
        let mut q = base.clone();
        q.faults = "crash:0.2".parse().unwrap();
        assert!(q.validate().is_err());
        let mut q = base.clone();
        q.quorum = 0.5;
        assert!(q.validate().is_err());
        let mut q = base.clone();
        q.transport_timeout_secs = 0.0;
        assert!(q.validate().is_err());
        // Dropout alone stays legal (the degenerate fault plan).
        let mut q = base.clone();
        q.dropout = 0.25;
        q.validate().unwrap();
        // All of those are fine under `single`.
        let mut q = base;
        q.topology = Topology::Single;
        q.agg_goal = 4;
        q.validate().unwrap();
    }

    #[test]
    fn wire_toml_round_trips_the_training_config() {
        let mut p = FlParams::default();
        p.experiment_name = "wire-exp".into();
        p.num_agents = 37;
        p.sampling_ratio = 0.25;
        p.split = Scheme::NonIid { niid_factor: 2 };
        p.seed = 0xDEAD_BEEF;
        p.lr = 0.05;
        p.local_epochs = 3;
        p.dropout = 0.125;
        p.workers = 6;
        p.eval_every = 2;
        p.topology = Topology::InProc { workers: 2 };
        p.adversary = "adv:signflip:0.25;adv:collude:-4,0.3".parse().unwrap();
        let q = FlParams::from_toml(&p.to_wire_toml()).unwrap();
        // Everything that shapes local training + sharding survives…
        assert_eq!(q.experiment_name, p.experiment_name);
        assert_eq!(q.num_agents, p.num_agents);
        assert_eq!(q.sampling_ratio, p.sampling_ratio);
        assert_eq!(q.split, p.split);
        assert_eq!(q.seed, p.seed);
        assert_eq!(q.lr, p.lr);
        assert_eq!(q.local_epochs, p.local_epochs);
        assert_eq!(q.dropout, p.dropout);
        // The adversary plan rides the wire so workers poison on-device.
        assert_eq!(q.adversary, p.adversary);
        // The registry mode rides the wire so both sides resolve the
        // same agent→shard map.
        assert_eq!(q.registry, p.registry);
        // …while leader-only knobs are pinned for the worker.
        assert_eq!(q.topology, Topology::Single);
        assert_eq!(q.workers, 1);
        assert_eq!(q.eval_every, 0);
        assert!(q.log_dir.is_empty());
    }

    #[test]
    fn engine_section_parses_and_maps_to_policy() {
        let p = FlParams::from_toml(
            r#"
            name = "fedbuff"
            [engine]
            latency = "lognormal:0.5,0.8"
            deadline_secs = 1.5
            agg_goal = 8
            staleness_alpha = 0.25
            clock = "virtual"
            "#,
        )
        .unwrap();
        assert_eq!(p.latency, LatencyModel::Lognormal { median: 0.5, sigma: 0.8 });
        assert_eq!(p.deadline_secs, 1.5);
        assert_eq!(p.agg_goal, 8);
        let pol = p.round_policy();
        assert!(!pol.is_degenerate());
        assert!(pol.buffered());
        assert_eq!(pol.deadline.unwrap(), SimTime::from_secs_f64(1.5));
        assert_eq!(pol.goal, Some(8));
        // The defaults are the degenerate (lockstep) policy.
        let d = FlParams::default().round_policy();
        assert!(d.is_degenerate());
        assert_eq!(d, RoundPolicy::lockstep());
    }

    #[test]
    fn faults_section_parses_and_maps_to_policy() {
        let p = FlParams::from_toml(
            r#"
            name = "chaos"
            [faults]
            plan = "crash:0.2;drop:0.1;churn:flapping:60,0.8"
            adversary = "adv:scale:-5,0.3;adv:noise:0.5,0.1"
            retry = 2
            backoff = "0.5,2,0.25"
            quorum = 0.4
            resample = true
            "#,
        )
        .unwrap();
        assert_eq!(p.retry, 2);
        assert!(p.resample);
        assert_eq!(p.adversary.scale, -5.0);
        assert_eq!(p.adversary.scale_p, 0.3);
        assert_eq!(p.adversary.noise_sigma, 0.5);
        assert_eq!(p.adversary.noise_p, 0.1);
        assert!(FlParams::default().adversary.is_none());
        let pol = p.round_policy();
        assert!(!pol.is_degenerate());
        assert!(pol.chaos_active());
        assert_eq!(pol.faults.crash, 0.2);
        assert_eq!(pol.recovery.max_retries, 2);
        assert_eq!(pol.recovery.backoff, "0.5,2,0.25".parse().unwrap());
        assert_eq!(pol.recovery.quorum, 0.4);
        // The defaults are fault-free with no recovery.
        let d = FlParams::default();
        assert!(d.fault_plan().is_inert());
        assert!(d.recovery_policy().is_none());
        assert!(!d.round_policy().chaos_active());
    }

    #[test]
    fn legacy_dropout_folds_into_the_fault_plan() {
        let mut p = FlParams::default();
        p.dropout = 0.25;
        let plan = p.fault_plan();
        assert_eq!(plan.dropout, 0.25);
        assert!(plan.is_vanilla());
        assert!(p.round_policy().is_degenerate(), "dropout alone keeps lockstep parity");
        // fl.dropout takes precedence over a plan's own dropout term.
        p.faults = "dropout:0.9".parse().unwrap();
        assert_eq!(p.fault_plan().dropout, 0.25);
        // dropout = 1.0 is legal: every round skips, model unchanged.
        p.dropout = 1.0;
        p.validate().unwrap();
        p.dropout = 1.1;
        assert!(p.validate().is_err());
        // Recovery knobs are validated too.
        let mut p = FlParams::default();
        p.quorum = 1.5;
        assert!(p.validate().is_err());
        let mut p = FlParams::default();
        p.backoff.factor = 0.5;
        assert!(p.validate().is_err());
    }

    #[test]
    fn fuse_parses_and_requires_sgd() {
        let p = FlParams::from_toml(
            r#"
            name = "f"
            [run]
            fuse = true
            "#,
        )
        .unwrap();
        assert!(p.fuse);
        assert!(!FlParams::default().fuse);

        let mut p = FlParams::default();
        p.fuse = true;
        p.optimizer = Optimizer::Adam;
        assert!(p.validate().is_err(), "fuse is SGD-only");
        p.optimizer = Optimizer::Sgd;
        p.validate().unwrap();
    }

    #[test]
    fn registry_parses_validates_and_rides_the_wire() {
        let p = FlParams::from_toml(
            r#"
            name = "big"
            [fl]
            num_agents = 1000000
            sampling_ratio = 0.000064
            [run]
            registry = "virtual"
            "#,
        )
        .unwrap();
        assert_eq!(p.registry, RegistryMode::Virtual);
        assert_eq!(p.sampled_per_round(), 64);
        assert_eq!(FlParams::default().registry, RegistryMode::Auto);

        // Explicit modes use range shards → iid only.
        let mut q = FlParams::default();
        q.registry = RegistryMode::Materialized;
        q.validate().unwrap();
        q.split = Scheme::NonIid { niid_factor: 2 };
        assert!(q.validate().is_err());

        // Auto above the threshold virtualizes, so it too needs iid.
        let mut q = FlParams::default();
        q.num_agents = crate::agents::AUTO_VIRTUAL_THRESHOLD + 1;
        q.sampling_ratio = 0.001;
        q.split = Scheme::Dirichlet { alpha: 0.5 };
        assert!(q.validate().is_err());
        q.split = Scheme::Iid;
        q.validate().unwrap();

        // An explicit mode survives the wire TOML.
        let mut q = FlParams::default();
        q.registry = RegistryMode::Virtual;
        let r = FlParams::from_toml(&q.to_wire_toml()).unwrap();
        assert_eq!(r.registry, RegistryMode::Virtual);

        assert!(FlParams::from_toml(
            "name = \"x\"\n[run]\nregistry = \"eager\"\n"
        )
        .is_err());
    }

    #[test]
    fn backend_parses_from_toml() {
        let p = FlParams::from_toml(
            r#"
            name = "b"
            [run]
            backend = "native"
            "#,
        )
        .unwrap();
        assert_eq!(p.backend, BackendKind::Native);
        assert_eq!(FlParams::default().backend, BackendKind::Native);
    }
}
