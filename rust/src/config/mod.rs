//! Experiment configuration (paper §3.2.4: `FLParams` + config files).
//!
//! TorchFL wraps all FL hyperparameters in an `FLParams` object fed to
//! the entrypoint; we mirror that, parsed from a TOML file (see
//! `configs/*.toml`) with CLI overrides applied on top.

pub mod toml;

use crate::federation::Scheme;
use crate::runtime::BackendKind;
use crate::util::error::{bail, Context, Result};
pub use toml::{TomlDoc, TomlValue};

/// All hyperparameters of one FL experiment — the paper's `FLParams`.
#[derive(Clone, Debug)]
pub struct FlParams {
    /// Experiment name (log file prefix).
    pub experiment_name: String,
    /// Zoo model variant (must have an AOT artifact for `dataset`).
    pub model: String,
    /// Dataset registry entry.
    pub dataset: String,
    /// Total number of agents K.
    pub num_agents: usize,
    /// Fraction of agents sampled per round (paper: sampling_ratio).
    pub sampling_ratio: f64,
    /// Global federation rounds T (paper: global_epochs).
    pub global_epochs: usize,
    /// Local epochs per sampled agent per round.
    pub local_epochs: usize,
    /// Data distribution across agents.
    pub split: Scheme,
    /// Sampler name (see samplers::from_name).
    pub sampler: String,
    /// Aggregator name (see aggregators::from_name).
    pub aggregator: String,
    /// Local optimizer: "sgd" or "adam".
    pub optimizer: String,
    /// Training mode: "full" (scratch/finetune) or "featext".
    pub mode: String,
    /// Start from the pretrained weights (finetune / featext)?
    pub use_pretrained: bool,
    /// Local learning rate.
    pub lr: f32,
    /// RNG seed for the whole experiment.
    pub seed: u64,
    /// Worker threads simulating parallel clients (0 = auto).
    pub workers: usize,
    /// Run each round's sampled cohort as one fused lockstep step
    /// stream on the leader (SGD only): every layer of every agent's
    /// step becomes one fused panel-parallel GEMM instead of per-agent
    /// pool jobs. Identical results; faster for small-model cohorts.
    pub fuse: bool,
    /// Evaluate the global model every N rounds (0 = only at the end).
    pub eval_every: usize,
    /// Optional cap on per-agent local steps per epoch (0 = full shard).
    pub max_local_steps: usize,
    /// Directory for CSV/JSONL logs (empty = no file logs).
    pub log_dir: String,
    /// Probability a sampled agent drops out of the round (cross-device
    /// FL straggler/failure simulation; 0 = nobody drops).
    pub dropout: f64,
    /// Server-side update defense (see defense::from_name).
    pub defense: String,
    /// Client update compression (see compression::from_name).
    pub compression: String,
    /// Execution backend: "native" (pure rust, default) or "pjrt"
    /// (AOT artifacts; requires the `pjrt` cargo feature).
    pub backend: String,
}

impl Default for FlParams {
    fn default() -> Self {
        Self {
            experiment_name: "experiment".into(),
            model: "lenet5".into(),
            dataset: "synth-mnist".into(),
            num_agents: 10,
            sampling_ratio: 0.5,
            global_epochs: 10,
            local_epochs: 2,
            split: Scheme::Iid,
            sampler: "random".into(),
            aggregator: "fedavg".into(),
            optimizer: "sgd".into(),
            mode: "full".into(),
            use_pretrained: false,
            lr: 0.05,
            seed: 42,
            workers: 0,
            fuse: false,
            eval_every: 1,
            max_local_steps: 0,
            log_dir: String::new(),
            dropout: 0.0,
            defense: "none".into(),
            compression: "none".into(),
            backend: "native".into(),
        }
    }
}

impl FlParams {
    /// Number of agents sampled per round (at least 1).
    pub fn sampled_per_round(&self) -> usize {
        ((self.num_agents as f64 * self.sampling_ratio).round() as usize)
            .clamp(1, self.num_agents)
    }

    /// Parse from TOML text (section `[fl]` + top-level `name`).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        Self::from_doc(&doc)
    }

    /// Parse from an already-parsed document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = FlParams::default();
        let p = FlParams {
            experiment_name: doc.get_str("name", &d.experiment_name)?,
            model: doc.get_str("fl.model", &d.model)?,
            dataset: doc.get_str("fl.dataset", &d.dataset)?,
            num_agents: doc.get_int("fl.num_agents", d.num_agents as i64)? as usize,
            sampling_ratio: doc.get_float("fl.sampling_ratio", d.sampling_ratio)?,
            global_epochs: doc.get_int("fl.global_epochs", d.global_epochs as i64)?
                as usize,
            local_epochs: doc.get_int("fl.local_epochs", d.local_epochs as i64)?
                as usize,
            split: Scheme::parse(&doc.get_str("fl.split", "iid")?)?,
            sampler: doc.get_str("fl.sampler", &d.sampler)?,
            aggregator: doc.get_str("fl.aggregator", &d.aggregator)?,
            optimizer: doc.get_str("train.optimizer", &d.optimizer)?,
            mode: doc.get_str("train.mode", &d.mode)?,
            use_pretrained: doc.get_bool("train.use_pretrained", d.use_pretrained)?,
            lr: doc.get_float("train.lr", d.lr as f64)? as f32,
            seed: doc.get_int("fl.seed", d.seed as i64)? as u64,
            workers: doc.get_int("run.workers", d.workers as i64)? as usize,
            fuse: doc.get_bool("run.fuse", d.fuse)?,
            eval_every: doc.get_int("run.eval_every", d.eval_every as i64)? as usize,
            max_local_steps: doc.get_int("run.max_local_steps", 0)? as usize,
            log_dir: doc.get_str("run.log_dir", &d.log_dir)?,
            dropout: doc.get_float("fl.dropout", 0.0)?,
            defense: doc.get_str("fl.defense", "none")?,
            compression: doc.get_str("fl.compression", "none")?,
            backend: doc.get_str("run.backend", &d.backend)?,
        };
        p.validate()?;
        Ok(p)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml(&text)
    }

    /// Sanity-check ranges and enums.
    pub fn validate(&self) -> Result<()> {
        if self.num_agents == 0 {
            bail!("num_agents must be >= 1");
        }
        let r = self.sampling_ratio;
        if r.is_nan() || r <= 0.0 || r > 1.0 {
            bail!("sampling_ratio must be in (0, 1]");
        }
        if self.global_epochs == 0 || self.local_epochs == 0 {
            bail!("global_epochs and local_epochs must be >= 1");
        }
        if !matches!(self.optimizer.as_str(), "sgd" | "adam") {
            bail!("optimizer must be sgd or adam, got {:?}", self.optimizer);
        }
        if !matches!(self.mode.as_str(), "full" | "featext") {
            bail!("mode must be full or featext, got {:?}", self.mode);
        }
        if self.mode == "featext" && !self.use_pretrained {
            bail!("featext mode requires use_pretrained = true");
        }
        if !self.lr.is_finite() || self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if self.fuse && self.optimizer != "sgd" {
            bail!("fuse = true requires optimizer = sgd (the fused lockstep path is SGD-only)");
        }
        if !(0.0..1.0).contains(&self.dropout) {
            bail!("dropout must be in [0, 1)");
        }
        // Fails fast on unknown backends (whether the build can actually
        // serve "pjrt" is decided at executor-construction time).
        BackendKind::parse(&self.backend)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        FlParams::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let p = FlParams::from_toml(
            r#"
            name = "fig8i"
            [fl]
            model = "lenet5"
            dataset = "synth-mnist"
            num_agents = 100
            sampling_ratio = 0.1
            global_epochs = 50
            local_epochs = 5
            split = "niid:2"
            sampler = "random"
            aggregator = "fedavg"
            seed = 7
            [train]
            optimizer = "sgd"
            lr = 0.05
            [run]
            workers = 4
            eval_every = 5
            "#,
        )
        .unwrap();
        assert_eq!(p.experiment_name, "fig8i");
        assert_eq!(p.num_agents, 100);
        assert_eq!(p.sampled_per_round(), 10);
        assert_eq!(p.split, Scheme::NonIid { niid_factor: 2 });
        assert_eq!(p.eval_every, 5);
    }

    #[test]
    fn sampled_per_round_clamps() {
        let mut p = FlParams::default();
        p.num_agents = 3;
        p.sampling_ratio = 0.01;
        assert_eq!(p.sampled_per_round(), 1);
        p.sampling_ratio = 1.0;
        assert_eq!(p.sampled_per_round(), 3);
    }

    #[test]
    fn rejects_invalid() {
        let mut p = FlParams::default();
        p.sampling_ratio = 0.0;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.sampling_ratio = f64::NAN;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.optimizer = "rmsprop".into();
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.mode = "featext".into();
        p.use_pretrained = false;
        assert!(p.validate().is_err());

        let mut p = FlParams::default();
        p.backend = "tpu".into();
        assert!(p.validate().is_err());
    }

    #[test]
    fn fuse_parses_and_requires_sgd() {
        let p = FlParams::from_toml(
            r#"
            name = "f"
            [run]
            fuse = true
            "#,
        )
        .unwrap();
        assert!(p.fuse);
        assert!(!FlParams::default().fuse);

        let mut p = FlParams::default();
        p.fuse = true;
        p.optimizer = "adam".into();
        assert!(p.validate().is_err(), "fuse is SGD-only");
        p.optimizer = "sgd".into();
        p.validate().unwrap();
    }

    #[test]
    fn backend_parses_from_toml() {
        let p = FlParams::from_toml(
            r#"
            name = "b"
            [run]
            backend = "native"
            "#,
        )
        .unwrap();
        assert_eq!(p.backend, "native");
        assert_eq!(FlParams::default().backend, "native");
    }
}
