//! First-party error handling (anyhow-style, zero dependencies).
//!
//! The crate previously leaned on the `anyhow` crate; to keep the
//! default build fully hermetic (no registry access, no vendored set),
//! this module provides the small slice of that API the codebase uses:
//! a string-backed [`Error`], a [`Result`] alias, the [`Context`]
//! extension for `Result` and `Option`, and the [`err!`]/[`bail!`]
//! macros.
//!
//! `Error` deliberately does **not** implement `std::error::Error`, so
//! the blanket `From<E: std::error::Error>` conversion (which powers
//! `?` on io/parse errors) cannot overlap the reflexive `From` impl.

use std::fmt;

/// A boxed-string error with its context chain pre-rendered.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Self {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = std::error::Error::source(&e);
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Self { msg }
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (`Result`) or absences (`Option`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad value {v}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("bad value {v}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

// Make the macros importable from this module as well as the crate root.
pub use crate::{bail, err};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 7)
    }

    #[test]
    fn bail_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner 7");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        let e = parse("nope").unwrap_err();
        assert!(format!("{e}").contains("invalid digit"), "{e}");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(3).context("never seen").unwrap(), 3);
    }

    #[test]
    fn io_error_keeps_source_chain() {
        let e: Error = std::fs::read_to_string("/nonexistent-ferrisfl-err")
            .context("reading config")
            .unwrap_err();
        let text = format!("{e}");
        assert!(text.starts_with("reading config:"), "{text}");
    }
}
