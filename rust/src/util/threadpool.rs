//! Worker pools over std threads: the agent-level [`WorkerPool`] and
//! the panel-level [`PanelPool`].
//!
//! The FL entrypoint dispatches each sampled agent's local training round
//! onto the [`WorkerPool`] — the simulated analogue of clients training
//! in parallel on their own devices. Workers own thread-local state
//! (their own PJRT client + compiled executables, since the `xla`
//! wrappers are `Rc`-based and not `Send`), created lazily by an `init`
//! closure the first time a job runs on that worker.
//!
//! The [`PanelPool`] sits *under* that layer: the GEMM drivers in
//! `runtime::gemm` split one large matrix product into disjoint output
//! panels and fan them across it (claim-based, allocation-free waitable
//! jobs — see the panel-pool section below). `FERRISFL_THREADS` (via
//! [`gemm_threads`]) caps only this panel fan-out; the agent-level pool
//! is sized by `FlParams::workers`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::util::Parallelism;

/// The process-wide shared pool: round evaluation shards test batches
/// across it when the caller has no pool of its own (the central
/// trainer). Guarded by a `Mutex` so one parallel region runs at a time;
/// callers submit from the leader thread and jobs must never recursively
/// submit to this pool (that would deadlock a full pool).
pub fn shared_pool() -> &'static Mutex<WorkerPool> {
    static POOL: OnceLock<Mutex<WorkerPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = Parallelism::Auto.resolve(Parallelism::detect()).clamp(2, 8);
        Mutex::new(WorkerPool::new(n))
    })
}

type Job = Box<dyn FnOnce(usize) + Send + 'static>;

/// Fixed pool of named worker threads consuming jobs from a shared queue.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `size` workers (clamped to at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|wid| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ferrisfl-worker-{wid}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(wid),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            handles,
            size,
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `jobs` across the pool and collect results **in input order**.
    /// Each job receives the worker id it landed on (for thread-local
    /// state lookup). Blocks until all jobs finish.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce(usize) -> T + Send + 'static,
    {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            let boxed: Job = Box::new(move |wid| {
                let out = job(wid);
                // Receiver outlives all jobs within this call; ignore a
                // send error only if the caller panicked.
                let _ = rtx.send((i, out));
            });
            self.tx
                .as_ref()
                .expect("pool already shut down")
                .send(boxed)
                .expect("worker pool died");
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rrx.recv().expect("worker panicked");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A two-stage producer/consumer pipeline over a pool of reusable
/// buffers: `produce` fills buffers on a scoped helper thread while
/// `consume` drains them **in production order** on the calling thread,
/// so stage t+1's production overlaps stage t's consumption (with two
/// buffers this is classic double buffering). The training loop uses it
/// to synthesize batch t+1 while batch t trains.
///
/// `produce` returns `false` when the stream is exhausted; `consume`
/// may fail, which stops the pipeline and returns the error. On success
/// all buffers are handed back for reuse (no steady-state allocation);
/// on the error path surviving buffers are recovered best-effort.
///
/// `consume` runs on the caller's thread, so it may freely use
/// non-`Send` state (thread-local executors); only `produce` and the
/// buffers cross the thread boundary.
pub fn pipeline<B, E>(
    bufs: Vec<B>,
    mut produce: impl FnMut(&mut B) -> bool + Send,
    mut consume: impl FnMut(&mut B) -> std::result::Result<(), E>,
) -> std::result::Result<Vec<B>, E>
where
    B: Send,
    E: Send,
{
    assert!(!bufs.is_empty(), "pipeline needs at least one buffer");
    let (free_tx, free_rx) = channel::<B>();
    let (full_tx, full_rx) = channel::<B>();
    for b in bufs {
        free_tx.send(b).expect("pipeline free channel");
    }
    std::thread::scope(|s| {
        let producer = s.spawn(move || {
            // `Some` while still producing; dropped (None) to close the
            // full channel once the stream ends, after which this side
            // only drains returned buffers so the caller recovers them.
            let mut full_tx = Some(full_tx);
            let mut recovered = Vec::new();
            while let Ok(mut b) = free_rx.recv() {
                if full_tx.is_some() && produce(&mut b) {
                    let sent = full_tx.as_ref().expect("checked is_some").send(b);
                    if let Err(unsent) = sent {
                        recovered.push(unsent.0); // consumer bailed early
                        full_tx = None;
                    }
                } else {
                    recovered.push(b);
                    full_tx = None;
                }
            }
            recovered
        });
        let mut result = Ok(());
        while let Ok(mut b) = full_rx.recv() {
            if let Err(e) = consume(&mut b) {
                result = Err(e);
                break;
            }
            if free_tx.send(b).is_err() {
                break;
            }
        }
        // Closing the free channel unblocks the producer's drain loop.
        drop(free_tx);
        let mut bufs = match producer.join() {
            Ok(recovered) => recovered,
            Err(panic) => std::panic::resume_unwind(panic),
        };
        // Error path: buffers may still sit in the full channel.
        while let Ok(b) = full_rx.try_recv() {
            bufs.push(b);
        }
        result.map(|()| bufs)
    })
}

// ==================================================== panel pool
//
// The GEMM drivers split one matrix product into independent output
// panels and run them across this pool. Unlike [`WorkerPool::run`] —
// which boxes each job and collects results through channels — a panel
// job is published as a single type-erased `(fn, ctx)` pair and workers
// *claim* panel indices from a shared counter, so a warm hot-path
// dispatch performs **zero heap allocations** (pinned by
// `tests/zero_alloc.rs`). The submitting thread participates in the
// claim loop, so a pool with zero helper threads degenerates to the
// serial loop.

/// Hard cap on panel helper threads (the leader is the +1).
const MAX_PANEL_WORKERS: usize = 15;

/// Threads the panel-parallel GEMM drivers may use, including the
/// calling thread: `FERRISFL_THREADS` when set (clamped to
/// `[1, MAX_PANEL_WORKERS + 1]`; `0`/`auto` mean auto-detect, `1`
/// forces every GEMM serial), else `available_parallelism` clamped to
/// 8. Resolved once per process.
pub fn gemm_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let auto = Parallelism::detect().clamp(1, 8);
        // Warn on garbage before it degrades to Auto — the one site
        // that distinguishes Invalid from unset.
        if let crate::util::env::ThreadsVar::Invalid(s) = crate::util::env::threads() {
            eprintln!(
                "warning: unknown FERRISFL_THREADS value {s:?} \
                 (want a thread count, 0, or auto); using {auto}"
            );
            return auto;
        }
        match Parallelism::from_env() {
            Parallelism::Auto => auto,
            Parallelism::Fixed(n) => n.clamp(1, MAX_PANEL_WORKERS + 1),
        }
    })
}

/// The process-wide panel pool the GEMM drivers fan panels out on:
/// `gemm_threads() - 1` helper threads (the calling thread is the
/// extra one). With `FERRISFL_THREADS=1` the pool has no helpers and
/// the auto drivers never engage it.
pub fn panel_pool() -> &'static PanelPool {
    static POOL: OnceLock<PanelPool> = OnceLock::new();
    POOL.get_or_init(|| PanelPool::new(gemm_threads().saturating_sub(1)))
}

/// A published panel job: a monomorphized trampoline plus a pointer to
/// the leader's closure. The leader keeps the closure alive until every
/// claimed panel has finished, so the pointer never dangles while a
/// worker can still dereference it.
#[derive(Clone, Copy)]
struct RawPanelJob {
    call: unsafe fn(*const (), usize),
    ctx: *const (),
}

// SAFETY: the pointer is only dereferenced through `call` while the
// submitting `try_run` frame (which owns the referent) is blocked
// waiting for the job to finish; the referent is `Sync`.
unsafe impl Send for RawPanelJob {}

struct PanelState {
    /// Bumped per published job so sleeping workers can tell a new job
    /// from the one they already drained.
    epoch: u64,
    /// Panels in the current job.
    panels: usize,
    /// Next unclaimed panel index.
    next: usize,
    /// Panels claimed or unclaimed but not yet finished.
    remaining: usize,
    job: Option<RawPanelJob>,
    shutdown: bool,
}

struct PanelShared {
    state: Mutex<PanelState>,
    /// Workers sleep here between jobs.
    work: Condvar,
    /// The leader sleeps here while workers finish their claims.
    done: Condvar,
}

/// Fixed pool of helper threads executing claim-based panel jobs — see
/// the module-level notes above. One job runs at a time; a second
/// submitter is refused ([`PanelPool::try_run`] returns `false`) rather
/// than queued, because a busy pool means the cores are already doing
/// panel work and the refused caller's serial path is the better use of
/// its own core.
pub struct PanelPool {
    shared: Arc<PanelShared>,
    busy: AtomicBool,
    handles: Vec<JoinHandle<()>>,
}

impl PanelPool {
    /// Spawn `workers` helper threads (0 is valid: `try_run` then runs
    /// every panel on the calling thread — the degenerate 1-thread
    /// pool).
    pub fn new(workers: usize) -> Self {
        let workers = workers.min(MAX_PANEL_WORKERS);
        let shared = Arc::new(PanelShared {
            state: Mutex::new(PanelState {
                epoch: 0,
                panels: 0,
                next: 0,
                remaining: 0,
                job: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ferrisfl-panel-{wid}"))
                    .spawn(move || panel_worker(&shared))
                    .expect("spawn panel worker")
            })
            .collect();
        Self {
            shared,
            busy: AtomicBool::new(false),
            handles,
        }
    }

    /// Helper threads (the calling thread adds one more).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(0..panels)` across the pool, the calling thread included,
    /// blocking until every panel has finished. Panels may run in any
    /// order and concurrently — `f` must only touch disjoint state per
    /// index. Returns `false` without calling `f` when another job is
    /// already in flight (the caller should run its serial path).
    pub fn try_run<F>(&self, panels: usize, f: &F) -> bool
    where
        F: Fn(usize) + Sync,
    {
        if panels == 0 {
            return true;
        }
        if self
            .busy
            .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return false;
        }
        unsafe fn trampoline<F: Fn(usize)>(ctx: *const (), i: usize) {
            (*(ctx as *const F))(i)
        }
        let job = RawPanelJob {
            call: trampoline::<F>,
            ctx: f as *const F as *const (),
        };
        {
            let mut st = self.shared.state.lock().expect("panel pool poisoned");
            st.epoch = st.epoch.wrapping_add(1);
            st.panels = panels;
            st.next = 0;
            st.remaining = panels;
            st.job = Some(job);
            self.shared.work.notify_all();
        }
        // The leader claims panels alongside the workers.
        loop {
            let i = {
                let mut st = self.shared.state.lock().expect("panel pool poisoned");
                if st.next >= st.panels {
                    break;
                }
                let i = st.next;
                st.next += 1;
                i
            };
            f(i);
            let mut st = self.shared.state.lock().expect("panel pool poisoned");
            st.remaining -= 1;
            if st.remaining == 0 {
                self.shared.done.notify_all();
            }
        }
        // Wait out panels claimed by workers, then retire the job so the
        // closure pointer cannot outlive this frame.
        let mut st = self.shared.state.lock().expect("panel pool poisoned");
        while st.remaining > 0 {
            st = self.shared.done.wait(st).expect("panel pool poisoned");
        }
        st.job = None;
        drop(st);
        self.busy.store(false, Ordering::Release);
        true
    }
}

impl Drop for PanelPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("panel pool poisoned");
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn panel_worker(shared: &PanelShared) {
    let mut seen = 0u64;
    let mut st = shared.state.lock().expect("panel pool poisoned");
    loop {
        if st.shutdown {
            return;
        }
        let fresh = st.job.is_some() && st.epoch != seen;
        let claimable = fresh && st.next < st.panels;
        if !claimable {
            if fresh {
                // Fully claimed before this worker woke: nothing to do
                // for this epoch.
                seen = st.epoch;
            }
            st = shared.work.wait(st).expect("panel pool poisoned");
            continue;
        }
        let job = st.job.expect("claimable job present");
        loop {
            let i = st.next;
            st.next += 1;
            drop(st);
            // SAFETY: the leader blocks in `try_run` until `remaining`
            // reaches zero, which cannot happen before the decrement
            // below — so the closure behind `ctx` is alive here.
            unsafe { (job.call)(job.ctx, i) };
            st = shared.state.lock().expect("panel pool poisoned");
            st.remaining -= 1;
            if st.remaining == 0 {
                shared.done.notify_all();
            }
            if st.next >= st.panels {
                break;
            }
        }
        seen = st.epoch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_input_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                move |_wid: usize| {
                    // Stagger so completion order != input order.
                    std::thread::sleep(std::time::Duration::from_millis(
                        (32 - i) % 7,
                    ));
                    i * 10
                }
            })
            .collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_execute_once() {
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                |_wid: usize| {
                    COUNT.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run(jobs);
        assert_eq!(COUNT.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_ids_within_bounds() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<_> = (0..16).map(|_| |wid: usize| wid).collect();
        let ids = pool.run(jobs);
        assert!(ids.iter().all(|&w| w < 2));
    }

    #[test]
    fn sequential_batches_reuse_pool() {
        let pool = WorkerPool::new(2);
        for round in 0..5 {
            let jobs: Vec<_> = (0..8).map(|i| move |_w: usize| i + round).collect();
            let out = pool.run(jobs);
            assert_eq!(out.len(), 8);
        }
    }

    #[test]
    fn zero_size_clamped() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.run(vec![|_w: usize| 7]);
        assert_eq!(out, vec![7]);
    }

    // ------------------------------------------------------- pipeline

    /// Items arrive at the consumer in production order, every item is
    /// consumed exactly once, and all buffers come back for reuse.
    #[test]
    fn pipeline_preserves_order_and_returns_buffers() {
        let mut next = 0usize;
        let mut seen = Vec::new();
        let bufs = pipeline::<usize, ()>(
            vec![0usize, 0],
            |b| {
                if next < 20 {
                    *b = next;
                    next += 1;
                    true
                } else {
                    false
                }
            },
            |b| {
                seen.push(*b);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        assert_eq!(bufs.len(), 2, "both buffers must come back");
    }

    #[test]
    fn pipeline_consume_error_stops_early() {
        let mut next = 0usize;
        let mut consumed = 0usize;
        let res = pipeline::<usize, &'static str>(
            vec![0usize, 0],
            |b| {
                *b = next;
                next += 1;
                next <= 100
            },
            |b| {
                consumed += 1;
                if *b == 5 {
                    Err("boom")
                } else {
                    Ok(())
                }
            },
        );
        assert_eq!(res.unwrap_err(), "boom");
        assert_eq!(consumed, 6, "items 0..=5 consumed, then stop");
    }

    // ----------------------------------------------------- panel pool

    /// Every panel index runs exactly once, whatever the pool size —
    /// including the degenerate 0-helper pool (leader-only claims).
    #[test]
    fn panel_pool_runs_every_panel_once() {
        for workers in [0usize, 1, 3] {
            let pool = PanelPool::new(workers);
            assert_eq!(pool.workers(), workers);
            let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            let ran = pool.try_run(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            assert!(ran, "workers={workers}");
            assert!(
                hits.iter().all(|h| h.load(Ordering::SeqCst) == 1),
                "workers={workers}"
            );
        }
    }

    /// Sequential jobs reuse the pool; zero-panel jobs are a no-op.
    #[test]
    fn panel_pool_reuses_across_jobs() {
        let pool = PanelPool::new(2);
        assert!(pool.try_run(0, &|_| panic!("no panels to run")));
        for round in 1..=5usize {
            let sum = AtomicUsize::new(0);
            assert!(pool.try_run(round * 4, &|i| {
                sum.fetch_add(i + 1, Ordering::SeqCst);
            }));
            let n = round * 4;
            assert_eq!(sum.load(Ordering::SeqCst), n * (n + 1) / 2, "round {round}");
        }
    }

    /// Disjoint-slice panel writes — the exact shape the GEMM drivers
    /// use — land in the right places.
    #[test]
    fn panel_pool_disjoint_writes() {
        struct SendMut(*mut usize);
        unsafe impl Sync for SendMut {}
        let pool = PanelPool::new(3);
        let mut out = vec![0usize; 64];
        let ptr = SendMut(out.as_mut_ptr());
        let chunk = 8;
        pool.try_run(out.len() / chunk, &|p| {
            // SAFETY: each panel writes its own disjoint chunk.
            let s = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(p * chunk), chunk) };
            for (j, v) in s.iter_mut().enumerate() {
                *v = p * chunk + j;
            }
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    /// A second submission while a job is in flight is refused (the
    /// caller then runs its serial path) instead of deadlocking.
    #[test]
    fn panel_pool_refuses_nested_submission() {
        let pool = PanelPool::new(1);
        let nested_ran = AtomicUsize::new(0);
        let refused = AtomicUsize::new(0);
        let ran = pool.try_run(4, &|_| {
            if pool.try_run(2, &|_| {
                nested_ran.fetch_add(1, Ordering::SeqCst);
            }) {
                nested_ran.fetch_add(100, Ordering::SeqCst);
            } else {
                refused.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(ran);
        assert_eq!(nested_ran.load(Ordering::SeqCst), 0);
        assert_eq!(refused.load(Ordering::SeqCst), 4);
        // The pool is usable again after the refusals.
        let count = AtomicUsize::new(0);
        assert!(pool.try_run(3, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn gemm_threads_is_at_least_one() {
        assert!(gemm_threads() >= 1);
        assert!(panel_pool().workers() + 1 >= 1);
    }

    #[test]
    fn pipeline_empty_stream_and_single_buffer() {
        let bufs = pipeline::<u8, ()>(vec![9u8], |_| false, |_| panic!("nothing to consume"))
            .unwrap();
        assert_eq!(bufs, vec![9]);
        // One buffer degenerates to strict alternation but still works.
        let mut next = 0;
        let mut seen = Vec::new();
        pipeline::<usize, ()>(
            vec![0usize],
            |b| {
                *b = next;
                next += 1;
                next <= 5
            },
            |b| {
                seen.push(*b);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
