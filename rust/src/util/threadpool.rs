//! A small fixed-size worker pool over std threads.
//!
//! The FL entrypoint dispatches each sampled agent's local training round
//! onto this pool — the simulated analogue of clients training in
//! parallel on their own devices. Workers own thread-local state (their
//! own PJRT client + compiled executables, since the `xla` wrappers are
//! `Rc`-based and not `Send`), created lazily by an `init` closure the
//! first time a job runs on that worker.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

/// The process-wide shared pool: round evaluation shards test batches
/// across it when the caller has no pool of its own (the central
/// trainer). Guarded by a `Mutex` so one parallel region runs at a time;
/// callers submit from the leader thread and jobs must never recursively
/// submit to this pool (that would deadlock a full pool).
pub fn shared_pool() -> &'static Mutex<WorkerPool> {
    static POOL: OnceLock<Mutex<WorkerPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Mutex::new(WorkerPool::new(n.clamp(2, 8)))
    })
}

type Job = Box<dyn FnOnce(usize) + Send + 'static>;

/// Fixed pool of named worker threads consuming jobs from a shared queue.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `size` workers (clamped to at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|wid| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("ferrisfl-worker-{wid}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(wid),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            handles,
            size,
        }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `jobs` across the pool and collect results **in input order**.
    /// Each job receives the worker id it landed on (for thread-local
    /// state lookup). Blocks until all jobs finish.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce(usize) -> T + Send + 'static,
    {
        let n = jobs.len();
        let (rtx, rrx) = channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let rtx = rtx.clone();
            let boxed: Job = Box::new(move |wid| {
                let out = job(wid);
                // Receiver outlives all jobs within this call; ignore a
                // send error only if the caller panicked.
                let _ = rtx.send((i, out));
            });
            self.tx
                .as_ref()
                .expect("pool already shut down")
                .send(boxed)
                .expect("worker pool died");
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rrx.recv().expect("worker panicked");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A two-stage producer/consumer pipeline over a pool of reusable
/// buffers: `produce` fills buffers on a scoped helper thread while
/// `consume` drains them **in production order** on the calling thread,
/// so stage t+1's production overlaps stage t's consumption (with two
/// buffers this is classic double buffering). The training loop uses it
/// to synthesize batch t+1 while batch t trains.
///
/// `produce` returns `false` when the stream is exhausted; `consume`
/// may fail, which stops the pipeline and returns the error. On success
/// all buffers are handed back for reuse (no steady-state allocation);
/// on the error path surviving buffers are recovered best-effort.
///
/// `consume` runs on the caller's thread, so it may freely use
/// non-`Send` state (thread-local executors); only `produce` and the
/// buffers cross the thread boundary.
pub fn pipeline<B, E>(
    bufs: Vec<B>,
    mut produce: impl FnMut(&mut B) -> bool + Send,
    mut consume: impl FnMut(&mut B) -> std::result::Result<(), E>,
) -> std::result::Result<Vec<B>, E>
where
    B: Send,
    E: Send,
{
    assert!(!bufs.is_empty(), "pipeline needs at least one buffer");
    let (free_tx, free_rx) = channel::<B>();
    let (full_tx, full_rx) = channel::<B>();
    for b in bufs {
        free_tx.send(b).expect("pipeline free channel");
    }
    std::thread::scope(|s| {
        let producer = s.spawn(move || {
            // `Some` while still producing; dropped (None) to close the
            // full channel once the stream ends, after which this side
            // only drains returned buffers so the caller recovers them.
            let mut full_tx = Some(full_tx);
            let mut recovered = Vec::new();
            while let Ok(mut b) = free_rx.recv() {
                if full_tx.is_some() && produce(&mut b) {
                    let sent = full_tx.as_ref().expect("checked is_some").send(b);
                    if let Err(unsent) = sent {
                        recovered.push(unsent.0); // consumer bailed early
                        full_tx = None;
                    }
                } else {
                    recovered.push(b);
                    full_tx = None;
                }
            }
            recovered
        });
        let mut result = Ok(());
        while let Ok(mut b) = full_rx.recv() {
            if let Err(e) = consume(&mut b) {
                result = Err(e);
                break;
            }
            if free_tx.send(b).is_err() {
                break;
            }
        }
        // Closing the free channel unblocks the producer's drain loop.
        drop(free_tx);
        let mut bufs = match producer.join() {
            Ok(recovered) => recovered,
            Err(panic) => std::panic::resume_unwind(panic),
        };
        // Error path: buffers may still sit in the full channel.
        while let Ok(b) = full_rx.try_recv() {
            bufs.push(b);
        }
        result.map(|()| bufs)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_input_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                move |_wid: usize| {
                    // Stagger so completion order != input order.
                    std::thread::sleep(std::time::Duration::from_millis(
                        (32 - i) % 7,
                    ));
                    i * 10
                }
            })
            .collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn all_jobs_execute_once() {
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                |_wid: usize| {
                    COUNT.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run(jobs);
        assert_eq!(COUNT.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn worker_ids_within_bounds() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<_> = (0..16).map(|_| |wid: usize| wid).collect();
        let ids = pool.run(jobs);
        assert!(ids.iter().all(|&w| w < 2));
    }

    #[test]
    fn sequential_batches_reuse_pool() {
        let pool = WorkerPool::new(2);
        for round in 0..5 {
            let jobs: Vec<_> = (0..8).map(|i| move |_w: usize| i + round).collect();
            let out = pool.run(jobs);
            assert_eq!(out.len(), 8);
        }
    }

    #[test]
    fn zero_size_clamped() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.run(vec![|_w: usize| 7]);
        assert_eq!(out, vec![7]);
    }

    // ------------------------------------------------------- pipeline

    /// Items arrive at the consumer in production order, every item is
    /// consumed exactly once, and all buffers come back for reuse.
    #[test]
    fn pipeline_preserves_order_and_returns_buffers() {
        let mut next = 0usize;
        let mut seen = Vec::new();
        let bufs = pipeline::<usize, ()>(
            vec![0usize, 0],
            |b| {
                if next < 20 {
                    *b = next;
                    next += 1;
                    true
                } else {
                    false
                }
            },
            |b| {
                seen.push(*b);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        assert_eq!(bufs.len(), 2, "both buffers must come back");
    }

    #[test]
    fn pipeline_consume_error_stops_early() {
        let mut next = 0usize;
        let mut consumed = 0usize;
        let res = pipeline::<usize, &'static str>(
            vec![0usize, 0],
            |b| {
                *b = next;
                next += 1;
                next <= 100
            },
            |b| {
                consumed += 1;
                if *b == 5 {
                    Err("boom")
                } else {
                    Ok(())
                }
            },
        );
        assert_eq!(res.unwrap_err(), "boom");
        assert_eq!(consumed, 6, "items 0..=5 consumed, then stop");
    }

    #[test]
    fn pipeline_empty_stream_and_single_buffer() {
        let bufs = pipeline::<u8, ()>(vec![9u8], |_| false, |_| panic!("nothing to consume"))
            .unwrap();
        assert_eq!(bufs, vec![9]);
        // One buffer degenerates to strict alternation but still works.
        let mut next = 0;
        let mut seen = Vec::new();
        pipeline::<usize, ()>(
            vec![0usize],
            |b| {
                *b = next;
                next += 1;
                next <= 5
            },
            |b| {
                seen.push(*b);
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
