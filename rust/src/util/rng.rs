//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! Every stochastic decision in FerrisFL — sharding, sampling, synthetic
//! data — flows through this RNG, seeded from the experiment config, so
//! whole FL runs are bit-reproducible. SplitMix64 passes BigCrush, has a
//! 64-bit state, and `split()` derives independent streams, which is how
//! per-agent / per-sample generators are made without sharing state.

/// The SplitMix64 increment ("golden gamma"). One `next_u64` adds this
/// to the state and mixes, so the generator is *counter-based*: the
/// j-th upcoming draw of a generator whose state is `s` is
/// `splitmix64_mix(s + j·SPLITMIX64_GAMMA)` — independent lanes can
/// compute arbitrary stream positions without sequencing through the
/// state (see `runtime::simd`).
pub const SPLITMIX64_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The SplitMix64 output mix (finalizer). Pure function of the counter;
/// [`Rng::next_u64`] is `splitmix64_mix(state += GAMMA)`.
#[inline]
pub fn splitmix64_mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64 PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Raw generator state. `Rng::new(r.state())` continues the stream:
    /// it is the counter base for counter-mode draws (the j-th upcoming
    /// `next_u64` is `splitmix64_mix(state + j·SPLITMIX64_GAMMA)`).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Derive an independent stream keyed by `salt` (e.g. an agent id or
    /// a sample index) without advancing `self`.
    pub fn split(&self, salt: u64) -> Rng {
        let mut r = Rng::new(
            self.state
                .wrapping_add(SPLITMIX64_GAMMA.wrapping_mul(salt ^ 0xA5A5_5A5A)),
        );
        r.next_u64(); // decorrelate
        Rng::new(r.next_u64())
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(SPLITMIX64_GAMMA);
        splitmix64_mix(self.state)
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f32 {
        // Guard against log(0).
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    ///
    /// Sparse: instead of materializing `(0..n)` and swapping — O(n)
    /// memory, which a 10^6-agent registry cannot afford for a K=64
    /// cohort — only the displaced positions live in a map. Each step
    /// draws the same `next_below(n - i)` the dense swap loop drew and
    /// emits the value the dense loop would have left at position `i`,
    /// so both the RNG stream and the output are bit-identical to the
    /// materialized version at every `(n, k, seed)`; memory is O(k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        // displaced[p] = the value currently at position p, for the
        // positions the dense loop would have written; absent means the
        // identity value p.
        let mut displaced: std::collections::BTreeMap<usize, usize> =
            std::collections::BTreeMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            displaced.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Sample from a discrete distribution given non-negative weights.
    /// Returns the chosen index; panics if all weights are zero.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        self.sample_weighted_with(weights.len(), |i| weights[i])
    }

    /// [`Rng::sample_weighted`] over a weight *function* instead of a
    /// slice: the subtract-scan streams `w(0), w(1), …` without ever
    /// materializing a weight vector, so reputation-weighted sampling
    /// over a virtual registry costs O(1) memory. The total is the same
    /// left-to-right f64 sum a slice would produce, so this is
    /// bit-identical to the slice form for equal weight sequences.
    pub fn sample_weighted_with(&mut self, n: usize, w: impl Fn(usize) -> f64) -> usize {
        let total: f64 = (0..n).map(&w).sum();
        assert!(total > 0.0, "sample_weighted: all-zero weights");
        let mut t = self.next_f64() * total;
        for i in 0..n {
            t -= w(i);
            if t <= 0.0 {
                return i;
            }
        }
        n - 1
    }

    /// Sample from Gamma(shape, 1) — Marsaglia–Tsang, used for Dirichlet
    /// partitioning.
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.next_gamma(shape + 1.0);
            return g * self.next_f64().max(1e-12).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_gaussian() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-12).ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample on the k-simplex.
    pub fn next_dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.next_gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The counter-mode identity the SIMD synthesis path relies on: the
    /// j-th sequential draw equals the mix of `state + j·GAMMA`.
    #[test]
    fn counter_mode_matches_sequential_draws() {
        let mut r = Rng::new(0xABCD_EF01);
        r.next_u64(); // start mid-stream
        let s = r.state();
        for j in 1..=64u64 {
            let counter = s.wrapping_add(SPLITMIX64_GAMMA.wrapping_mul(j));
            assert_eq!(r.next_u64(), splitmix64_mix(counter), "draw {j}");
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let r = Rng::new(7);
        let mut a = r.split(1);
        let mut b = r.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    /// The sparse partial Fisher–Yates must reproduce the dense
    /// swap-and-truncate version exactly — same draws, same outputs —
    /// since sampler parity across registry modes rests on it.
    #[test]
    fn sample_indices_matches_dense_fisher_yates() {
        fn dense(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + rng.next_below((n - i) as u64) as usize;
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
        for seed in 0..20u64 {
            for &(n, k) in &[(1, 1), (5, 5), (50, 20), (64, 64), (1024, 7), (100_000, 64)] {
                let mut a = Rng::new(seed * 31 + 7);
                let mut b = a.clone();
                let sparse = a.sample_indices(n, k);
                assert_eq!(sparse, dense(&mut b, n, k), "n={n} k={k} seed={seed}");
                // Both generators ended at the same stream position.
                assert_eq!(a.state(), b.state());
            }
        }
    }

    /// The streaming weight-function form is bit-identical to the slice
    /// form (same total, same subtract-scan) — the contract that lets
    /// virtual registries skip the weights vector.
    #[test]
    fn sample_weighted_with_matches_slice_form() {
        let w = [0.25, 3.0, 0.0, 1.5, 0.75];
        for seed in 0..50u64 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            assert_eq!(a.sample_weighted(&w), b.sample_weighted_with(w.len(), |i| w[i]));
        }
    }

    #[test]
    fn dirichlet_on_simplex() {
        let mut r = Rng::new(21);
        for alpha in [0.1, 0.5, 1.0, 10.0] {
            let d = r.next_dirichlet(alpha, 8);
            assert_eq!(d.len(), 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut r = Rng::new(33);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }
}
