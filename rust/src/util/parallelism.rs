//! One parallelism knob, one precedence rule.
//!
//! Pool sizing used to be scattered: `FlParams::workers` sized the
//! agent pool, `FERRISFL_THREADS` sized the GEMM panel fan-out, and the
//! shared evaluation pool auto-detected on its own. [`Parallelism`]
//! collapses them behind the crate's uniform precedence — **explicit
//! config > environment > auto-detect** — so every pool resolves its
//! size the same way and `FERRISFL_THREADS` becomes the single
//! process-level override. Call sites keep their own clamps (the panel
//! pool caps at `MAX_PANEL_WORKERS + 1`, the agent pool at 8, the
//! shared pool at `[2, 8]`): the knob names *how many*, the site knows
//! *how many it can use*.

use std::str::FromStr;

use crate::util::env::{self, ThreadsVar};
use crate::util::error::{bail, Error, Result};

/// A parallelism request: an explicit thread/worker count, or defer to
/// the environment and then the machine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Parallelism {
    /// No explicit request — fall through to `FERRISFL_THREADS`, then
    /// to hardware detection.
    #[default]
    Auto,
    /// Exactly this many (call sites clamp to their own legal range).
    Fixed(usize),
}

impl Parallelism {
    /// From a config count where `0` conventionally means auto
    /// (`FlParams::workers`, `[run] workers`).
    pub fn from_workers(n: usize) -> Self {
        if n == 0 {
            Parallelism::Auto
        } else {
            Parallelism::Fixed(n)
        }
    }

    /// The environment's request (`FERRISFL_THREADS`). An unparseable
    /// value degrades to `Auto`; sites that want to warn first (the
    /// panel pool) match [`env::threads`] themselves.
    pub fn from_env() -> Self {
        match env::threads() {
            ThreadsVar::Count(n) => Parallelism::Fixed(n),
            ThreadsVar::Auto | ThreadsVar::Invalid(_) => Parallelism::Auto,
        }
    }

    /// Hardware parallelism (≥ 1), the final fallback.
    pub fn detect() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Resolve to a concrete count with the crate's precedence:
    /// `Fixed(n)` wins outright; `Auto` consults the environment, then
    /// takes `auto_detect`. Never returns 0.
    pub fn resolve(self, auto_detect: usize) -> usize {
        match self {
            Parallelism::Fixed(n) => n.max(1),
            Parallelism::Auto => match Parallelism::from_env() {
                Parallelism::Fixed(n) => n.max(1),
                Parallelism::Auto => auto_detect.max(1),
            },
        }
    }
}

impl FromStr for Parallelism {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" | "0" => Ok(Parallelism::Auto),
            t => match t.parse::<usize>() {
                Ok(n) => Ok(Parallelism::Fixed(n)),
                Err(_) => bail!("bad parallelism {s:?} (auto | 0 | a thread count)"),
            },
        }
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Parallelism::Auto => f.write_str("auto"),
            Parallelism::Fixed(n) => write!(f, "{n}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_displays() {
        assert_eq!("auto".parse::<Parallelism>().unwrap(), Parallelism::Auto);
        assert_eq!("0".parse::<Parallelism>().unwrap(), Parallelism::Auto);
        assert_eq!(" 6 ".parse::<Parallelism>().unwrap(), Parallelism::Fixed(6));
        assert!("many".parse::<Parallelism>().is_err());
        assert_eq!(Parallelism::Auto.to_string(), "auto");
        assert_eq!(Parallelism::Fixed(3).to_string(), "3");
        assert_eq!(Parallelism::from_workers(0), Parallelism::Auto);
        assert_eq!(Parallelism::from_workers(5), Parallelism::Fixed(5));
    }

    #[test]
    fn explicit_beats_everything_and_never_resolves_to_zero() {
        // Fixed short-circuits: the env never enters into it.
        assert_eq!(Parallelism::Fixed(3).resolve(8), 3);
        assert_eq!(Parallelism::Fixed(0).resolve(8), 1);
        assert!(Parallelism::detect() >= 1);
        // Auto lands on auto_detect (or the env, which tests can't
        // assume); either way the result is >= 1.
        assert!(Parallelism::Auto.resolve(4) >= 1);
    }
}
