//! Zero-dependency substrates: RNG, JSON, and a worker pool.
//!
//! FerrisFL builds fully offline against a vendored crate set that carries
//! only `xla` and `anyhow`, so the small infrastructure pieces a project
//! would normally pull from crates.io (rand, serde_json, tokio/rayon) are
//! implemented here, each with its own unit tests.

pub mod json;
pub mod rng;
pub mod threadpool;

pub use json::Json;
pub use rng::Rng;
pub use threadpool::WorkerPool;
