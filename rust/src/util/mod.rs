//! Zero-dependency substrates: errors, RNG, JSON, and a worker pool.
//!
//! FerrisFL builds fully offline with **no external crates at all**, so
//! the small infrastructure pieces a project would normally pull from
//! crates.io (anyhow, rand, serde_json, tokio/rayon) are implemented
//! here, each with its own unit tests. The [`env`] module is the single
//! registry of `FERRISFL_*` environment knobs.

pub mod env;
pub mod error;
pub mod json;
pub mod mem;
pub mod parallelism;
pub mod rng;
pub mod threadpool;

pub use error::{Context, Error, Result};
pub use json::Json;
pub use parallelism::Parallelism;
pub use rng::Rng;
pub use threadpool::{gemm_threads, panel_pool, pipeline, shared_pool, PanelPool, WorkerPool};
