//! Minimal JSON: parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to read `artifacts/manifest.json`
//! and to emit structured experiment logs. No external crates.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::error::{bail, err, Result};

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["key"]` with a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| err!("missing JSON key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ------------------------------------------------------------- write

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} got {other:?} at {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected , or ] got {other:?} at {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| err!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| err!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": null}, "e": true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn access_helpers() {
        let v = Json::parse(r#"{"n": 42, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cA\n");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
