//! The `FERRISFL_*` environment knobs, in one place.
//!
//! Every env var the crate reads is declared, parsed, and documented
//! here; call sites go through the typed accessors instead of
//! scattering `std::env::var` strings. The knobs and their consumers:
//!
//! | Variable | Accessor | Meaning |
//! |---|---|---|
//! | `FERRISFL_THREADS` | [`threads`] | GEMM panel threads (`0`/`auto` = detect) |
//! | `FERRISFL_SIMD` | [`simd`] | SIMD level override (`0`/`scalar`/`avx2`/`neon`/`auto`) |
//! | `FERRISFL_SYNTH_CACHE` | [`synth_cache_enabled`] | `0` disables the synthesis cache |
//! | `FERRISFL_BENCH_FAST` | [`bench_fast`] | non-`0` shrinks bench workloads for CI |
//! | `FERRISFL_BENCH_JSON` | [`bench_json`] | bench snapshot path override |
//! | `FERRISFL_WORKER_BIN` | [`worker_bin`] | worker binary the distributed leader spawns |
//! | `FERRISFL_WIRE_CHAOS` | [`wire_chaos`] | corrupt the first N wire deltas (tests/CI) |
//!
//! **Precedence** is uniform across the crate: an explicit config value
//! (an `FlParams`/builder field, a CLI flag, a TOML key) beats the
//! environment, and the environment beats auto-detection. Env knobs
//! deliberately cover only what has no config-file home — process-level
//! tuning (threads, SIMD, caches) and bench harness plumbing.
//!
//! Accessors that cache per-process do so at *their* call site (e.g.
//! `util::threadpool::gemm_threads` resolves once into a `OnceLock`);
//! this module itself re-reads the environment on every call so tests
//! can exercise the parsers purely.

use std::path::PathBuf;

/// GEMM panel-thread count (see `util::threadpool::gemm_threads`).
pub const THREADS: &str = "FERRISFL_THREADS";
/// SIMD dispatch override (see `runtime::simd::level`).
pub const SIMD: &str = "FERRISFL_SIMD";
/// Synthesis-cache switch (see `datasets::SynthCache`).
pub const SYNTH_CACHE: &str = "FERRISFL_SYNTH_CACHE";
/// Bench fast-mode switch (see `benchutil::fast_mode`).
pub const BENCH_FAST: &str = "FERRISFL_BENCH_FAST";
/// Bench JSON snapshot path (see `benchutil::bench_json_path`).
pub const BENCH_JSON: &str = "FERRISFL_BENCH_JSON";
/// Worker binary override for process spawning (see
/// `transport::leader`).
pub const WORKER_BIN: &str = "FERRISFL_WORKER_BIN";
/// Wire-corruption chaos knob (see `transport::worker`).
pub const WIRE_CHAOS: &str = "FERRISFL_WIRE_CHAOS";

/// A parsed `FERRISFL_THREADS` request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ThreadsVar {
    /// Unset, empty, `0`, or `auto` — detect from the machine.
    Auto,
    /// An explicit thread count (callers clamp to their own range).
    Count(usize),
    /// Set to something unparseable; the offending text, for warnings.
    Invalid(String),
}

/// Parse a raw `FERRISFL_THREADS` value (pure; see [`threads`]).
pub fn parse_threads(raw: Option<&str>) -> ThreadsVar {
    match raw.map(str::trim) {
        None | Some("") | Some("0") | Some("auto") => ThreadsVar::Auto,
        Some(s) => match s.parse::<usize>() {
            Ok(0) => ThreadsVar::Auto,
            Ok(n) => ThreadsVar::Count(n),
            Err(_) => ThreadsVar::Invalid(s.to_string()),
        },
    }
}

/// `FERRISFL_THREADS`: requested GEMM panel-thread count.
pub fn threads() -> ThreadsVar {
    parse_threads(std::env::var(THREADS).ok().as_deref())
}

/// `FERRISFL_SIMD`: the raw SIMD level request, if set. Validation is
/// architecture-dependent and lives in `runtime::simd::resolve`.
pub fn simd() -> Option<String> {
    std::env::var(SIMD).ok()
}

/// Parse a raw `FERRISFL_SYNTH_CACHE` value (pure; see
/// [`synth_cache_enabled`]): only a literal `0` disables the cache.
pub fn parse_synth_cache(raw: Option<&str>) -> bool {
    raw != Some("0")
}

/// `FERRISFL_SYNTH_CACHE`: whether the per-worker synthesis cache is
/// enabled (default yes; `0` disables).
pub fn synth_cache_enabled() -> bool {
    parse_synth_cache(std::env::var(SYNTH_CACHE).ok().as_deref())
}

/// Parse a raw `FERRISFL_BENCH_FAST` value (pure; see [`bench_fast`]):
/// set to anything but `0` means fast mode.
pub fn parse_bench_fast(raw: Option<&str>) -> bool {
    matches!(raw, Some(v) if v != "0")
}

/// `FERRISFL_BENCH_FAST`: whether benches shrink their workloads so CI
/// can smoke-run them on every merge.
pub fn bench_fast() -> bool {
    parse_bench_fast(std::env::var(BENCH_FAST).ok().as_deref())
}

/// `FERRISFL_BENCH_JSON`: explicit bench snapshot path, if set. The
/// default (workspace-root `BENCH_native.json`) is resolved by
/// `benchutil::bench_json_path`, which owns the fallback.
pub fn bench_json() -> Option<PathBuf> {
    std::env::var(BENCH_JSON).ok().map(PathBuf::from)
}

/// `FERRISFL_WORKER_BIN`: the binary the distributed leader spawns for
/// `multiprocess:N` workers. Unset means `std::env::current_exe()` —
/// right for `ferrisfl run`, wrong inside a test harness, whose
/// current exe is the test binary; tests set this to
/// `env!("CARGO_BIN_EXE_ferrisfl")`.
pub fn worker_bin() -> Option<String> {
    std::env::var(WORKER_BIN).ok().filter(|s| !s.trim().is_empty())
}

/// Parse a raw `FERRISFL_WIRE_CHAOS` value (pure; see [`wire_chaos`]):
/// the number of initial `Delta` frames each worker corrupts before
/// sending (resends always go out clean). Unset, empty, or
/// unparseable means 0 — no chaos.
pub fn parse_wire_chaos(raw: Option<&str>) -> u32 {
    raw.map(str::trim).and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// `FERRISFL_WIRE_CHAOS`: deterministic wire-corruption injection for
/// the distributed executor's retry path (tests and the CI
/// distributed-e2e step).
pub fn wire_chaos() -> u32 {
    parse_wire_chaos(std::env::var(WIRE_CHAOS).ok().as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_parsing() {
        assert_eq!(parse_threads(None), ThreadsVar::Auto);
        assert_eq!(parse_threads(Some("")), ThreadsVar::Auto);
        assert_eq!(parse_threads(Some("0")), ThreadsVar::Auto);
        assert_eq!(parse_threads(Some("auto")), ThreadsVar::Auto);
        assert_eq!(parse_threads(Some(" 6 ")), ThreadsVar::Count(6));
        assert_eq!(parse_threads(Some("lots")), ThreadsVar::Invalid("lots".into()));
    }

    #[test]
    fn synth_cache_parsing() {
        assert!(parse_synth_cache(None));
        assert!(parse_synth_cache(Some("1")));
        assert!(!parse_synth_cache(Some("0")));
        // Historical behaviour: only a bare "0" disables.
        assert!(parse_synth_cache(Some(" 0 ")));
    }

    #[test]
    fn bench_fast_parsing() {
        assert!(!parse_bench_fast(None));
        assert!(!parse_bench_fast(Some("0")));
        assert!(parse_bench_fast(Some("1")));
        assert!(parse_bench_fast(Some("yes")));
    }

    #[test]
    fn wire_chaos_parsing() {
        assert_eq!(parse_wire_chaos(None), 0);
        assert_eq!(parse_wire_chaos(Some("")), 0);
        assert_eq!(parse_wire_chaos(Some("gremlins")), 0);
        assert_eq!(parse_wire_chaos(Some(" 3 ")), 3);
    }
}
