//! Process memory introspection for the million-agent memory contract.
//!
//! The CI gate for the virtualized registry is a plain `cargo test`
//! assertion: the million-agent e2e test reads its own peak resident
//! set (`VmHWM` from `/proc/self/status`) after the round and fails if
//! it exceeded the ceiling. Reading procfs needs no privileges and no
//! external tooling, and works identically on the x86 and ARM Linux
//! runners; on non-Linux hosts the reading is simply unavailable and
//! callers skip the assertion.

/// Peak resident set size of this process in bytes (`VmHWM`), or `None`
/// where procfs is unavailable (non-Linux hosts).
///
/// `VmHWM` is a process-lifetime high-water mark: it never decreases,
/// so a test that wants to gate one workload must run it in its own
/// process (its own integration-test binary) rather than sharing a
/// binary with memory-hungry neighbours.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    parse_vm_hwm(&status)
}

/// Extract `VmHWM:	  12345 kB` from a `/proc/self/status` document.
fn parse_vm_hwm(status: &str) -> Option<u64> {
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())?;
    Some(kb * 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_procfs_line() {
        let doc = "Name:\tferrisfl\nVmPeak:\t  999 kB\nVmHWM:\t   2048 kB\nThreads:\t1\n";
        assert_eq!(parse_vm_hwm(doc), Some(2048 * 1024));
        assert_eq!(parse_vm_hwm("Name:\tferrisfl\n"), None);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn reads_a_positive_peak_on_linux() {
        let hwm = peak_rss_bytes().expect("procfs readable on linux");
        assert!(hwm > 0);
    }
}
