//! Memory tracker — the Fig 10 series (paper §4.2.3).
//!
//! Samples the runtime's global marshalling counters
//! (`runtime::stats`) at batch boundaries, yielding per-batch bytes
//! allocated / freed / in-use — the same stacked-area series the paper
//! draws from Lightning's device-stats monitor.

use crate::runtime::stats::{snapshot, MemSnapshot};

/// One per-batch sample.
#[derive(Clone, Copy, Debug)]
pub struct MemorySample {
    pub batch: usize,
    /// Bytes marshalled into device buffers during this batch.
    pub allocated: u64,
    /// Bytes released during this batch.
    pub freed: u64,
    /// Cumulative in-use bytes after this batch.
    pub in_use: u64,
}

/// Batch-boundary sampler over the global runtime counters.
pub struct MemoryTracker {
    base: MemSnapshot,
    last: MemSnapshot,
    samples: Vec<MemorySample>,
}

impl Default for MemoryTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl MemoryTracker {
    /// Start tracking from the current counter state.
    pub fn new() -> Self {
        let now = snapshot();
        Self {
            base: now,
            last: now,
            samples: Vec::new(),
        }
    }

    /// Record the end of one batch.
    pub fn sample_batch(&mut self) {
        let now = snapshot();
        let delta = now.since(&self.last);
        let since_base = now.since(&self.base);
        self.samples.push(MemorySample {
            batch: self.samples.len(),
            allocated: delta.allocated,
            freed: delta.freed,
            in_use: since_base.in_use(),
        });
        self.last = now;
    }

    /// All samples so far.
    pub fn samples(&self) -> &[MemorySample] {
        &self.samples
    }

    /// Render the Fig 10 series as CSV text
    /// (`batch,allocated,freed,in_use`).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("batch,bytes_allocated,bytes_freed,bytes_in_use\n");
        for m in &self.samples {
            s.push_str(&format!(
                "{},{},{},{}\n",
                m.batch, m.allocated, m.freed, m.in_use
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::stats;

    #[test]
    fn tracks_batch_deltas() {
        let mut t = MemoryTracker::new();
        stats::add_allocated(1000);
        stats::add_freed(400);
        t.sample_batch();
        stats::add_allocated(50);
        t.sample_batch();
        let s = t.samples();
        assert_eq!(s.len(), 2);
        // Other tests may add to the global counters concurrently, so
        // deltas are lower bounds.
        assert!(s[0].allocated >= 1000);
        assert!(s[0].freed >= 400);
        assert!(s[1].allocated >= 50);
        assert_eq!(s[1].batch, 1);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = MemoryTracker::new();
        t.sample_batch();
        let csv = t.to_csv();
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines[0], "batch,bytes_allocated,bytes_freed,bytes_in_use");
        assert_eq!(lines.len(), 2);
    }
}
