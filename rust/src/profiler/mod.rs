//! Profilers — Lightning-profiler analogues (paper §3.3.2, Table 4 and
//! §4.2.3, Fig 10).
//!
//! [`SimpleProfiler`] mirrors Lightning's `SimpleProfiler`: named action
//! timers with mean duration / call count / total / percentage, rendered
//! in exactly Table 4's schema. [`MemoryTracker`] samples the runtime's
//! marshalling counters per batch, producing Fig 10's
//! allocated/freed/in-use series.

pub mod memory;

use std::collections::BTreeMap;
use std::time::Instant;

pub use memory::{MemoryTracker, MemorySample};

/// One profiled action's accumulated timing.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActionStats {
    pub num_calls: usize,
    pub total_secs: f64,
}

impl ActionStats {
    pub fn mean_secs(&self) -> f64 {
        if self.num_calls == 0 {
            0.0
        } else {
            self.total_secs / self.num_calls as f64
        }
    }
}

/// A row of the rendered profile (Table 4 schema).
#[derive(Clone, Debug)]
pub struct ProfileRow {
    pub action: String,
    pub mean_secs: f64,
    pub num_calls: usize,
    pub total_secs: f64,
    pub percent: f64,
}

/// Named-action wall-clock profiler.
#[derive(Default)]
pub struct SimpleProfiler {
    actions: BTreeMap<String, ActionStats>,
    started: Option<Instant>,
    /// Total profiled wall-clock (set on `stop`, or live if running).
    total: f64,
}

/// RAII timer: records on drop.
pub struct ActionTimer<'p> {
    profiler: &'p mut SimpleProfiler,
    action: &'static str,
    start: Instant,
}

impl Drop for ActionTimer<'_> {
    fn drop(&mut self) {
        let dt = self.start.elapsed().as_secs_f64();
        self.profiler.record(self.action, dt);
    }
}

impl SimpleProfiler {
    pub fn new() -> Self {
        Self {
            actions: BTreeMap::new(),
            started: Some(Instant::now()),
            total: 0.0,
        }
    }

    /// Record a completed action of `secs` duration.
    pub fn record(&mut self, action: &str, secs: f64) {
        let e = self.actions.entry(action.to_string()).or_default();
        e.num_calls += 1;
        e.total_secs += secs;
    }

    /// Time a closure under `action`.
    pub fn time<T>(&mut self, action: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(action, t0.elapsed().as_secs_f64());
        out
    }

    /// Start an RAII timer (records when the guard drops).
    pub fn start(&mut self, action: &'static str) -> ActionTimer<'_> {
        ActionTimer {
            start: Instant::now(),
            action,
            profiler: self,
        }
    }

    /// Freeze the total wall-clock.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total = t0.elapsed().as_secs_f64();
        }
    }

    fn total_secs(&self) -> f64 {
        match self.started {
            Some(t0) => t0.elapsed().as_secs_f64(),
            None => self.total,
        }
    }

    /// Rows sorted by total time descending, plus the "Total Run" row
    /// first — exactly the paper's Table 4 layout.
    pub fn rows(&self) -> Vec<ProfileRow> {
        let total = self.total_secs().max(1e-12);
        let total_calls: usize = self.actions.values().map(|a| a.num_calls).sum();
        let mut rows = vec![ProfileRow {
            action: "Total Run".into(),
            mean_secs: f64::NAN,
            num_calls: total_calls,
            total_secs: total,
            percent: 100.0,
        }];
        let mut body: Vec<ProfileRow> = self
            .actions
            .iter()
            .map(|(name, a)| ProfileRow {
                action: name.clone(),
                mean_secs: a.mean_secs(),
                num_calls: a.num_calls,
                total_secs: a.total_secs,
                percent: 100.0 * a.total_secs / total,
            })
            .collect();
        body.sort_by(|a, b| b.total_secs.partial_cmp(&a.total_secs).unwrap());
        rows.extend(body);
        rows
    }

    /// Render the Table-4-style report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{:<28} {:>12} {:>10} {:>12} {:>9}\n",
            "Action", "Mean Dur.(s)", "Num Calls", "Total(s)", "Percent."
        ));
        s.push_str(&"-".repeat(76));
        s.push('\n');
        for r in self.rows() {
            let mean = if r.mean_secs.is_nan() {
                "-".to_string()
            } else {
                format!("{:.6}", r.mean_secs)
            };
            s.push_str(&format!(
                "{:<28} {:>12} {:>10} {:>12.4} {:>9.4}\n",
                r.action, mean, r.num_calls, r.total_secs, r.percent
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_calls_and_totals() {
        let mut p = SimpleProfiler::new();
        p.record("opt_step", 0.002);
        p.record("opt_step", 0.004);
        p.record("data_marshal", 0.001);
        let rows = p.rows();
        assert_eq!(rows[0].action, "Total Run");
        let opt = rows.iter().find(|r| r.action == "opt_step").unwrap();
        assert_eq!(opt.num_calls, 2);
        assert!((opt.total_secs - 0.006).abs() < 1e-9);
        assert!((opt.mean_secs - 0.003).abs() < 1e-9);
    }

    #[test]
    fn rows_sorted_by_total_desc() {
        let mut p = SimpleProfiler::new();
        p.record("small", 0.001);
        p.record("big", 1.0);
        let rows = p.rows();
        assert_eq!(rows[1].action, "big");
        assert_eq!(rows[2].action, "small");
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = SimpleProfiler::new();
        let v = p.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(p.rows().len(), 2);
    }

    #[test]
    fn raii_timer_records_on_drop() {
        let mut p = SimpleProfiler::new();
        {
            let _t = p.start("scoped");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let rows = p.rows();
        let scoped = rows.iter().find(|r| r.action == "scoped").unwrap();
        assert_eq!(scoped.num_calls, 1);
        assert!(scoped.total_secs >= 0.002);
    }

    #[test]
    fn report_contains_table4_columns() {
        let mut p = SimpleProfiler::new();
        p.record("lr_sched", 0.0006);
        p.stop();
        let rep = p.report();
        for col in ["Action", "Mean Dur.(s)", "Num Calls", "Total(s)", "Percent."] {
            assert!(rep.contains(col), "missing column {col}");
        }
        assert!(rep.contains("Total Run"));
        assert!(rep.contains("lr_sched"));
    }

    #[test]
    fn percentages_relative_to_total() {
        let mut p = SimpleProfiler::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        p.record("x", 0.001);
        p.stop();
        let rows = p.rows();
        let x = rows.iter().find(|r| r.action == "x").unwrap();
        assert!(x.percent > 0.0 && x.percent < 100.0);
    }
}
