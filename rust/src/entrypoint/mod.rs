//! Entrypoint — the federated experiment orchestrator (paper §3.2.4).
//!
//! TorchFL's `Entrypoint` wraps agents, a sampler, and an aggregator and
//! runs the whole experiment from an `FLParams` config; this module is
//! the rust analogue, with local training fanned out over the worker
//! pool (each worker = one simulated client device with its own
//! executor — native or PJRT, per `FlParams::backend`) and aggregation
//! + evaluation on the leader thread.
//!
//! Round loop (the FL lifecycle of paper Fig 1):
//!   1. sampler picks `A^t ⊆ A`
//!   2. each sampled agent trains locally from `W^t` (worker pool)
//!   3. the aggregator folds the deltas into `W^{t+1}` (Eq. 2)
//!   4. the global model is evaluated on the test split, sharded
//!      across the same worker pool
//!   5. loggers receive per-round + per-agent records
//!
//! Rounds are **streamed** whenever the aggregation rule is a function
//! of the weighted mean delta (FedAvg/FedSGD/FedAvgM/FedAdam) and no
//! stage needs the materialized cohort (defense and compression are
//! no-ops): each worker pushes its finished delta into a shared
//! [`StreamingAccumulator`] as the agent completes, so the server-side
//! reduce overlaps local training and step 3 collapses to one finalize
//! pass — order-invariant by construction (exact integer reduce).
//! Robust rules, defenses, and compressors keep the materialized path.
//!
//! With `fuse = true` (SGD only) step 2 runs as a **fused lockstep
//! cohort** on the leader instead of per-agent pool jobs: every layer
//! of every sampled agent's step goes through one fused panel-parallel
//! GEMM (`worker::run_local_fused`), which keeps small-model cohorts
//! from contending for cores — per-agent results are identical to the
//! pooled path.

pub mod experiment;
pub mod trainer;
pub mod worker;

pub use experiment::{Experiment, ExperimentBuilder};

use std::sync::Arc;
use std::time::Instant;

use crate::agents::AgentRegistry;
use crate::aggregators::{self, Aggregator, StreamKind, StreamingAccumulator};
use crate::compression::{self, Compressor};
use crate::config::FlParams;
use crate::datasets::{Dataset, Split};
use crate::defense::{self, Defense};
use crate::federation;
use crate::incentives::ContributionTracker;
use crate::loggers::Logger;
use crate::metrics::{
    Accumulator, AgentRecord, RecoveryStats, RoundOutcome, RoundRecord, SkipReason,
};
use crate::profiler::SimpleProfiler;
use crate::runtime::{EvalStats, Manifest};
use crate::samplers::{self, Sampler};
use crate::util::error::Result;
use crate::util::{Parallelism, Rng, WorkerPool};

use worker::{LocalJob, RuntimeKey};

/// Communication accounting for a run (compression effectiveness).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Bytes the updates would cost dense (f32).
    pub dense_bytes: u64,
    /// Bytes actually "sent" after compression.
    pub wire_bytes: u64,
}

impl CommStats {
    pub fn ratio(&self) -> f64 {
        if self.wire_bytes == 0 {
            1.0
        } else {
            self.dense_bytes as f64 / self.wire_bytes as f64
        }
    }
}

/// Result of a full federated run.
pub struct RunResult {
    pub rounds: Vec<RoundRecord>,
    pub agent_records: Vec<AgentRecord>,
    pub final_eval: EvalStats,
    pub profiler: SimpleProfiler,
    /// Upload accounting (non-trivial when compression is enabled).
    pub comm: CommStats,
    /// Gradient-alignment contribution scores per agent (incentives).
    pub contributions: ContributionTracker,
    /// Agents that dropped out, per round.
    pub dropped: Vec<Vec<usize>>,
    /// Updates rejected by the defense, per round.
    pub defense_rejected: Vec<Vec<usize>>,
    /// Total simulated seconds on the engine's clock (0 for the
    /// lockstep reference and the degenerate policy).
    pub sim_secs: f64,
}

/// The federated experiment orchestrator.
pub struct Entrypoint {
    pub params: FlParams,
    pub manifest: Arc<Manifest>,
    pub dataset: Arc<Dataset>,
    pub registry: AgentRegistry,
    pub(crate) sampler: Box<dyn Sampler>,
    pub(crate) aggregator: Box<dyn Aggregator>,
    pub(crate) defense: Box<dyn Defense>,
    pub(crate) compressor: Box<dyn Compressor>,
    pub(crate) pool: WorkerPool,
    pub(crate) global: Vec<f32>,
    pub(crate) key: RuntimeKey,
    pub(crate) rng: Rng,
    /// Streaming-round reduce state, allocated on the first streaming
    /// round and reused (reset) every round after.
    pub(crate) stream_acc: Option<Arc<StreamingAccumulator>>,
}

impl Entrypoint {
    /// Build an experiment from config: loads the manifest + dataset,
    /// shards the train split, initialises agents and the global model.
    pub fn new(params: FlParams, manifest: Arc<Manifest>) -> Result<Self> {
        params.validate()?;
        let mut rng = Rng::new(params.seed);

        let dataset = Arc::new(Dataset::load(&manifest, &params.dataset, params.seed)?);
        let registry = if params.registry.uses_legacy_partition(params.num_agents) {
            // Legacy path: materialize labels, run the scheme partition
            // (which consumes seeded RNG draws), one eager Agent per
            // shard — bit-for-bit what every pre-registry config got.
            let labels = dataset.labels(Split::Train);
            let partition =
                federation::shard(&labels, params.num_agents, params.split, &mut rng)?;
            AgentRegistry::from_partition(partition.shards)
        } else {
            // Closed-form range shards over the virtual index space.
            // Synthesis is a pure function of (seed, split, index) for
            // *any* index, so the space stretches to cover populations
            // larger than the nominal train split; no construction-time
            // RNG draws, so materialized and virtual are bit-identical.
            let total_train = dataset.num_train().max(params.num_agents);
            if params.registry.resolves_virtual(params.num_agents) {
                AgentRegistry::virtualized(params.num_agents, total_train)
            } else {
                AgentRegistry::materialized_range(params.num_agents, total_train)
            }
        };

        let key = RuntimeKey {
            backend: params.backend,
            model: params.model.clone(),
            dataset: params.dataset.clone(),
            optimizer: params.optimizer.to_string(),
            mode: params.mode.to_string(),
            entry_tag: String::new(),
        };
        // W^0 comes from the executor (op 5: model loading) — weight
        // files under PJRT, deterministic synthesis under native.
        let use_pretrained = params.use_pretrained;
        let global = worker::with_runtime(&manifest, &key, |rt| {
            if use_pretrained {
                rt.pretrained_params()
            } else {
                rt.init_params()
            }
        })?;

        let sampler = samplers::from_name(&params.sampler)?;
        let aggregator = aggregators::from_name(&params.aggregator)?;
        let defense = defense::from_name(&params.defense)?;
        let compressor = compression::from_name(&params.compression, params.seed)?;
        // One precedence rule for every pool: explicit config beats
        // `FERRISFL_THREADS` beats hardware detection.
        let workers = Parallelism::from_workers(params.workers)
            .resolve(Parallelism::detect().min(8));

        Ok(Self {
            params,
            manifest,
            dataset,
            registry,
            sampler,
            aggregator,
            defense,
            compressor,
            pool: WorkerPool::new(workers),
            global,
            key,
            rng,
            stream_acc: None,
        })
    }

    /// Whether rounds of this run reduce updates incrementally: the
    /// aggregation rule must be a function of the weighted mean delta,
    /// and no stage may need the materialized cohort (defenses screen —
    /// and may reject — whole deltas; compressors rewrite them on the
    /// "wire" before aggregation). Gated on the traits' own
    /// capability probes, not on config names.
    pub(crate) fn stream_kind(&self) -> Option<StreamKind> {
        if !self.defense.is_passthrough() || !self.compressor.is_identity() {
            return None;
        }
        self.aggregator.stream_kind()
    }

    /// Current global parameters.
    pub fn global_params(&self) -> &[f32] {
        &self.global
    }

    /// Run the full experiment, emitting records into `logger`.
    ///
    /// With the default `single` topology this routes through the
    /// event-driven round engine (see [`crate::engine`]): the
    /// scheduling policy comes from `FlParams::round_policy`, and with
    /// the default config (zero latency, no deadline, no goal-count)
    /// the engine's degenerate policy reproduces
    /// [`Self::run_lockstep`] bit-identically — the parity is pinned
    /// by `tests/engine_e2e.rs`. Distributed topologies route through
    /// [`crate::transport`]'s leader, whose wire protocol carries the
    /// streaming reduce's own fixed-point terms and therefore lands on
    /// the same bits again (pinned by `tests/distributed_e2e.rs`).
    pub fn run(&mut self, logger: &mut dyn Logger) -> Result<RunResult> {
        if self.params.topology.is_single() {
            crate::engine::driver::run_engine(self, logger)
        } else {
            crate::transport::run_distributed(self, logger)
        }
    }

    /// The original synchronous round loop, retained as the golden
    /// reference the engine's degenerate policy is pinned against
    /// (the same idiom as `NaiveMlp` and the serial GEMM drivers:
    /// the trusted implementation stays, bit-exact, as the oracle).
    pub fn run_lockstep(&mut self, logger: &mut dyn Logger) -> Result<RunResult> {
        let mut profiler = SimpleProfiler::new();
        let mut rounds = Vec::new();
        let mut agent_records = Vec::new();
        let mut comm = CommStats::default();
        let mut contributions = ContributionTracker::new();
        let mut dropped_log = Vec::new();
        let mut rejected_log = Vec::new();
        let k = self.params.sampled_per_round();
        let fault_plan = self.params.fault_plan();

        for round in 0..self.params.global_epochs {
            let t_round = Instant::now();

            // 1. sample A^t
            let mut sampled = profiler.time("sampling", || {
                self.sampler.sample(&self.registry, k, &mut self.rng)
            })?;

            // 1b. straggler/failure injection: each sampled device drops
            // with probability `dropout` (cross-device FL reality; the
            // round proceeds with survivors, paper Fig 1 lifecycle).
            // The draw loop lives on `FaultPlan` so the engine's richer
            // fault model provably shares this exact RNG sequence.
            let mut dropped = Vec::new();
            fault_plan.apply_dropout(&mut self.rng, &mut sampled, &mut dropped);
            if sampled.is_empty() {
                // whole cohort offline: skip the round (the dropped
                // list is still surfaced to the logger, like any round)
                dropped_log.push(dropped.clone());
                rejected_log.push(Vec::new());
                let rec = RoundRecord {
                    round,
                    train_loss: f64::NAN,
                    train_acc: f64::NAN,
                    eval_loss: f64::NAN,
                    eval_acc: f64::NAN,
                    sampled,
                    dropped,
                    rejected: Vec::new(),
                    secs: t_round.elapsed().as_secs_f64(),
                    sim_secs: 0.0,
                    outcome: RoundOutcome::Skipped(SkipReason::EmptyCohort),
                    recovery: RecoveryStats::default(),
                    adversarial: 0,
                    trimmed_frac: 0.0,
                };
                logger.log_round(&rec)?;
                rounds.push(rec);
                continue;
            }

            // 2. local training on the worker pool. On streaming rounds
            // each worker also pushes its finished delta straight into
            // the shared lock-striped accumulator, so the FedAvg-family
            // reduce overlaps the stragglers' local training and the
            // leader-side aggregation step collapses to one finalize
            // pass. FedAvg weights depend only on shard sizes, which are
            // known before dispatch (and the defense is a no-op on this
            // path, so the cohort cannot shrink after pushing).
            // Observer rules (the sketch defenses) fold updates into
            // leader-side state, which the pool closures cannot reach;
            // this reference loop routes them through the materialized
            // path — bit-identical, since their `aggregate()` replays
            // the same quantize→observe pipeline.
            let stream_kind =
                if self.aggregator.observes_updates() { None } else { self.stream_kind() };
            let stream_acc = if stream_kind.is_some() {
                let p = self.global.len();
                if self.stream_acc.as_ref().is_some_and(|acc| acc.len() == p) {
                    let acc = self.stream_acc.as_ref().unwrap();
                    acc.reset();
                    Some(Arc::clone(acc))
                } else {
                    let acc = Arc::new(StreamingAccumulator::new(p));
                    self.stream_acc = Some(Arc::clone(&acc));
                    Some(acc)
                }
            } else {
                None
            };
            let stream_weights: Vec<u64> = match stream_kind {
                Some(StreamKind::SampleWeighted) => {
                    let ws: Vec<u64> =
                        sampled.iter().map(|&aid| self.registry.shard_len(aid) as u64).collect();
                    if ws.iter().sum::<u64>() == 0 {
                        // all-zero sample counts: uniform fallback,
                        // mirroring aggregators::sample_weights.
                        vec![1; ws.len()]
                    } else {
                        ws
                    }
                }
                _ => vec![1; sampled.len()],
            };

            let t_local = Instant::now();
            let global = Arc::new(self.global.clone());
            let mk_job = |aid: usize| LocalJob {
                agent_id: aid,
                round,
                shard: self.registry.shard(aid),
                global: Arc::clone(&global),
                lr: self.params.lr,
                local_epochs: self.params.local_epochs,
                max_steps_per_epoch: self.params.max_local_steps,
                seed: self.params.seed,
            };
            let results: Vec<Result<(aggregators::Update, AgentRecord)>> = if self.params.fuse {
                // Fused lockstep on the leader (`fuse = true`): the
                // cohort's batches go through one fused panel-parallel
                // GEMM per layer (`worker::run_local_fused`), so the
                // cores are driven by the panel pool under a single
                // step instead of contending per-agent worker jobs.
                // Streaming rounds push the finished deltas afterwards
                // — the reduce is order-invariant, so the result is
                // identical to the workers pushing as they finish.
                let jobs: Vec<LocalJob> = sampled.iter().map(|&aid| mk_job(aid)).collect();
                let mut list = worker::with_runtime(&self.manifest, &self.key, |rt| {
                    worker::run_local_fused(rt, &self.dataset, &jobs)
                })?;
                // Byzantine clients perturb before anything leaves the
                // device — the accumulator push and the aggregate both
                // see the poisoned delta.
                for (update, record) in list.iter_mut() {
                    self.params.adversary.perturb(
                        self.params.seed,
                        record.agent_id as u64,
                        round as u64,
                        &mut update.delta,
                    );
                }
                if let Some(acc) = &stream_acc {
                    for (i, (update, _)) in list.iter().enumerate() {
                        acc.push(&update.delta, stream_weights[i])?;
                    }
                }
                list.into_iter().map(Ok).collect()
            } else {
                let jobs: Vec<_> = sampled
                    .iter()
                    .enumerate()
                    .map(|(i, &aid)| {
                        let job = mk_job(aid);
                        let manifest = Arc::clone(&self.manifest);
                        let dataset = Arc::clone(&self.dataset);
                        let key = self.key.clone();
                        let adversary = self.params.adversary.clone();
                        let stream =
                            stream_acc.as_ref().map(|acc| (Arc::clone(acc), stream_weights[i]));
                        move |_wid: usize| -> Result<_> {
                            worker::with_runtime(&manifest, &key, |rt| {
                                let (mut update, record) = worker::run_local(rt, &dataset, &job)?;
                                // The perturbation happens on-device,
                                // before the delta reaches the reduce.
                                adversary.perturb(
                                    job.seed,
                                    job.agent_id as u64,
                                    job.round as u64,
                                    &mut update.delta,
                                );
                                if let Some((acc, w)) = &stream {
                                    acc.push(&update.delta, *w)?;
                                }
                                Ok((update, record))
                            })
                        }
                    })
                    .collect();
                self.pool.run(jobs)
            };
            profiler.record("local_training", t_local.elapsed().as_secs_f64());

            let mut updates = Vec::with_capacity(results.len());
            let mut train_loss = Accumulator::default();
            let mut train_acc = Accumulator::default();
            let mut adversarial = 0u32;
            for res in results {
                let (mut update, record) = res?;
                // `perturb` fired inside the worker closure; its draw
                // is a pure function of (seed, agent, round), so the
                // counter can be reconstructed here.
                if self.params.adversary.is_adversarial(
                    self.params.seed,
                    record.agent_id as u64,
                    round as u64,
                ) {
                    adversarial += 1;
                }
                train_loss.add(record.final_loss());
                train_acc.add(record.final_acc());
                self.registry.record_round(
                    record.agent_id,
                    record.final_loss(),
                    self.params.local_epochs,
                );
                logger.log_agent(&record)?;
                agent_records.push(record);
                let dense = (update.delta.len() * 4) as u64;
                comm.dense_bytes += dense;
                if stream_acc.is_some() {
                    // Streaming rounds require the identity compressor;
                    // the delta is already reduced, and is retained (no
                    // copy) only for the contribution scoring below.
                    comm.wire_bytes += dense;
                } else {
                    // client-side compression: the update crosses the
                    // "wire" compressed; the server reconstructs before
                    // aggregation.
                    let compressed = self.compressor.compress(&update.delta);
                    comm.wire_bytes += compressed.wire_bytes() as u64;
                    update.delta = compressed.decompress();
                }
                updates.push(update);
            }

            // 2b. server-side defense screens the cohort before Eq. 2.
            let report = profiler.time("defense", || self.defense.screen(&mut updates));
            rejected_log.push(report.rejected.clone());
            dropped_log.push(dropped.clone());
            if updates.is_empty() {
                // defense rejected everything: keep the old global model
                let rec = RoundRecord {
                    round,
                    train_loss: train_loss.mean(),
                    train_acc: train_acc.mean(),
                    eval_loss: f64::NAN,
                    eval_acc: f64::NAN,
                    sampled,
                    dropped,
                    rejected: report.rejected,
                    secs: t_round.elapsed().as_secs_f64(),
                    sim_secs: 0.0,
                    outcome: RoundOutcome::Skipped(SkipReason::NoUpdates),
                    recovery: RecoveryStats::default(),
                    adversarial,
                    trimmed_frac: 0.0,
                };
                logger.log_round(&rec)?;
                rounds.push(rec);
                continue;
            }

            // 3. aggregate (Eq. 2). Streaming rounds finalize the
            // already-reduced mean delta (one P pass) and fold it
            // through the rule's state update; materialized rounds run
            // the full rule on the leader's executor as before.
            let t_agg = Instant::now();
            let new_global = match &stream_acc {
                Some(acc) => {
                    let mean = acc.finalize()?;
                    self.aggregator.apply_streamed(&self.global, &mean)?
                }
                None => {
                    let manifest = Arc::clone(&self.manifest);
                    let key = self.key.clone();
                    let aggregator = &mut self.aggregator;
                    worker::with_runtime(&manifest, &key, |rt| {
                        aggregator.aggregate(&self.global, &updates, Some(rt))
                    })?
                }
            };
            // incentives: score the cohort's gradient alignment against
            // the realised round delta.
            let round_delta: Vec<f32> = new_global
                .iter()
                .zip(&self.global)
                .map(|(n, g)| n - g)
                .collect();
            contributions.record_round(&updates, &round_delta);
            self.global = new_global;
            profiler.record("aggregation", t_agg.elapsed().as_secs_f64());

            // 4. evaluate
            let do_eval = self.params.eval_every > 0
                && (round + 1) % self.params.eval_every == 0;
            let eval = if do_eval {
                let t_eval = Instant::now();
                let stats = self.evaluate()?;
                profiler.record("evaluation", t_eval.elapsed().as_secs_f64());
                Some(stats)
            } else {
                None
            };

            // 5. log
            let rec = RoundRecord {
                round,
                train_loss: train_loss.mean(),
                train_acc: train_acc.mean(),
                eval_loss: eval.map_or(f64::NAN, |e| e.mean_loss()),
                eval_acc: eval.map_or(f64::NAN, |e| e.accuracy()),
                sampled,
                dropped,
                rejected: report.rejected,
                secs: t_round.elapsed().as_secs_f64(),
                sim_secs: 0.0,
                outcome: RoundOutcome::Aggregated,
                recovery: RecoveryStats::default(),
                adversarial,
                trimmed_frac: self.aggregator.trimmed_frac(),
            };
            logger.log_round(&rec)?;
            rounds.push(rec);
        }

        let final_eval = self.evaluate()?;
        profiler.stop();
        logger.finish()?;
        Ok(RunResult {
            rounds,
            agent_records,
            final_eval,
            profiler,
            comm,
            contributions,
            dropped: dropped_log,
            defense_rejected: rejected_log,
            sim_secs: 0.0,
        })
    }

    /// Evaluate the current global model over the full test split,
    /// sharding eval batches across the experiment's worker pool (the
    /// same pool local training fans out on).
    pub fn evaluate(&self) -> Result<EvalStats> {
        worker::evaluate_sharded(
            &self.manifest,
            &self.key,
            &self.dataset,
            &self.pool,
            &self.global,
            0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BackendKind;

    #[test]
    fn entrypoint_validates_params() {
        let mut p = FlParams::default();
        p.sampling_ratio = -1.0;
        // Invalid params must fail before any artifact I/O.
        let m = Arc::new(Manifest {
            backend: BackendKind::Native,
            dir: "/nonexistent".into(),
            train_batch: 32,
            eval_batch: 128,
            k_pad: 16,
            datasets: Default::default(),
            zoo: Default::default(),
            artifacts: vec![],
        });
        assert!(Entrypoint::new(p, m).is_err());
    }

    #[test]
    fn entrypoint_builds_on_native_manifest() {
        let p = FlParams {
            num_agents: 4,
            model: "mlp-s".into(),
            workers: 1,
            ..FlParams::default()
        };
        let m = Arc::new(Manifest::native());
        let ep = Entrypoint::new(p, m).unwrap();
        assert_eq!(ep.registry.len(), 4);
        assert!(!ep.global_params().is_empty());
    }
}
