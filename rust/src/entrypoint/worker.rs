//! Worker-side local training (the simulated FL client).
//!
//! Each pool worker owns its own executor cache — PJRT executors wrap
//! `Rc`-based `xla` handles and must not cross threads, and the native
//! executors are cheap to build — so the runtime cache is thread-local,
//! keyed directly by [`RuntimeKey`] (it derives `Hash`/`Eq`; no string
//! key is formatted on lookup). Sequential experiments in one process
//! reuse compilations.
//!
//! The local-training loop is a zero-allocation steady state: one
//! [`crate::runtime::StepScratch`] arena, one [`BatchBuf`], and one
//! index buffer are reused across every step of an agent's round.
//!
//! This module is the only place that knows which concrete backend
//! implements [`ModelExecutor`]; everything above it (entrypoint,
//! trainer, repro, benches) is backend-agnostic.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use crate::aggregators::Update;
use crate::datasets::{BatchBuf, Dataset, Split};
use crate::metrics::AgentRecord;
use crate::runtime::{
    AdamState, BackendKind, Manifest, ModelExecutor, NativeExecutor, StepScratch,
};
use crate::util::error::{bail, Result};
use crate::util::{Rng, WorkerPool};

thread_local! {
    static RUNTIMES: RefCell<HashMap<RuntimeKey, Rc<dyn ModelExecutor>>> =
        RefCell::new(HashMap::new());
}

#[cfg(feature = "pjrt")]
thread_local! {
    static DEVICE: RefCell<Option<Rc<crate::runtime::Device>>> = const { RefCell::new(None) };
}

/// Identifies one (backend, model, dataset, optimizer, mode, tag) bundle.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RuntimeKey {
    pub backend: BackendKind,
    pub model: String,
    pub dataset: String,
    pub optimizer: String,
    pub mode: String,
    /// "" for Pallas-kernel artifacts, "_ref" for the pure-jnp ablation
    /// (PJRT only).
    pub entry_tag: String,
}

impl RuntimeKey {
    /// A native-backend key with the common defaults filled in.
    pub fn native(model: &str, dataset: &str, optimizer: &str, mode: &str) -> Self {
        Self {
            backend: BackendKind::Native,
            model: model.to_string(),
            dataset: dataset.to_string(),
            optimizer: optimizer.to_string(),
            mode: mode.to_string(),
            entry_tag: String::new(),
        }
    }
}

impl fmt::Display for RuntimeKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}@{}:{}:{}:{}",
            self.backend, self.model, self.dataset, self.optimizer, self.mode, self.entry_tag
        )
    }
}

/// Get (or lazily build) this thread's executor for `key`.
pub fn with_runtime<T>(
    manifest: &Arc<Manifest>,
    key: &RuntimeKey,
    f: impl FnOnce(&dyn ModelExecutor) -> Result<T>,
) -> Result<T> {
    let rt = RUNTIMES.with(|r| -> Result<Rc<dyn ModelExecutor>> {
        let mut r = r.borrow_mut();
        if let Some(rt) = r.get(key) {
            return Ok(Rc::clone(rt));
        }
        let rt = build_executor(manifest, key)?;
        r.insert(key.clone(), Rc::clone(&rt));
        Ok(rt)
    })?;
    f(&*rt)
}

fn build_executor(manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    match key.backend {
        BackendKind::Native => {
            if !key.entry_tag.is_empty() {
                bail!(
                    "entry tag {:?} is a PJRT artifact ablation; the native \
                     backend has no kernel/ref split",
                    key.entry_tag
                );
            }
            Ok(Rc::new(NativeExecutor::load(
                manifest,
                &key.model,
                &key.dataset,
                &key.optimizer,
                &key.mode,
            )?))
        }
        BackendKind::Pjrt => build_pjrt(manifest, key),
    }
}

#[cfg(feature = "pjrt")]
fn build_pjrt(manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    use crate::util::error::Context;

    let device = DEVICE.with(|d| -> Result<Rc<crate::runtime::Device>> {
        let mut d = d.borrow_mut();
        if d.is_none() {
            *d = Some(Rc::new(crate::runtime::Device::cpu()?));
        }
        Ok(Rc::clone(d.as_ref().unwrap()))
    })?;
    let art = manifest.artifact(&key.model, &key.dataset)?;
    let ds = manifest.dataset(&key.dataset)?;
    let rt = crate::runtime::PjrtRuntime::load(
        &device,
        manifest,
        art,
        ds,
        &key.optimizer,
        &key.mode,
        &key.entry_tag,
    )
    .with_context(|| format!("loading PJRT runtime for {key}"))?;
    Ok(Rc::new(rt))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt(_manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    bail!(
        "backend 'pjrt' requested for {}@{} but this build has no PJRT \
         support — vendor the xla crate and add it under the `pjrt` \
         feature (see the instructions in rust/Cargo.toml), then \
         rebuild with `--features pjrt`; or use the default native \
         backend",
        key.model,
        key.dataset
    )
}

/// Everything a worker needs to run one agent's local round.
#[derive(Clone)]
pub struct LocalJob {
    pub agent_id: usize,
    pub round: usize,
    pub shard: Vec<usize>,
    pub global: Arc<Vec<f32>>,
    pub lr: f32,
    pub local_epochs: usize,
    /// 0 = unlimited (full shard per epoch).
    pub max_steps_per_epoch: usize,
    pub seed: u64,
}

/// One training pass over `order` in fixed-shape batches, shared by the
/// FL client loop ([`run_local`]) and the central trainer: the tail
/// batch wraps around `order`, and the epoch metrics weight each batch
/// by its *distinct* examples so the wrapped duplicates don't
/// double-count. `max_steps == 0` means unlimited. Returns
/// `(loss_sum, hit_sum, seen)` with the sums weighted by distinct
/// examples — divide by `seen` for epoch means.
#[allow(clippy::too_many_arguments)]
pub(crate) fn train_epoch(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    order: &[usize],
    lr: f32,
    max_steps: usize,
    mut adam: Option<&mut AdamState>,
    params: &mut Vec<f32>,
    scratch: &mut StepScratch,
    buf: &mut BatchBuf,
    idx: &mut Vec<usize>,
) -> Result<(f64, f64, usize)> {
    let b = rt.train_batch_size();
    let mut loss_sum = 0.0f64;
    let mut hit_sum = 0.0f64;
    let mut seen = 0usize;
    let mut steps = 0usize;
    let mut start = 0usize;
    while start < order.len() {
        if max_steps > 0 && steps >= max_steps {
            break;
        }
        // Fixed-shape batches: wrap around the shard for the tail.
        idx.clear();
        for i in 0..b {
            idx.push(order[(start + i) % order.len()]);
        }
        let batch = dataset.gather_into(Split::Train, idx, buf);
        let stats = match adam.as_deref_mut() {
            Some(state) => rt.train_step_adam(params, state, batch.x, batch.y, lr, scratch)?,
            None => rt.train_step_sgd(params, batch.x, batch.y, lr, scratch)?,
        };
        // The wrapped tail repeats examples already seen this epoch;
        // weight the batch by its distinct examples so the epoch
        // metrics don't double-count them.
        let distinct = b.min(order.len() - start);
        loss_sum += stats.loss as f64 * distinct as f64;
        hit_sum += stats.hits as f64 * distinct as f64 / b as f64;
        seen += distinct;
        steps += 1;
        start += b;
    }
    Ok((loss_sum, hit_sum, seen))
}

/// Run local training for one agent; returns its parameter delta (Eq. 1)
/// and per-epoch metrics (the Fig 9 series).
///
/// The steady-state loop allocates nothing: batches gather into a
/// reused [`BatchBuf`], steps run on a reused [`StepScratch`], the
/// batch index buffer persists across steps, and the final delta is
/// computed in place in the params buffer.
pub fn run_local(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    job: &LocalJob,
) -> Result<(Update, AgentRecord)> {
    let t0 = Instant::now();
    let b = rt.train_batch_size();
    let mut params: Vec<f32> = (*job.global).clone();
    let mut adam = (rt.optimizer() == "adam").then(|| AdamState::zeros(params.len()));
    let mut scratch = rt.new_scratch();
    let mut buf = BatchBuf::new();
    let mut idx: Vec<usize> = Vec::with_capacity(b);

    let mut epoch_losses = Vec::with_capacity(job.local_epochs);
    let mut epoch_accs = Vec::with_capacity(job.local_epochs);
    let mut order = job.shard.clone();
    let mut rng = Rng::new(job.seed)
        .split(job.round as u64)
        .split(job.agent_id as u64);

    for _epoch in 0..job.local_epochs {
        rng.shuffle(&mut order);
        let (loss_sum, hit_sum, seen) = train_epoch(
            rt,
            dataset,
            &order,
            job.lr,
            job.max_steps_per_epoch,
            adam.as_mut(),
            &mut params,
            &mut scratch,
            &mut buf,
            &mut idx,
        )?;
        if seen > 0 {
            epoch_losses.push(loss_sum / seen as f64);
            epoch_accs.push(hit_sum / seen as f64);
        }
    }

    // delta_i = W_i^{t+1} - W^t (Eq. 1), computed in place: the params
    // buffer becomes the delta instead of allocating a second P-vector.
    let mut delta = params;
    for (d, g) in delta.iter_mut().zip(job.global.iter()) {
        *d -= *g;
    }

    let record = AgentRecord {
        round: job.round,
        agent_id: job.agent_id,
        epoch_losses,
        epoch_accs,
        num_samples: job.shard.len(),
        secs: t0.elapsed().as_secs_f64(),
    };
    Ok((
        Update {
            agent_id: job.agent_id,
            delta,
            num_samples: job.shard.len(),
        },
        record,
    ))
}

/// Evaluate a contiguous test-index range `[lo, hi)` in eval-batch
/// chunks on this thread's executor, with reused scratch/batch buffers.
fn eval_range(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    params: &[f32],
    lo: usize,
    hi: usize,
) -> Result<crate::runtime::EvalStats> {
    let eb = rt.eval_batch_size();
    let mut scratch = rt.new_scratch();
    let mut buf = BatchBuf::new();
    let mut idx: Vec<usize> = Vec::with_capacity(eb);
    let mut total = crate::runtime::EvalStats::default();
    let mut start = lo;
    while start < hi {
        let end = (start + eb).min(hi);
        idx.clear();
        idx.extend(start..end);
        let batch = dataset.gather_into(Split::Test, &idx, &mut buf);
        let s = rt.eval_batch(params, batch.x, batch.y, end - start, &mut scratch)?;
        total.loss_sum += s.loss_sum;
        total.correct += s.correct;
        total.count += s.count;
        start = end;
    }
    Ok(total)
}

/// Evaluate `params` over the full test split on the calling thread.
pub fn evaluate<'a>(
    rt: &'a dyn ModelExecutor,
    dataset: &'a Dataset,
) -> impl Fn(&[f32]) -> Result<crate::runtime::EvalStats> + 'a {
    move |params: &[f32]| eval_range(rt, dataset, params, 0, dataset.num_test())
}

/// Evaluate `params` over the test split (or its first `limit` samples
/// when `limit > 0`), sharding eval batches across `pool`.
///
/// Each shard is a contiguous, batch-aligned index range evaluated on a
/// pool worker's own executor (thread-local cache), so round evaluation
/// scales with the pool instead of serialising on the leader. Results
/// are summed in shard order — identical batching to the serial path.
pub fn evaluate_sharded(
    manifest: &Arc<Manifest>,
    key: &RuntimeKey,
    dataset: &Arc<Dataset>,
    pool: &WorkerPool,
    params: &[f32],
    limit: usize,
) -> Result<crate::runtime::EvalStats> {
    let n = if limit == 0 {
        dataset.num_test()
    } else {
        limit.min(dataset.num_test())
    };
    let eb = manifest.eval_batch.max(1);
    let batches = n.div_ceil(eb);
    let shards = pool.size().min(batches);
    if shards <= 1 {
        return with_runtime(manifest, key, |rt| eval_range(rt, dataset, params, 0, n));
    }
    let per = batches.div_ceil(shards);
    let params = Arc::new(params.to_vec());
    let jobs: Vec<_> = (0..shards)
        .map(|s| {
            let lo = (s * per * eb).min(n);
            let hi = ((s + 1) * per * eb).min(n);
            let manifest = Arc::clone(manifest);
            let key = key.clone();
            let dataset = Arc::clone(dataset);
            let params = Arc::clone(&params);
            move |_wid: usize| -> Result<crate::runtime::EvalStats> {
                with_runtime(&manifest, &key, |rt| eval_range(rt, &dataset, &params, lo, hi))
            }
        })
        .collect();
    let mut total = crate::runtime::EvalStats::default();
    for res in pool.run(jobs) {
        let s = res?;
        total.loss_sum += s.loss_sum;
        total.correct += s.correct;
        total.count += s.count;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_builds_and_caches() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let p1 = with_runtime(&m, &key, |rt| {
            assert_eq!(rt.backend(), BackendKind::Native);
            assert_eq!(rt.train_batch_size(), m.train_batch);
            rt.init_params()
        })
        .unwrap();
        // Second lookup hits the thread-local cache and agrees.
        let p2 = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn runtime_key_displays_all_fields() {
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        assert_eq!(format!("{key}"), "native:mlp-s@synth-mnist:sgd:full:");
    }

    #[test]
    fn native_rejects_ref_ablation_tag() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey {
            entry_tag: "_ref".into(),
            ..RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full")
        };
        let err = with_runtime(&m, &key, |_| Ok(())).unwrap_err();
        assert!(format!("{err}").contains("native"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_needs_feature() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey {
            backend: BackendKind::Pjrt,
            ..RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full")
        };
        let err = with_runtime(&m, &key, |_| Ok(())).unwrap_err();
        assert!(format!("{err}").contains("--features pjrt"), "{err}");
    }

    /// Sharded evaluation equals the serial path (same batching, summed
    /// in shard order) regardless of the pool size.
    #[test]
    fn sharded_eval_matches_serial() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Arc::new(Dataset::load(&m, "synth-mnist", 23).unwrap());
        let params = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        let serial = with_runtime(&m, &key, |rt| evaluate(rt, &dataset)(&params)).unwrap();
        for workers in [1usize, 3, 4] {
            let pool = WorkerPool::new(workers);
            let sharded =
                evaluate_sharded(&m, &key, &dataset, &pool, &params, 0).unwrap();
            assert_eq!(sharded.count, serial.count, "workers={workers}");
            assert_eq!(sharded.correct, serial.correct, "workers={workers}");
            assert!(
                (sharded.loss_sum - serial.loss_sum).abs() < 1e-6,
                "workers={workers}: {} vs {}",
                sharded.loss_sum,
                serial.loss_sum
            );
        }
    }

    /// `limit` caps the evaluated prefix, batch-aligned sharding intact.
    #[test]
    fn sharded_eval_respects_limit() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Arc::new(Dataset::load(&m, "synth-mnist", 29).unwrap());
        let params = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        let pool = WorkerPool::new(2);
        let s = evaluate_sharded(&m, &key, &dataset, &pool, &params, 200).unwrap();
        assert_eq!(s.count, 200.0);
    }
}
