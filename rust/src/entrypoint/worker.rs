//! Worker-side local training (the simulated FL client).
//!
//! Each pool worker owns its own executor cache — PJRT executors wrap
//! `Rc`-based `xla` handles and must not cross threads, and the native
//! executors are cheap to build — so the runtime cache is thread-local,
//! keyed directly by [`RuntimeKey`] (it derives `Hash`/`Eq`; no string
//! key is formatted on lookup). Sequential experiments in one process
//! reuse compilations.
//!
//! The local-training compute path allocates nothing per step: one
//! [`crate::runtime::StepScratch`] arena and one [`EpochPipe`] (a
//! double-buffer pool + index buffer) are reused across every step of
//! an agent's round (pinned by `tests/zero_alloc.rs`), and batch
//! synthesis runs on a helper thread one step ahead of training, fed
//! by a per-worker [`SynthCache`]. The pipeline's plumbing itself has a
//! small bounded cost: one scoped thread + two channels per epoch, and
//! an mpsc queue node per batch handoff.
//!
//! This module is the only place that knows which concrete backend
//! implements [`ModelExecutor`]; everything above it (entrypoint,
//! trainer, repro, benches) is backend-agnostic.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use crate::aggregators::Update;
use crate::datasets::{BatchBuf, Dataset, Split, SynthCache};
use crate::federation::ShardSpec;
use crate::metrics::AgentRecord;
use crate::runtime::{
    AdamState, BackendKind, FusedSlot, Manifest, ModelExecutor, NativeExecutor, StepScratch,
    StepStats,
};
use crate::util::error::{bail, Result};
use crate::util::{pipeline, Rng, WorkerPool};

thread_local! {
    static RUNTIMES: RefCell<HashMap<RuntimeKey, Rc<dyn ModelExecutor>>> =
        RefCell::new(HashMap::new());

    /// Per-worker cache of synthesized examples: an agent re-sampled
    /// onto a warm worker (and every local epoch after the first, and
    /// every round's eval shard) gathers batches by memcpy instead of
    /// re-running the per-pixel RNG.
    static SYNTH_CACHE: RefCell<SynthCache> = RefCell::new(SynthCache::new());
}

#[cfg(feature = "pjrt")]
thread_local! {
    static DEVICE: RefCell<Option<Rc<crate::runtime::Device>>> = const { RefCell::new(None) };
}

/// Identifies one (backend, model, dataset, optimizer, mode, tag) bundle.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RuntimeKey {
    pub backend: BackendKind,
    pub model: String,
    pub dataset: String,
    pub optimizer: String,
    pub mode: String,
    /// "" for Pallas-kernel artifacts, "_ref" for the pure-jnp ablation
    /// (PJRT only).
    pub entry_tag: String,
}

impl RuntimeKey {
    /// A native-backend key with the common defaults filled in.
    pub fn native(model: &str, dataset: &str, optimizer: &str, mode: &str) -> Self {
        Self {
            backend: BackendKind::Native,
            model: model.to_string(),
            dataset: dataset.to_string(),
            optimizer: optimizer.to_string(),
            mode: mode.to_string(),
            entry_tag: String::new(),
        }
    }
}

impl fmt::Display for RuntimeKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}@{}:{}:{}:{}",
            self.backend, self.model, self.dataset, self.optimizer, self.mode, self.entry_tag
        )
    }
}

/// Get (or lazily build) this thread's executor for `key`.
pub fn with_runtime<T>(
    manifest: &Arc<Manifest>,
    key: &RuntimeKey,
    f: impl FnOnce(&dyn ModelExecutor) -> Result<T>,
) -> Result<T> {
    let rt = RUNTIMES.with(|r| -> Result<Rc<dyn ModelExecutor>> {
        let mut r = r.borrow_mut();
        if let Some(rt) = r.get(key) {
            return Ok(Rc::clone(rt));
        }
        let rt = build_executor(manifest, key)?;
        r.insert(key.clone(), Rc::clone(&rt));
        Ok(rt)
    })?;
    f(&*rt)
}

fn build_executor(manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    match key.backend {
        BackendKind::Native => {
            if !key.entry_tag.is_empty() {
                bail!(
                    "entry tag {:?} is a PJRT artifact ablation; the native \
                     backend has no kernel/ref split",
                    key.entry_tag
                );
            }
            Ok(Rc::new(NativeExecutor::load(
                manifest,
                &key.model,
                &key.dataset,
                &key.optimizer,
                &key.mode,
            )?))
        }
        BackendKind::Pjrt => build_pjrt(manifest, key),
    }
}

#[cfg(feature = "pjrt")]
fn build_pjrt(manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    use crate::util::error::Context;

    let device = DEVICE.with(|d| -> Result<Rc<crate::runtime::Device>> {
        let mut d = d.borrow_mut();
        if d.is_none() {
            *d = Some(Rc::new(crate::runtime::Device::cpu()?));
        }
        Ok(Rc::clone(d.as_ref().unwrap()))
    })?;
    let art = manifest.artifact(&key.model, &key.dataset)?;
    let ds = manifest.dataset(&key.dataset)?;
    let rt = crate::runtime::PjrtRuntime::load(
        &device,
        manifest,
        art,
        ds,
        &key.optimizer,
        &key.mode,
        &key.entry_tag,
    )
    .with_context(|| format!("loading PJRT runtime for {key}"))?;
    Ok(Rc::new(rt))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt(_manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    bail!(
        "backend 'pjrt' requested for {}@{} but this build has no PJRT \
         support — vendor the xla crate and add it under the `pjrt` \
         feature (see the instructions in rust/Cargo.toml), then \
         rebuild with `--features pjrt`; or use the default native \
         backend",
        key.model,
        key.dataset
    )
}

/// Everything a worker needs to run one agent's local round.
#[derive(Clone)]
pub struct LocalJob {
    pub agent_id: usize,
    pub round: usize,
    /// Train indices this agent owns: an explicit list (legacy
    /// partitions) or a closed-form range (the virtualized registry) —
    /// either way `to_order()` yields the epoch's starting order.
    pub shard: ShardSpec,
    pub global: Arc<Vec<f32>>,
    pub lr: f32,
    pub local_epochs: usize,
    /// 0 = unlimited (full shard per epoch).
    pub max_steps_per_epoch: usize,
    pub seed: u64,
}

/// Epochs shorter than this many steps run serially — a scoped helper
/// thread costs more than it hides on two-batch shards.
const PIPELINE_MIN_STEPS: usize = 3;

/// Reusable buffers for [`train_epoch`]: the double-buffer pool cycled
/// through the synthesis pipeline plus the batch index scratch. One per
/// training loop; buffers grow once and are then reused.
pub(crate) struct EpochPipe {
    bufs: Vec<StepBatch>,
    idx: Vec<usize>,
}

/// One in-flight batch: the storage plus the epoch position it was cut
/// at (which the training side needs for distinct-example weighting).
#[derive(Default)]
pub(crate) struct StepBatch {
    buf: BatchBuf,
    start: usize,
}

impl EpochPipe {
    pub(crate) fn new() -> Self {
        Self {
            bufs: Vec::new(),
            idx: Vec::new(),
        }
    }

    /// Hand the buffer pool (two buffers, created on first use) to a
    /// pipeline run; the caller puts it back afterwards.
    fn take_bufs(&mut self) -> Vec<StepBatch> {
        let mut bufs = std::mem::take(&mut self.bufs);
        while bufs.len() < 2 {
            bufs.push(StepBatch::default());
        }
        bufs
    }
}

/// One training step over the gathered batch in `sb`, folding the step
/// stats into `sums = (loss_sum, hit_sum, seen)` weighted by the
/// batch's *distinct* examples (the wrapped tail repeats examples
/// already seen this epoch; they must not double-count).
#[allow(clippy::too_many_arguments)]
fn epoch_step(
    rt: &dyn ModelExecutor,
    sb: &StepBatch,
    order_len: usize,
    b: usize,
    lr: f32,
    adam: &mut Option<&mut AdamState>,
    params: &mut Vec<f32>,
    scratch: &mut StepScratch,
    sums: &mut (f64, f64, usize),
) -> Result<()> {
    let batch = sb.buf.view();
    let stats = match adam.as_deref_mut() {
        Some(state) => rt.train_step_adam(params, state, batch.x, batch.y, lr, scratch)?,
        None => rt.train_step_sgd(params, batch.x, batch.y, lr, scratch)?,
    };
    let distinct = b.min(order_len - sb.start);
    sums.0 += stats.loss as f64 * distinct as f64;
    sums.1 += stats.hits as f64 * distinct as f64 / b as f64;
    sums.2 += distinct;
    Ok(())
}

/// One training pass over `order` in fixed-shape batches, shared by the
/// FL client loop ([`run_local`]) and the central trainer: the tail
/// batch wraps around `order`, and the epoch metrics weight each batch
/// by its *distinct* examples so the wrapped duplicates don't
/// double-count. `max_steps == 0` means unlimited. Returns
/// `(loss_sum, hit_sum, seen)` with the sums weighted by distinct
/// examples — divide by `seen` for epoch means.
///
/// Long epochs run as a two-stage pipeline: batch `t+1` is synthesized
/// (through the worker's [`SynthCache`]) on a scoped helper thread
/// while batch `t` trains on the calling thread, double-buffered
/// through `pipe`'s buffer pool. Batches, step order, and arithmetic
/// are identical to the serial path, so the result is bit-identical —
/// the pipeline only hides synthesis latency.
#[allow(clippy::too_many_arguments)]
pub(crate) fn train_epoch(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    order: &[usize],
    lr: f32,
    max_steps: usize,
    mut adam: Option<&mut AdamState>,
    params: &mut Vec<f32>,
    scratch: &mut StepScratch,
    pipe: &mut EpochPipe,
    cache: &mut SynthCache,
) -> Result<(f64, f64, usize)> {
    let b = rt.train_batch_size();
    if order.is_empty() || b == 0 {
        return Ok((0.0, 0.0, 0));
    }
    let total_batches = order.len().div_ceil(b);
    let planned = if max_steps > 0 {
        total_batches.min(max_steps)
    } else {
        total_batches
    };
    let mut sums = (0.0f64, 0.0f64, 0usize);

    if planned < PIPELINE_MIN_STEPS {
        // Serial fallback: gather + step on this thread.
        if pipe.bufs.is_empty() {
            pipe.bufs.push(StepBatch::default());
        }
        let sb = &mut pipe.bufs[0];
        for step in 0..planned {
            let start = step * b;
            pipe.idx.clear();
            for i in 0..b {
                pipe.idx.push(order[(start + i) % order.len()]);
            }
            dataset.gather_cached(Split::Train, &pipe.idx, &mut sb.buf, cache);
            sb.start = start;
            epoch_step(rt, sb, order.len(), b, lr, &mut adam, params, scratch, &mut sums)?;
        }
        return Ok(sums);
    }

    let bufs = pipe.take_bufs();
    let idx = &mut pipe.idx;
    let mut produced = 0usize;
    let produce = move |sb: &mut StepBatch| -> bool {
        if produced >= planned {
            return false;
        }
        let start = produced * b;
        // Fixed-shape batches: wrap around the shard for the tail.
        idx.clear();
        for i in 0..b {
            idx.push(order[(start + i) % order.len()]);
        }
        dataset.gather_cached(Split::Train, idx, &mut sb.buf, cache);
        sb.start = start;
        produced += 1;
        true
    };
    let consume = |sb: &mut StepBatch| -> Result<()> {
        epoch_step(rt, sb, order.len(), b, lr, &mut adam, params, scratch, &mut sums)
    };
    pipe.bufs = pipeline(bufs, produce, consume)?;
    Ok(sums)
}

/// Run local training for one agent; returns its parameter delta (Eq. 1)
/// and per-epoch metrics (the Fig 9 series).
///
/// The steady-state compute path allocates nothing: batches
/// double-buffer through a reused [`EpochPipe`], steps run on a reused
/// [`StepScratch`], and the final delta is computed in place in the
/// params buffer (per-epoch pipeline plumbing is the only remaining
/// cost — see [`train_epoch`]). Batch synthesis overlaps the train
/// step and flows through this worker's [`SynthCache`], so epochs
/// after the first — and later rounds that land the agent on a warm
/// worker — gather by memcpy.
pub fn run_local(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    job: &LocalJob,
) -> Result<(Update, AgentRecord)> {
    let t0 = Instant::now();
    let mut params: Vec<f32> = (*job.global).clone();
    let mut adam = (rt.optimizer() == "adam").then(|| AdamState::zeros(params.len()));
    let mut scratch = rt.new_scratch();
    let mut pipe = EpochPipe::new();

    let mut epoch_losses = Vec::with_capacity(job.local_epochs);
    let mut epoch_accs = Vec::with_capacity(job.local_epochs);
    let mut order = job.shard.to_order();
    let mut rng = Rng::new(job.seed)
        .split(job.round as u64)
        .split(job.agent_id as u64);

    SYNTH_CACHE.with(|c| -> Result<()> {
        let cache = &mut *c.borrow_mut();
        for _epoch in 0..job.local_epochs {
            rng.shuffle(&mut order);
            let (loss_sum, hit_sum, seen) = train_epoch(
                rt,
                dataset,
                &order,
                job.lr,
                job.max_steps_per_epoch,
                adam.as_mut(),
                &mut params,
                &mut scratch,
                &mut pipe,
                cache,
            )?;
            if seen > 0 {
                epoch_losses.push(loss_sum / seen as f64);
                epoch_accs.push(hit_sum / seen as f64);
            }
        }
        Ok(())
    })?;

    // delta_i = W_i^{t+1} - W^t (Eq. 1), computed in place: the params
    // buffer becomes the delta instead of allocating a second P-vector.
    let mut delta = params;
    for (d, g) in delta.iter_mut().zip(job.global.iter()) {
        *d -= *g;
    }

    let record = AgentRecord {
        round: job.round,
        agent_id: job.agent_id,
        epoch_losses,
        epoch_accs,
        num_samples: job.shard.len(),
        secs: t0.elapsed().as_secs_f64(),
    };
    Ok((
        Update {
            agent_id: job.agent_id,
            delta,
            num_samples: job.shard.len(),
        },
        record,
    ))
}

/// Run several sampled agents' local rounds **in lockstep** through the
/// fused multi-batch step path
/// ([`ModelExecutor::train_step_sgd_fused`]), on the calling thread: at
/// every step the cohort's batches go through one fused panel-parallel
/// GEMM per layer, instead of each agent contending for cores from its
/// own pool worker. Per-agent semantics — RNG streams, batch schedule,
/// wrapped-tail distinct-example weighting, the arithmetic itself — are
/// identical to [`run_local`], so a fused round reproduces the pooled
/// round's updates (the native fused step is bit-identical per slot;
/// ≤1e-5 is the cross-backend contract). Agents whose epochs run out of
/// batches before the cohort's longest sit out the remaining fused
/// steps. Batches gather synchronously through this thread's
/// [`SynthCache`] (steady state is memcpy-fed), so the per-agent
/// synthesis pipeline thread is not spun up here.
///
/// All jobs must carry the same `lr`, `local_epochs`, and
/// `max_steps_per_epoch` (the entrypoint builds them that way).
pub fn run_local_fused(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    jobs: &[LocalJob],
) -> Result<Vec<(Update, AgentRecord)>> {
    if jobs.is_empty() {
        return Ok(Vec::new());
    }
    if rt.optimizer() != "sgd" {
        bail!(
            "fused lockstep training is SGD-only, but the executor was built for {:?}",
            rt.optimizer()
        );
    }
    let t0 = Instant::now();
    let b = rt.train_batch_size();
    let lr = jobs[0].lr;
    let local_epochs = jobs[0].local_epochs;
    let max_steps = jobs[0].max_steps_per_epoch;
    for j in jobs {
        if j.lr != lr || j.local_epochs != local_epochs || j.max_steps_per_epoch != max_steps {
            bail!("fused cohort requires uniform lr/local_epochs/max_steps across agents");
        }
    }
    let s_count = jobs.len();
    let mut params: Vec<Vec<f32>> = jobs.iter().map(|j| (*j.global).clone()).collect();
    let mut orders: Vec<Vec<usize>> = jobs.iter().map(|j| j.shard.to_order()).collect();
    let mut rngs: Vec<Rng> = jobs
        .iter()
        .map(|j| Rng::new(j.seed).split(j.round as u64).split(j.agent_id as u64))
        .collect();
    let mut bufs: Vec<BatchBuf> = (0..s_count).map(|_| BatchBuf::new()).collect();
    let mut idx: Vec<usize> = Vec::with_capacity(b);
    let mut scratch = rt.new_scratch();
    let mut stats: Vec<StepStats> = Vec::with_capacity(s_count);
    let mut epoch_losses: Vec<Vec<f64>> =
        (0..s_count).map(|_| Vec::with_capacity(local_epochs)).collect();
    let mut epoch_accs: Vec<Vec<f64>> =
        (0..s_count).map(|_| Vec::with_capacity(local_epochs)).collect();

    SYNTH_CACHE.with(|c| -> Result<()> {
        let cache = &mut *c.borrow_mut();
        for _epoch in 0..local_epochs {
            let mut sums = vec![(0.0f64, 0.0f64, 0usize); s_count];
            let mut planned = vec![0usize; s_count];
            for s in 0..s_count {
                rngs[s].shuffle(&mut orders[s]);
                let total = orders[s].len().div_ceil(b);
                planned[s] = if max_steps > 0 { total.min(max_steps) } else { total };
            }
            let steps = planned.iter().copied().max().unwrap_or(0);
            for step in 0..steps {
                let start = step * b;
                for s in 0..s_count {
                    if step >= planned[s] {
                        continue;
                    }
                    // Fixed-shape batches, tail wrapped around the
                    // shard — exactly train_epoch's schedule.
                    idx.clear();
                    for i in 0..b {
                        idx.push(orders[s][(start + i) % orders[s].len()]);
                    }
                    dataset.gather_cached(Split::Train, &idx, &mut bufs[s], cache);
                }
                let mut slots: Vec<FusedSlot> = Vec::with_capacity(s_count);
                let mut active: Vec<usize> = Vec::with_capacity(s_count);
                for (s, p) in params.iter_mut().enumerate() {
                    if step >= planned[s] {
                        continue;
                    }
                    let view = bufs[s].view();
                    slots.push(FusedSlot { params: p, x: view.x, y: view.y });
                    active.push(s);
                }
                rt.train_step_sgd_fused(&mut slots, lr, &mut scratch, &mut stats)?;
                drop(slots);
                for (i, &s) in active.iter().enumerate() {
                    let distinct = b.min(orders[s].len() - start);
                    sums[s].0 += stats[i].loss as f64 * distinct as f64;
                    sums[s].1 += stats[i].hits as f64 * distinct as f64 / b as f64;
                    sums[s].2 += distinct;
                }
            }
            for s in 0..s_count {
                if sums[s].2 > 0 {
                    epoch_losses[s].push(sums[s].0 / sums[s].2 as f64);
                    epoch_accs[s].push(sums[s].1 / sums[s].2 as f64);
                }
            }
        }
        Ok(())
    })?;

    let secs = t0.elapsed().as_secs_f64();
    let mut out = Vec::with_capacity(s_count);
    for (s, job) in jobs.iter().enumerate() {
        // delta_i = W_i^{t+1} - W^t, in place like run_local.
        let mut delta = std::mem::take(&mut params[s]);
        for (d, g) in delta.iter_mut().zip(job.global.iter()) {
            *d -= *g;
        }
        let record = AgentRecord {
            round: job.round,
            agent_id: job.agent_id,
            epoch_losses: std::mem::take(&mut epoch_losses[s]),
            epoch_accs: std::mem::take(&mut epoch_accs[s]),
            num_samples: job.shard.len(),
            // One cohort, one wall clock: every agent trained inside
            // the same fused lockstep window.
            secs,
        };
        out.push((
            Update {
                agent_id: job.agent_id,
                delta,
                num_samples: job.shard.len(),
            },
            record,
        ));
    }
    Ok(out)
}

/// Evaluate a contiguous test-index range `[lo, hi)` in eval-batch
/// chunks on this thread's executor, with reused scratch/batch buffers.
/// Test batches gather through the worker's [`SynthCache`]: every round
/// evaluates the same split, so steady-state eval is memcpy-fed.
fn eval_range(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    params: &[f32],
    lo: usize,
    hi: usize,
) -> Result<crate::runtime::EvalStats> {
    let eb = rt.eval_batch_size();
    let mut scratch = rt.new_scratch();
    let mut buf = BatchBuf::new();
    let mut idx: Vec<usize> = Vec::with_capacity(eb);
    let mut total = crate::runtime::EvalStats::default();
    let mut start = lo;
    SYNTH_CACHE.with(|c| -> Result<()> {
        let cache = &mut *c.borrow_mut();
        while start < hi {
            let end = (start + eb).min(hi);
            idx.clear();
            idx.extend(start..end);
            let batch = dataset.gather_cached(Split::Test, &idx, &mut buf, cache);
            let s = rt.eval_batch(params, batch.x, batch.y, end - start, &mut scratch)?;
            total.merge(&s);
            start = end;
        }
        Ok(())
    })?;
    Ok(total)
}

/// Evaluate `params` over the full test split on the calling thread.
pub fn evaluate<'a>(
    rt: &'a dyn ModelExecutor,
    dataset: &'a Dataset,
) -> impl Fn(&[f32]) -> Result<crate::runtime::EvalStats> + 'a {
    move |params: &[f32]| eval_range(rt, dataset, params, 0, dataset.num_test())
}

/// Evaluate `params` over the test split (or its first `limit` samples
/// when `limit > 0`), sharding eval batches across `pool`.
///
/// Each shard is a contiguous, batch-aligned index range evaluated on a
/// pool worker's own executor (thread-local cache), so round evaluation
/// scales with the pool instead of serialising on the leader. Results
/// are summed in shard order — identical batching to the serial path.
pub fn evaluate_sharded(
    manifest: &Arc<Manifest>,
    key: &RuntimeKey,
    dataset: &Arc<Dataset>,
    pool: &WorkerPool,
    params: &[f32],
    limit: usize,
) -> Result<crate::runtime::EvalStats> {
    let n = if limit == 0 {
        dataset.num_test()
    } else {
        limit.min(dataset.num_test())
    };
    let eb = manifest.eval_batch.max(1);
    let batches = n.div_ceil(eb);
    let shards = pool.size().min(batches);
    if shards <= 1 {
        return with_runtime(manifest, key, |rt| eval_range(rt, dataset, params, 0, n));
    }
    let per = batches.div_ceil(shards);
    let params = Arc::new(params.to_vec());
    let jobs: Vec<_> = (0..shards)
        .map(|s| {
            let lo = (s * per * eb).min(n);
            let hi = ((s + 1) * per * eb).min(n);
            let manifest = Arc::clone(manifest);
            let key = key.clone();
            let dataset = Arc::clone(dataset);
            let params = Arc::clone(&params);
            move |_wid: usize| -> Result<crate::runtime::EvalStats> {
                with_runtime(&manifest, &key, |rt| eval_range(rt, &dataset, &params, lo, hi))
            }
        })
        .collect();
    let mut total = crate::runtime::EvalStats::default();
    for res in pool.run(jobs) {
        total.merge(&res?);
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_builds_and_caches() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let p1 = with_runtime(&m, &key, |rt| {
            assert_eq!(rt.backend(), BackendKind::Native);
            assert_eq!(rt.train_batch_size(), m.train_batch);
            rt.init_params()
        })
        .unwrap();
        // Second lookup hits the thread-local cache and agrees.
        let p2 = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn runtime_key_displays_all_fields() {
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        assert_eq!(format!("{key}"), "native:mlp-s@synth-mnist:sgd:full:");
    }

    #[test]
    fn native_rejects_ref_ablation_tag() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey {
            entry_tag: "_ref".into(),
            ..RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full")
        };
        let err = with_runtime(&m, &key, |_| Ok(())).unwrap_err();
        assert!(format!("{err}").contains("native"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_needs_feature() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey {
            backend: BackendKind::Pjrt,
            ..RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full")
        };
        let err = with_runtime(&m, &key, |_| Ok(())).unwrap_err();
        assert!(format!("{err}").contains("--features pjrt"), "{err}");
    }

    /// Sharded evaluation equals the serial path (same batching, summed
    /// in shard order) regardless of the pool size.
    #[test]
    fn sharded_eval_matches_serial() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Arc::new(Dataset::load(&m, "synth-mnist", 23).unwrap());
        let params = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        let serial = with_runtime(&m, &key, |rt| evaluate(rt, &dataset)(&params)).unwrap();
        for workers in [1usize, 3, 4] {
            let pool = WorkerPool::new(workers);
            let sharded =
                evaluate_sharded(&m, &key, &dataset, &pool, &params, 0).unwrap();
            assert_eq!(sharded.count, serial.count, "workers={workers}");
            assert_eq!(sharded.correct, serial.correct, "workers={workers}");
            assert!(
                (sharded.loss_sum - serial.loss_sum).abs() < 1e-6,
                "workers={workers}: {} vs {}",
                sharded.loss_sum,
                serial.loss_sum
            );
        }
    }

    /// The pipelined epoch (helper-thread synthesis, double-buffered)
    /// is bit-identical to a straightforward serial gather+step loop —
    /// same batches, same order, same arithmetic.
    #[test]
    fn pipelined_epoch_is_bit_identical_to_serial() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Dataset::load(&m, "synth-mnist", 37).unwrap();
        let order: Vec<usize> = (0..200).collect();
        with_runtime(&m, &key, |rt| {
            let b = rt.train_batch_size();
            let p0 = rt.init_params()?;

            // Pipelined path (200/32 => 7 steps, above the threshold).
            let mut p_pipe = p0.clone();
            let mut scratch = rt.new_scratch();
            let mut pipe = EpochPipe::new();
            let mut cache = SynthCache::new();
            let (loss_p, hits_p, seen_p) = train_epoch(
                rt,
                &dataset,
                &order,
                0.05,
                0,
                None,
                &mut p_pipe,
                &mut scratch,
                &mut pipe,
                &mut cache,
            )?;

            // Hand-rolled serial reference.
            let mut p_ser = p0.clone();
            let mut scratch = rt.new_scratch();
            let mut buf = BatchBuf::new();
            let mut idx = Vec::with_capacity(b);
            let (mut loss_s, mut hits_s, mut seen_s) = (0.0f64, 0.0f64, 0usize);
            let mut start = 0usize;
            while start < order.len() {
                idx.clear();
                for i in 0..b {
                    idx.push(order[(start + i) % order.len()]);
                }
                let batch = dataset.gather_into(Split::Train, &idx, &mut buf);
                let stats = rt.train_step_sgd(&mut p_ser, batch.x, batch.y, 0.05, &mut scratch)?;
                let distinct = b.min(order.len() - start);
                loss_s += stats.loss as f64 * distinct as f64;
                hits_s += stats.hits as f64 * distinct as f64 / b as f64;
                seen_s += distinct;
                start += b;
            }

            assert_eq!(p_pipe, p_ser, "pipelined params must be bit-identical");
            assert_eq!(loss_p, loss_s);
            assert_eq!(hits_p, hits_s);
            assert_eq!(seen_p, seen_s);

            // And a second epoch through the same (now warm) pipe +
            // cache still agrees.
            let mut scratch = rt.new_scratch();
            let (l2, _, s2) = train_epoch(
                rt,
                &dataset,
                &order,
                0.05,
                0,
                None,
                &mut p_pipe,
                &mut scratch,
                &mut pipe,
                &mut cache,
            )?;
            assert!(l2.is_finite() && s2 == seen_s);
            Ok(())
        })
        .unwrap();
    }

    /// A fused lockstep cohort produces bit-identical deltas and epoch
    /// metrics to running each agent through [`run_local`] — including
    /// ragged shards (different step counts per agent) and multiple
    /// local epochs.
    #[test]
    fn fused_cohort_matches_run_local_per_agent() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Dataset::load(&m, "synth-mnist", 43).unwrap();
        with_runtime(&m, &key, |rt| {
            let global = Arc::new(rt.init_params()?);
            let jobs: Vec<LocalJob> = [(0usize, 90usize), (1, 64), (2, 100)]
                .iter()
                .map(|&(aid, shard_len)| LocalJob {
                    agent_id: aid,
                    round: 2,
                    shard: (aid * 10..aid * 10 + shard_len).collect::<Vec<_>>().into(),
                    global: Arc::clone(&global),
                    lr: 0.05,
                    local_epochs: 2,
                    max_steps_per_epoch: 0,
                    seed: 7,
                })
                .collect();

            let serial: Vec<_> = jobs
                .iter()
                .map(|j| run_local(rt, &dataset, j))
                .collect::<Result<_, _>>()?;
            let fused = run_local_fused(rt, &dataset, &jobs)?;

            assert_eq!(fused.len(), serial.len());
            for ((fu, fr), (su, sr)) in fused.iter().zip(&serial) {
                assert_eq!(fu.agent_id, su.agent_id);
                assert_eq!(fu.num_samples, su.num_samples);
                assert_eq!(fu.delta, su.delta, "agent {}: delta", fu.agent_id);
                assert_eq!(fr.epoch_losses, sr.epoch_losses, "agent {}", fu.agent_id);
                assert_eq!(fr.epoch_accs, sr.epoch_accs, "agent {}", fu.agent_id);
            }
            Ok(())
        })
        .unwrap();
    }

    /// `max_steps` truncates the pipelined epoch exactly as it did the
    /// serial loop (including the short-epoch serial fallback).
    #[test]
    fn train_epoch_respects_max_steps() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Dataset::load(&m, "synth-mnist", 41).unwrap();
        let order: Vec<usize> = (0..300).collect();
        with_runtime(&m, &key, |rt| {
            let b = rt.train_batch_size();
            for max_steps in [1usize, 2, 4] {
                let mut params = rt.init_params()?;
                let mut scratch = rt.new_scratch();
                let mut pipe = EpochPipe::new();
                let mut cache = SynthCache::new();
                let (_, _, seen) = train_epoch(
                    rt,
                    &dataset,
                    &order,
                    0.05,
                    max_steps,
                    None,
                    &mut params,
                    &mut scratch,
                    &mut pipe,
                    &mut cache,
                )?;
                assert_eq!(seen, max_steps * b, "max_steps={max_steps}");
            }
            Ok(())
        })
        .unwrap();
    }

    /// `limit` caps the evaluated prefix, batch-aligned sharding intact.
    #[test]
    fn sharded_eval_respects_limit() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let dataset = Arc::new(Dataset::load(&m, "synth-mnist", 29).unwrap());
        let params = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        let pool = WorkerPool::new(2);
        let s = evaluate_sharded(&m, &key, &dataset, &pool, &params, 200).unwrap();
        assert_eq!(s.count, 200.0);
    }
}
