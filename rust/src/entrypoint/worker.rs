//! Worker-side local training (the simulated FL client).
//!
//! Each pool worker owns its own executor cache — PJRT executors wrap
//! `Rc`-based `xla` handles and must not cross threads, and the native
//! executors are cheap to build — so the runtime cache is thread-local,
//! keyed by (backend, artifact, optimizer, mode, tag). Sequential
//! experiments in one process reuse compilations.
//!
//! This module is the only place that knows which concrete backend
//! implements [`ModelExecutor`]; everything above it (entrypoint,
//! trainer, repro, benches) is backend-agnostic.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use crate::aggregators::Update;
use crate::datasets::{Dataset, Split};
use crate::metrics::AgentRecord;
use crate::runtime::{AdamState, BackendKind, Manifest, ModelExecutor, NativeExecutor};
use crate::util::error::{bail, Result};
use crate::util::Rng;

thread_local! {
    static RUNTIMES: RefCell<HashMap<String, Rc<dyn ModelExecutor>>> =
        RefCell::new(HashMap::new());
}

#[cfg(feature = "pjrt")]
thread_local! {
    static DEVICE: RefCell<Option<Rc<crate::runtime::Device>>> = const { RefCell::new(None) };
}

/// Identifies one (backend, model, dataset, optimizer, mode, tag) bundle.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RuntimeKey {
    pub backend: BackendKind,
    pub model: String,
    pub dataset: String,
    pub optimizer: String,
    pub mode: String,
    /// "" for Pallas-kernel artifacts, "_ref" for the pure-jnp ablation
    /// (PJRT only).
    pub entry_tag: String,
}

impl RuntimeKey {
    /// A native-backend key with the common defaults filled in.
    pub fn native(model: &str, dataset: &str, optimizer: &str, mode: &str) -> Self {
        Self {
            backend: BackendKind::Native,
            model: model.to_string(),
            dataset: dataset.to_string(),
            optimizer: optimizer.to_string(),
            mode: mode.to_string(),
            entry_tag: String::new(),
        }
    }

    fn cache_key(&self) -> String {
        format!(
            "{}:{}@{}:{}:{}:{}",
            self.backend, self.model, self.dataset, self.optimizer, self.mode, self.entry_tag
        )
    }
}

/// Get (or lazily build) this thread's executor for `key`.
pub fn with_runtime<T>(
    manifest: &Arc<Manifest>,
    key: &RuntimeKey,
    f: impl FnOnce(&dyn ModelExecutor) -> Result<T>,
) -> Result<T> {
    let rt = RUNTIMES.with(|r| -> Result<Rc<dyn ModelExecutor>> {
        let mut r = r.borrow_mut();
        if let Some(rt) = r.get(&key.cache_key()) {
            return Ok(Rc::clone(rt));
        }
        let rt = build_executor(manifest, key)?;
        r.insert(key.cache_key(), Rc::clone(&rt));
        Ok(rt)
    })?;
    f(&*rt)
}

fn build_executor(manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    match key.backend {
        BackendKind::Native => {
            if !key.entry_tag.is_empty() {
                bail!(
                    "entry tag {:?} is a PJRT artifact ablation; the native \
                     backend has no kernel/ref split",
                    key.entry_tag
                );
            }
            Ok(Rc::new(NativeExecutor::load(
                manifest,
                &key.model,
                &key.dataset,
                &key.optimizer,
                &key.mode,
            )?))
        }
        BackendKind::Pjrt => build_pjrt(manifest, key),
    }
}

#[cfg(feature = "pjrt")]
fn build_pjrt(manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    use crate::util::error::Context;

    let device = DEVICE.with(|d| -> Result<Rc<crate::runtime::Device>> {
        let mut d = d.borrow_mut();
        if d.is_none() {
            *d = Some(Rc::new(crate::runtime::Device::cpu()?));
        }
        Ok(Rc::clone(d.as_ref().unwrap()))
    })?;
    let art = manifest.artifact(&key.model, &key.dataset)?;
    let ds = manifest.dataset(&key.dataset)?;
    let rt = crate::runtime::PjrtRuntime::load(
        &device,
        manifest,
        art,
        ds,
        &key.optimizer,
        &key.mode,
        &key.entry_tag,
    )
    .with_context(|| format!("loading PJRT runtime for {}", key.cache_key()))?;
    Ok(Rc::new(rt))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt(_manifest: &Arc<Manifest>, key: &RuntimeKey) -> Result<Rc<dyn ModelExecutor>> {
    bail!(
        "backend 'pjrt' requested for {}@{} but this build has no PJRT \
         support — vendor the xla crate and add it under the `pjrt` \
         feature (see the instructions in rust/Cargo.toml), then \
         rebuild with `--features pjrt`; or use the default native \
         backend",
        key.model,
        key.dataset
    )
}

/// Everything a worker needs to run one agent's local round.
#[derive(Clone)]
pub struct LocalJob {
    pub agent_id: usize,
    pub round: usize,
    pub shard: Vec<usize>,
    pub global: Arc<Vec<f32>>,
    pub lr: f32,
    pub local_epochs: usize,
    /// 0 = unlimited (full shard per epoch).
    pub max_steps_per_epoch: usize,
    pub seed: u64,
}

/// Run local training for one agent; returns its parameter delta (Eq. 1)
/// and per-epoch metrics (the Fig 9 series).
pub fn run_local(
    rt: &dyn ModelExecutor,
    dataset: &Dataset,
    job: &LocalJob,
) -> Result<(Update, AgentRecord)> {
    let t0 = Instant::now();
    let b = rt.train_batch_size();
    let mut params: Vec<f32> = (*job.global).clone();
    let mut adam = (rt.optimizer() == "adam").then(|| AdamState::zeros(params.len()));

    let mut epoch_losses = Vec::with_capacity(job.local_epochs);
    let mut epoch_accs = Vec::with_capacity(job.local_epochs);
    let mut order = job.shard.clone();
    let mut rng = Rng::new(job.seed)
        .split(job.round as u64)
        .split(job.agent_id as u64);

    for _epoch in 0..job.local_epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f64;
        let mut hit_sum = 0.0f64;
        let mut seen = 0usize;
        let mut steps = 0usize;
        let mut start = 0usize;
        while start < order.len() {
            if job.max_steps_per_epoch > 0 && steps >= job.max_steps_per_epoch {
                break;
            }
            // Fixed-shape batches: wrap around the shard for the tail.
            let mut idx = Vec::with_capacity(b);
            for i in 0..b {
                idx.push(order[(start + i) % order.len()]);
            }
            let batch = dataset.batch(Split::Train, &idx);
            let stats = match adam.as_mut() {
                Some(state) => {
                    rt.train_step_adam(&mut params, state, &batch.x, &batch.y, job.lr)?
                }
                None => rt.train_step_sgd(&mut params, &batch.x, &batch.y, job.lr)?,
            };
            loss_sum += stats.loss as f64 * b as f64;
            hit_sum += stats.hits as f64;
            seen += b;
            steps += 1;
            start += b;
        }
        if seen > 0 {
            epoch_losses.push(loss_sum / seen as f64);
            epoch_accs.push(hit_sum / seen as f64);
        }
    }

    // delta_i = W_i^{t+1} - W^t (Eq. 1)
    let delta: Vec<f32> = params
        .iter()
        .zip(job.global.iter())
        .map(|(p, g)| p - g)
        .collect();

    let record = AgentRecord {
        round: job.round,
        agent_id: job.agent_id,
        epoch_losses,
        epoch_accs,
        num_samples: job.shard.len(),
        secs: t0.elapsed().as_secs_f64(),
    };
    Ok((
        Update {
            agent_id: job.agent_id,
            delta,
            num_samples: job.shard.len(),
        },
        record,
    ))
}

/// Evaluate `params` over the full test split (padding + masking the
/// final short batch inside the executor).
pub fn evaluate<'a>(
    rt: &'a dyn ModelExecutor,
    dataset: &'a Dataset,
) -> impl Fn(&[f32]) -> Result<crate::runtime::EvalStats> + 'a {
    move |params: &[f32]| {
        let mut total = crate::runtime::EvalStats::default();
        for (batch, n_valid) in dataset.test_batches(rt.eval_batch_size()) {
            let s = rt.eval_batch(params, &batch.x, &batch.y, n_valid)?;
            total.loss_sum += s.loss_sum;
            total.correct += s.correct;
            total.count += s.count;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_builds_and_caches() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full");
        let p1 = with_runtime(&m, &key, |rt| {
            assert_eq!(rt.backend(), BackendKind::Native);
            assert_eq!(rt.train_batch_size(), m.train_batch);
            rt.init_params()
        })
        .unwrap();
        // Second lookup hits the thread-local cache and agrees.
        let p2 = with_runtime(&m, &key, |rt| rt.init_params()).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn native_rejects_ref_ablation_tag() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey {
            entry_tag: "_ref".into(),
            ..RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full")
        };
        let err = with_runtime(&m, &key, |_| Ok(())).unwrap_err();
        assert!(format!("{err}").contains("native"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_needs_feature() {
        let m = Arc::new(Manifest::native());
        let key = RuntimeKey {
            backend: BackendKind::Pjrt,
            ..RuntimeKey::native("mlp-s", "synth-mnist", "sgd", "full")
        };
        let err = with_runtime(&m, &key, |_| Ok(())).unwrap_err();
        assert!(format!("{err}").contains("--features pjrt"), "{err}");
    }
}
