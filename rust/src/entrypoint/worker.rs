//! Worker-side local training (the simulated FL client).
//!
//! Each pool worker owns its own PJRT device + compiled executables (the
//! `xla` wrappers are `Rc`-based and must not cross threads) — the
//! simulated analogue of every client having its own accelerator. The
//! runtime cache is thread-local and keyed by (artifact, optimizer, mode,
//! tag), so sequential experiments in one process reuse compilations.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::aggregators::Update;
use crate::datasets::{Dataset, Split};
use crate::metrics::AgentRecord;
use crate::runtime::{AdamState, Device, Manifest, ModelRuntime};
use crate::util::Rng;

thread_local! {
    static DEVICE: RefCell<Option<Rc<Device>>> = const { RefCell::new(None) };
    static RUNTIMES: RefCell<HashMap<String, Rc<ModelRuntime>>> =
        RefCell::new(HashMap::new());
}

/// Identifies one compiled (model, dataset, optimizer, mode, tag) bundle.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RuntimeKey {
    pub model: String,
    pub dataset: String,
    pub optimizer: String,
    pub mode: String,
    /// "" for Pallas-kernel artifacts, "_ref" for the pure-jnp ablation.
    pub entry_tag: String,
}

impl RuntimeKey {
    fn cache_key(&self) -> String {
        format!(
            "{}@{}:{}:{}:{}",
            self.model, self.dataset, self.optimizer, self.mode, self.entry_tag
        )
    }
}

/// Get (or lazily build) this thread's runtime for `key`.
pub fn with_runtime<T>(
    manifest: &Arc<Manifest>,
    key: &RuntimeKey,
    f: impl FnOnce(&ModelRuntime) -> Result<T>,
) -> Result<T> {
    let device = DEVICE.with(|d| -> Result<Rc<Device>> {
        let mut d = d.borrow_mut();
        if d.is_none() {
            *d = Some(Rc::new(Device::cpu()?));
        }
        Ok(Rc::clone(d.as_ref().unwrap()))
    })?;
    let rt = RUNTIMES.with(|r| -> Result<Rc<ModelRuntime>> {
        let mut r = r.borrow_mut();
        if let Some(rt) = r.get(&key.cache_key()) {
            return Ok(Rc::clone(rt));
        }
        let art = manifest.artifact(&key.model, &key.dataset)?;
        let ds = manifest.dataset(&key.dataset)?;
        let rt = Rc::new(
            ModelRuntime::load(
                &device,
                manifest,
                art,
                ds,
                &key.optimizer,
                &key.mode,
                &key.entry_tag,
            )
            .with_context(|| format!("loading runtime for {}", key.cache_key()))?,
        );
        r.insert(key.cache_key(), Rc::clone(&rt));
        Ok(rt)
    })?;
    f(&rt)
}

/// Everything a worker needs to run one agent's local round.
#[derive(Clone)]
pub struct LocalJob {
    pub agent_id: usize,
    pub round: usize,
    pub shard: Vec<usize>,
    pub global: Arc<Vec<f32>>,
    pub lr: f32,
    pub local_epochs: usize,
    /// 0 = unlimited (full shard per epoch).
    pub max_steps_per_epoch: usize,
    pub seed: u64,
}

/// Run local training for one agent; returns its parameter delta (Eq. 1)
/// and per-epoch metrics (the Fig 9 series).
pub fn run_local(
    rt: &ModelRuntime,
    dataset: &Dataset,
    job: &LocalJob,
) -> Result<(Update, AgentRecord)> {
    let t0 = Instant::now();
    let b = rt.train_batch;
    let mut params: Vec<f32> = (*job.global).clone();
    let mut adam = (rt.optimizer == "adam").then(|| AdamState::zeros(params.len()));

    let mut epoch_losses = Vec::with_capacity(job.local_epochs);
    let mut epoch_accs = Vec::with_capacity(job.local_epochs);
    let mut order = job.shard.clone();
    let mut rng = Rng::new(job.seed)
        .split(job.round as u64)
        .split(job.agent_id as u64);

    for _epoch in 0..job.local_epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f64;
        let mut hit_sum = 0.0f64;
        let mut seen = 0usize;
        let mut steps = 0usize;
        let mut start = 0usize;
        while start < order.len() {
            if job.max_steps_per_epoch > 0 && steps >= job.max_steps_per_epoch {
                break;
            }
            // Fixed-shape batches: wrap around the shard for the tail.
            let mut idx = Vec::with_capacity(b);
            for i in 0..b {
                idx.push(order[(start + i) % order.len()]);
            }
            let batch = dataset.batch(Split::Train, &idx);
            let stats = match adam.as_mut() {
                Some(state) => {
                    rt.train_step_adam(&mut params, state, &batch.x, &batch.y, job.lr)?
                }
                None => rt.train_step_sgd(&mut params, &batch.x, &batch.y, job.lr)?,
            };
            loss_sum += stats.loss as f64 * b as f64;
            hit_sum += stats.hits as f64;
            seen += b;
            steps += 1;
            start += b;
        }
        if seen > 0 {
            epoch_losses.push(loss_sum / seen as f64);
            epoch_accs.push(hit_sum / seen as f64);
        }
    }

    // delta_i = W_i^{t+1} - W^t (Eq. 1)
    let delta: Vec<f32> = params
        .iter()
        .zip(job.global.iter())
        .map(|(p, g)| p - g)
        .collect();

    let record = AgentRecord {
        round: job.round,
        agent_id: job.agent_id,
        epoch_losses,
        epoch_accs,
        num_samples: job.shard.len(),
        secs: t0.elapsed().as_secs_f64(),
    };
    Ok((
        Update {
            agent_id: job.agent_id,
            delta,
            num_samples: job.shard.len(),
        },
        record,
    ))
}

/// Evaluate `params` over the full test split (padding + masking the
/// final short batch inside the graph).
pub fn evaluate<'a>(
    rt: &'a ModelRuntime,
    dataset: &'a Dataset,
) -> impl Fn(&[f32]) -> Result<crate::runtime::EvalStats> + 'a {
    move |params: &[f32]| {
        let mut total = crate::runtime::EvalStats::default();
        for (batch, n_valid) in dataset.test_batches(rt.eval_batch) {
            let s = rt.eval_batch(params, &batch.x, &batch.y, n_valid)?;
            total.loss_sum += s.loss_sum;
            total.correct += s.correct;
            total.count += s.count;
        }
        Ok(total)
    }
}
