//! The `Experiment` builder — the redesigned construction API.
//!
//! TorchFL's pitch is bootstrapping an FL experiment in a few lines;
//! the struct-literal `FlParams { 24 fields... }` plus a hand-loaded
//! manifest was not that. [`Experiment::builder`] gives the same
//! surface as typed setters over [`FlParams`] defaults, resolves the
//! execution environment from the chosen backend, and validates the
//! whole config in [`ExperimentBuilder::build`]:
//!
//! ```no_run
//! use ferrisfl::prelude::*;
//!
//! let mut exp = Experiment::builder()
//!     .name("quickstart")
//!     .model("mlp-s")
//!     .dataset("synth-mnist")
//!     .num_agents(10)
//!     .sampling_ratio(0.5)
//!     .rounds(5)
//!     .local_epochs(2)
//!     .split(Scheme::NonIid { niid_factor: 3 })
//!     .build()?;
//! let result = exp.run(&mut ConsoleLogger::default())?;
//! # Ok::<(), ferrisfl::util::error::Error>(())
//! ```
//!
//! The low-level path (`FlParams` literal + `Entrypoint::new`) remains
//! public for harnesses that need to sweep raw configs.

use std::sync::Arc;

use crate::agents::RegistryMode;
use crate::config::{FlParams, Mode, Optimizer, Topology};
use crate::engine::{AdversaryPlan, Backoff, ClockKind, FaultPlan, LatencyModel};
use crate::federation::Scheme;
use crate::loggers::Logger;
use crate::metrics::RoundRecord;
use crate::runtime::{BackendKind, EvalStats, Manifest};
use crate::util::error::Result;
use crate::util::Parallelism;

use super::{Entrypoint, RunResult};

/// A fully-constructed federated experiment, ready to run.
///
/// Thin wrapper over [`Entrypoint`] — built by [`ExperimentBuilder`],
/// which is the supported way to construct one.
pub struct Experiment {
    inner: Entrypoint,
}

impl Experiment {
    /// Start building an experiment from the default [`FlParams`].
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder { params: FlParams::default(), manifest: None, artifacts_dir: None }
    }

    /// Run the experiment through the round engine, emitting records
    /// into `logger`.
    pub fn run(&mut self, logger: &mut dyn Logger) -> Result<RunResult> {
        self.inner.run(logger)
    }

    /// The validated experiment config.
    pub fn params(&self) -> &FlParams {
        &self.inner.params
    }

    /// Number of agents in the registry (materialized or virtual).
    pub fn num_agents(&self) -> usize {
        self.inner.registry.len()
    }

    /// Current global model parameters.
    pub fn global_params(&self) -> &[f32] {
        self.inner.global_params()
    }

    /// Evaluate the current global model on the test split.
    pub fn evaluate(&self) -> Result<EvalStats> {
        self.inner.evaluate()
    }

    /// Convenience: the last round that evaluated, if any.
    pub fn last_eval_round(result: &RunResult) -> Option<&RoundRecord> {
        result.rounds.iter().rev().find(|r| !r.eval_loss.is_nan())
    }

    /// Escape hatch to the underlying [`Entrypoint`].
    pub fn entrypoint(&mut self) -> &mut Entrypoint {
        &mut self.inner
    }
}

/// Typed, chainable setters over [`FlParams`]; [`Self::build`] validates
/// and constructs the [`Experiment`].
pub struct ExperimentBuilder {
    params: FlParams,
    manifest: Option<Arc<Manifest>>,
    artifacts_dir: Option<String>,
}

impl ExperimentBuilder {
    /// Experiment name (log file prefix).
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.params.experiment_name = name.into();
        self
    }

    /// Zoo model variant.
    pub fn model(mut self, model: impl Into<String>) -> Self {
        self.params.model = model.into();
        self
    }

    /// Dataset registry entry.
    pub fn dataset(mut self, dataset: impl Into<String>) -> Self {
        self.params.dataset = dataset.into();
        self
    }

    /// Total number of agents K.
    pub fn num_agents(mut self, n: usize) -> Self {
        self.params.num_agents = n;
        self
    }

    /// Fraction of agents sampled per round, in `(0, 1]`.
    pub fn sampling_ratio(mut self, r: f64) -> Self {
        self.params.sampling_ratio = r;
        self
    }

    /// Global federation rounds T (`FlParams::global_epochs`).
    pub fn rounds(mut self, t: usize) -> Self {
        self.params.global_epochs = t;
        self
    }

    /// Local epochs per sampled agent per round.
    pub fn local_epochs(mut self, e: usize) -> Self {
        self.params.local_epochs = e;
        self
    }

    /// Data distribution across agents.
    pub fn split(mut self, split: Scheme) -> Self {
        self.params.split = split;
        self
    }

    /// Sampler registry name (`random`, `reputation`, ...).
    pub fn sampler(mut self, sampler: impl Into<String>) -> Self {
        self.params.sampler = sampler.into();
        self
    }

    /// Aggregator registry name (`fedavg`, `median`, `trim:0.25`, ...).
    pub fn aggregator(mut self, aggregator: impl Into<String>) -> Self {
        self.params.aggregator = aggregator.into();
        self
    }

    /// Local optimizer.
    pub fn optimizer(mut self, optimizer: Optimizer) -> Self {
        self.params.optimizer = optimizer;
        self
    }

    /// Training mode.
    pub fn mode(mut self, mode: Mode) -> Self {
        self.params.mode = mode;
        self
    }

    /// Start from pretrained weights (finetune / featext).
    pub fn use_pretrained(mut self, yes: bool) -> Self {
        self.params.use_pretrained = yes;
        self
    }

    /// Local learning rate.
    pub fn lr(mut self, lr: f32) -> Self {
        self.params.lr = lr;
        self
    }

    /// RNG seed for the whole experiment.
    pub fn seed(mut self, seed: u64) -> Self {
        self.params.seed = seed;
        self
    }

    /// Worker threads simulating parallel client devices (0 = auto).
    pub fn workers(mut self, n: usize) -> Self {
        self.params.workers = n;
        self
    }

    /// Typed alias for [`Self::workers`]: `Parallelism::Auto` defers to
    /// `FERRISFL_THREADS`, then hardware detection, per the crate's one
    /// precedence rule (explicit config > env > auto).
    pub fn parallelism(mut self, p: Parallelism) -> Self {
        self.params.workers = match p {
            Parallelism::Auto => 0,
            Parallelism::Fixed(n) => n,
        };
        self
    }

    /// Agent-registry mode: `auto` (default — eager below
    /// [`crate::agents::AUTO_VIRTUAL_THRESHOLD`] agents, virtual
    /// above), or force `materialized` / `virtual` (the bit-identical
    /// range-sharded pair; iid split only).
    pub fn registry(mut self, mode: RegistryMode) -> Self {
        self.params.registry = mode;
        self
    }

    /// Execution topology: `single` (default, in-process engine),
    /// `inproc:N` / `multiprocess:N` (leader + N workers over framed
    /// transports), or `tcp:<addr>` (externally started workers).
    pub fn topology(mut self, topology: Topology) -> Self {
        self.params.topology = topology;
        self
    }

    /// Straggler/reconnect timeout for distributed topologies, in wall
    /// seconds.
    pub fn transport_timeout_secs(mut self, secs: f64) -> Self {
        self.params.transport_timeout_secs = secs;
        self
    }

    /// Run each cohort as one fused lockstep step stream (SGD only).
    pub fn fuse(mut self, yes: bool) -> Self {
        self.params.fuse = yes;
        self
    }

    /// Evaluate the global model every N rounds (0 = only at the end).
    pub fn eval_every(mut self, n: usize) -> Self {
        self.params.eval_every = n;
        self
    }

    /// Cap per-agent local steps per epoch (0 = full shard).
    pub fn max_local_steps(mut self, n: usize) -> Self {
        self.params.max_local_steps = n;
        self
    }

    /// Directory for CSV/JSONL logs (empty = no file logs).
    pub fn log_dir(mut self, dir: impl Into<String>) -> Self {
        self.params.log_dir = dir.into();
        self
    }

    /// Per-round dropout probability of a sampled agent, in `[0, 1]`.
    pub fn dropout(mut self, p: f64) -> Self {
        self.params.dropout = p;
        self
    }

    /// Seeded fault-injection plan (crashes, delta loss/corruption,
    /// availability churn). Replays bit-identically from the seed.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.params.faults = plan;
        self
    }

    /// Seeded Byzantine adversary plan (sign-flip / scale / noise /
    /// colluding set). Poisoned deltas pass the integrity checks; pair
    /// with a robust aggregation rule. Replays bit-identically from
    /// the seed in every topology.
    pub fn adversary(mut self, plan: AdversaryPlan) -> Self {
        self.params.adversary = plan;
        self
    }

    /// Retry attempts per failed client delivery (0 = no retries).
    pub fn retry(mut self, max_retries: u32) -> Self {
        self.params.retry = max_retries;
        self
    }

    /// Exponential backoff schedule for retries.
    pub fn backoff(mut self, backoff: Backoff) -> Self {
        self.params.backoff = backoff;
        self
    }

    /// Minimum fraction of the planned cohort that must arrive, else
    /// the round skips without touching the model, in `[0, 1]`.
    pub fn quorum(mut self, frac: f64) -> Self {
        self.params.quorum = frac;
        self
    }

    /// Replace permanently-failed clients with fresh resampled ones.
    pub fn resample(mut self, yes: bool) -> Self {
        self.params.resample = yes;
        self
    }

    /// Server-side defense registry name (`none`, `normfilter:T`, ...).
    pub fn defense(mut self, defense: impl Into<String>) -> Self {
        self.params.defense = defense.into();
        self
    }

    /// Client update compression registry name (`none`, `topk:0.1`, ...).
    pub fn compression(mut self, compression: impl Into<String>) -> Self {
        self.params.compression = compression.into();
        self
    }

    /// Execution backend (default native; pjrt needs the cargo feature
    /// and an artifacts dir — see [`Self::artifacts_dir`]).
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.params.backend = backend;
        self
    }

    /// Per-client latency model for the round engine.
    pub fn latency(mut self, latency: LatencyModel) -> Self {
        self.params.latency = latency;
        self
    }

    /// Round collection window in simulated seconds (0 = none).
    pub fn deadline_secs(mut self, secs: f64) -> Self {
        self.params.deadline_secs = secs;
        self
    }

    /// Buffered-aggregation goal count (FedBuff's K; 0 = whole cohort).
    pub fn agg_goal(mut self, k: usize) -> Self {
        self.params.agg_goal = k;
        self
    }

    /// Staleness discount exponent for buffered updates.
    pub fn staleness_alpha(mut self, alpha: f64) -> Self {
        self.params.staleness_alpha = alpha;
        self
    }

    /// Engine clock: virtual (deterministic) or wall (measured).
    pub fn clock(mut self, clock: ClockKind) -> Self {
        self.params.clock = clock;
        self
    }

    /// Use an already-loaded execution manifest (overrides backend/
    /// artifacts resolution).
    pub fn manifest(mut self, manifest: Arc<Manifest>) -> Self {
        self.manifest = Some(manifest);
        self
    }

    /// Where to look for AOT artifacts when the backend needs them
    /// (default `artifacts`).
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Replace the accumulated params wholesale (escape hatch for
    /// sweeps that start from an existing config).
    pub fn params(mut self, params: FlParams) -> Self {
        self.params = params;
        self
    }

    /// Validate the config ([`FlParams::validate`]), resolve the
    /// execution environment, and construct the experiment.
    pub fn build(self) -> Result<Experiment> {
        self.params.validate()?;
        let manifest = match self.manifest {
            Some(m) => m,
            None => match self.params.backend {
                BackendKind::Native => Arc::new(Manifest::native()),
                BackendKind::Pjrt => {
                    Arc::new(Manifest::load(self.artifacts_dir.as_deref().unwrap_or("artifacts"))?)
                }
            },
        };
        Ok(Experiment { inner: Entrypoint::new(self.params, manifest)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loggers::NullLogger;

    #[test]
    fn builder_builds_and_runs_a_tiny_experiment() {
        let mut exp = Experiment::builder()
            .name("builder_smoke")
            .model("mlp-s")
            .dataset("synth-mnist")
            .num_agents(4)
            .sampling_ratio(1.0)
            .rounds(1)
            .local_epochs(1)
            .max_local_steps(2)
            .workers(1)
            .eval_every(0)
            .build()
            .unwrap();
        assert_eq!(exp.num_agents(), 4);
        assert_eq!(exp.params().experiment_name, "builder_smoke");
        let res = exp.run(&mut NullLogger).unwrap();
        assert_eq!(res.rounds.len(), 1);
        assert!(Experiment::last_eval_round(&res).is_none());
        assert!(!exp.global_params().is_empty());
    }

    #[test]
    fn build_runs_validate() {
        let err = Experiment::builder().sampling_ratio(0.0).build();
        assert!(err.is_err(), "invalid configs must fail at build()");
        let err = Experiment::builder().fuse(true).optimizer(Optimizer::Adam).build();
        assert!(err.is_err(), "fuse is SGD-only");
    }

    #[test]
    fn builder_sets_engine_knobs() {
        let b = Experiment::builder()
            .latency("constant:0.5".parse().unwrap())
            .deadline_secs(2.0)
            .agg_goal(3)
            .staleness_alpha(1.0)
            .clock(ClockKind::Virtual);
        assert_eq!(b.params.latency, LatencyModel::Constant(0.5));
        let pol = b.params.round_policy();
        assert!(pol.buffered());
        assert_eq!(pol.goal, Some(3));
    }

    #[test]
    fn builder_sets_topology_and_parallelism() {
        let b = Experiment::builder()
            .topology("inproc:3".parse().unwrap())
            .parallelism(Parallelism::Fixed(2))
            .transport_timeout_secs(5.0);
        assert_eq!(b.params.topology, Topology::InProc { workers: 3 });
        assert_eq!(b.params.workers, 2);
        assert_eq!(b.params.transport_timeout_secs, 5.0);
        let b = b.parallelism(Parallelism::Auto);
        assert_eq!(b.params.workers, 0);
        // Distributed topologies reject engine-only knobs at build().
        let err = Experiment::builder()
            .topology("multiprocess:2".parse().unwrap())
            .deadline_secs(2.0)
            .build();
        assert!(err.is_err(), "deadlines are single-process engine scheduling");
    }

    #[test]
    fn builder_sets_registry_mode_and_runs_virtual() {
        let b = Experiment::builder().registry(RegistryMode::Virtual);
        assert_eq!(b.params.registry, RegistryMode::Virtual);
        // A tiny forced-virtual experiment builds and runs: range
        // shards, sparse overlay, nothing materialized per agent.
        let mut exp = Experiment::builder()
            .name("virt_smoke")
            .model("mlp-s")
            .num_agents(4)
            .sampling_ratio(1.0)
            .rounds(1)
            .local_epochs(1)
            .max_local_steps(1)
            .workers(1)
            .eval_every(0)
            .registry(RegistryMode::Virtual)
            .build()
            .unwrap();
        assert_eq!(exp.num_agents(), 4);
        let res = exp.run(&mut NullLogger).unwrap();
        assert_eq!(res.rounds.len(), 1);
    }

    #[test]
    fn builder_sets_fault_knobs() {
        let b = Experiment::builder()
            .fault_plan("crash:0.2;drop:0.1".parse().unwrap())
            .adversary("adv:signflip:0.3".parse().unwrap())
            .retry(2)
            .backoff("0.5,2,0.25".parse().unwrap())
            .quorum(0.5)
            .resample(true);
        let pol = b.params.round_policy();
        assert!(pol.chaos_active());
        assert_eq!(pol.recovery.max_retries, 2);
        assert_eq!(pol.recovery.quorum, 0.5);
        assert!(pol.recovery.resample);
        assert_eq!(pol.recovery.backoff.to_string(), "0.5,2,0.25");
        assert_eq!(b.params.adversary.signflip, 0.3);
        assert!(!b.params.adversary.is_none());
    }
}
