//! Central (non-federated) trainer — paper §4.1.2 (Table 3, Fig 7).
//!
//! TorchFL trains models outside the FL loop through the Lightning
//! Trainer; this is the rust analogue used by the transfer-learning
//! experiments: train one model on the full train split for E epochs,
//! recording per-epoch wall-clock, validation loss and accuracy.
//! Backend-agnostic: runs on whichever executor `cfg.backend` selects.
//!
//! The trainer's single step stream is exactly the case the
//! panel-parallel GEMM drivers target: with one model training at a
//! time, each large layer's product (cnn-m's 3072-wide shapes) fans
//! its panels across the otherwise-idle cores automatically —
//! `FERRISFL_THREADS` caps it, no scheduling changes needed here.

use std::sync::Arc;
use std::time::Instant;

use crate::datasets::{Dataset, SynthCache};
use crate::runtime::{AdamState, BackendKind, Manifest};
use crate::util::error::Result;
use crate::util::{shared_pool, Rng};

use super::worker::{self, RuntimeKey};

/// Training mode for the transfer-learning experiments (Table 3 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Random init, all parameters trainable.
    Scratch,
    /// Pretrained init, all parameters trainable.
    Finetune,
    /// Pretrained init, only the classifier head trainable.
    FeatureExtract,
}

impl TrainMode {
    pub fn label(self) -> &'static str {
        match self {
            TrainMode::Scratch => "SCRATCH",
            TrainMode::Finetune => "FINETUNE",
            TrainMode::FeatureExtract => "FEATURE_EXTRACT",
        }
    }

    /// Executor entry mode this maps to ("full" trains everything).
    fn entry_mode(self) -> &'static str {
        match self {
            TrainMode::FeatureExtract => "featext",
            _ => "full",
        }
    }

    fn pretrained(self) -> bool {
        !matches!(self, TrainMode::Scratch)
    }
}

/// One epoch's record (a Fig 7 point).
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    pub val_loss: f64,
    pub val_acc: f64,
    pub secs: f64,
}

/// Result of a central training run (a Table 3 row + Fig 7 curve).
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub mode: TrainMode,
    pub epochs: Vec<EpochRecord>,
    /// Trainable parameter count (head only under feature extraction).
    pub trainable_params: usize,
    pub total_params: usize,
    pub mean_epoch_secs: f64,
}

impl TrainResult {
    pub fn non_trainable_params(&self) -> usize {
        self.total_params - self.trainable_params
    }
}

/// Configuration for a central run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub dataset: String,
    /// Execution backend ("native" | "pjrt").
    pub backend: String,
    pub mode: TrainMode,
    pub epochs: usize,
    pub lr: f32,
    pub optimizer: String,
    /// Samples per epoch (0 = the full train split).
    pub epoch_samples: usize,
    /// Test samples used for per-epoch validation (0 = full test split).
    /// Large interpret-mode conv models make full-test eval dominate the
    /// walltime of curve experiments; a fixed subset preserves the trend.
    pub eval_samples: usize,
    pub seed: u64,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "cnn-m".into(),
            dataset: "synth-cifar10".into(),
            backend: "native".into(),
            mode: TrainMode::Scratch,
            epochs: 10,
            lr: 0.05,
            optimizer: "sgd".into(),
            epoch_samples: 0,
            eval_samples: 0,
            seed: 42,
            verbose: false,
        }
    }
}

/// Train centrally; returns per-epoch metrics and parameter counts.
///
/// The epoch loop is a zero-allocation steady state (reused scratch
/// arena and epoch pipe) with batch synthesis double-buffered against
/// the train step and cached across epochs; per-epoch validation shards
/// test batches across the process-wide [`shared_pool`].
pub fn train(manifest: &Arc<Manifest>, cfg: &TrainConfig) -> Result<TrainResult> {
    let dataset = Arc::new(Dataset::load(manifest, &cfg.dataset, cfg.seed)?);
    let key = RuntimeKey {
        backend: BackendKind::parse(&cfg.backend)?,
        model: cfg.model.clone(),
        dataset: cfg.dataset.clone(),
        optimizer: cfg.optimizer.clone(),
        mode: cfg.mode.entry_mode().to_string(),
        entry_tag: String::new(),
    };

    let mut rng = Rng::new(cfg.seed ^ 0x7e41);
    let mut epochs = Vec::with_capacity(cfg.epochs);
    let mut trainable = 0usize;
    let mut total_params = 0usize;

    worker::with_runtime(manifest, &key, |rt| {
        let mut params = if cfg.mode.pretrained() {
            rt.pretrained_params()?
        } else {
            rt.init_params()?
        };
        total_params = rt.num_params();
        trainable = match cfg.mode {
            TrainMode::FeatureExtract => rt.head_size(),
            _ => rt.num_params(),
        };
        let n = if cfg.epoch_samples == 0 {
            dataset.num_train()
        } else {
            cfg.epoch_samples.min(dataset.num_train())
        };
        let mut adam = (cfg.optimizer == "adam").then(|| AdamState::zeros(params.len()));
        let mut order: Vec<usize> = (0..n).collect();
        let mut scratch = rt.new_scratch();
        let mut pipe = worker::EpochPipe::new();
        let mut cache = SynthCache::new();
        for epoch in 0..cfg.epochs {
            let t0 = Instant::now();
            rng.shuffle(&mut order);
            let (loss_sum, hits, seen) = worker::train_epoch(
                rt,
                &dataset,
                &order,
                cfg.lr,
                0,
                adam.as_mut(),
                &mut params,
                &mut scratch,
                &mut pipe,
                &mut cache,
            )?;
            let train_secs = t0.elapsed().as_secs_f64();
            let eval = {
                let pool = shared_pool().lock().expect("shared pool poisoned");
                worker::evaluate_sharded(
                    manifest,
                    &key,
                    &dataset,
                    &pool,
                    &params,
                    cfg.eval_samples,
                )?
            };
            let rec = EpochRecord {
                epoch,
                train_loss: loss_sum / seen.max(1) as f64,
                train_acc: hits / seen.max(1) as f64,
                val_loss: eval.mean_loss(),
                val_acc: eval.accuracy(),
                secs: train_secs,
            };
            if cfg.verbose {
                println!(
                    "  [{} epoch {:>2}] train loss {:.4} acc {:.3} | val loss {:.4} acc {:.3} | {:.1}s",
                    cfg.mode.label(),
                    epoch,
                    rec.train_loss,
                    rec.train_acc,
                    rec.val_loss,
                    rec.val_acc,
                    rec.secs
                );
            }
            epochs.push(rec);
        }
        Ok(())
    })?;

    let mean_epoch_secs = epochs.iter().map(|e| e.secs).sum::<f64>() / epochs.len().max(1) as f64;
    Ok(TrainResult {
        mode: cfg.mode,
        epochs,
        trainable_params: trainable,
        total_params,
        mean_epoch_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_labels_and_entries() {
        assert_eq!(TrainMode::Scratch.label(), "SCRATCH");
        assert_eq!(TrainMode::Scratch.entry_mode(), "full");
        assert_eq!(TrainMode::Finetune.entry_mode(), "full");
        assert_eq!(TrainMode::FeatureExtract.entry_mode(), "featext");
        assert!(!TrainMode::Scratch.pretrained());
        assert!(TrainMode::Finetune.pretrained());
    }

    #[test]
    fn non_trainable_math() {
        let r = TrainResult {
            mode: TrainMode::FeatureExtract,
            epochs: vec![],
            trainable_params: 100,
            total_params: 1000,
            mean_epoch_secs: 0.0,
        };
        assert_eq!(r.non_trainable_params(), 900);
    }

    #[test]
    fn native_central_training_runs() {
        let m = Arc::new(Manifest::native());
        let cfg = TrainConfig {
            model: "mlp-s".into(),
            dataset: "synth-mnist".into(),
            mode: TrainMode::Scratch,
            epochs: 1,
            epoch_samples: 64,
            eval_samples: 64,
            seed: 3,
            ..TrainConfig::default()
        };
        let res = train(&m, &cfg).unwrap();
        assert_eq!(res.epochs.len(), 1);
        assert_eq!(res.trainable_params, res.total_params);
        assert!(res.epochs[0].train_loss.is_finite());
    }
}
