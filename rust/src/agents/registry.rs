//! The agent registry: materialized or virtual client populations.
//!
//! Cross-device FL simulates populations of 10^6+ clients of which only
//! K participate per round. Materializing an [`Agent`] per client makes
//! *population* size, not *cohort* size, bound memory — so the registry
//! comes in two forms behind one accessor surface:
//!
//! - [`AgentRegistry::Materialized`] — the eager `Vec<Agent>` the
//!   coordinator always used. Supports every split scheme and is the
//!   bit-parity reference for the virtual form.
//! - [`AgentRegistry::Virtual`] — agents exist only as values derived
//!   from `(seed, agent_id)`: shard bounds are the closed-form
//!   [`shard_range`] over the virtual index space, and mutable state
//!   (reputation, counters, last loss) lives in a sparse overlay keyed
//!   by agent id, populated only for agents a round ever touched.
//!   Memory is O(touched) = O(K · rounds), independent of population.
//!
//! The latency / fault / adversary streams never lived in the registry:
//! they are already pure functions of `(seed, agent_id, round, attempt)`
//! (PR 6/7/9), so virtualization leaves their draws untouched.
//!
//! **Parity contract:** at equal `(seed, population)` the explicit
//! `materialized` and `virtual` modes produce bit-identical sampler
//! draws, shard contents, reputation trajectories, and final models
//! (pinned by `tests/registry_parity.rs`). `auto` keeps the legacy
//! scheme-partitioned path (which consumes construction-time RNG draws
//! the range modes deliberately avoid) for small populations, and
//! resolves to `virtual` above [`AUTO_VIRTUAL_THRESHOLD`].

use std::collections::BTreeMap;

use super::Agent;
use crate::federation::{shard_range, ShardSpec};
use crate::util::error::{bail, Error, Result};

/// Population size above which `registry = "auto"` stops materializing
/// agents and switches to the virtual registry. Below it, auto keeps
/// the legacy eager path bit-for-bit (existing configs see no change).
pub const AUTO_VIRTUAL_THRESHOLD: usize = 10_000;

/// The `[run] registry` knob: how the agent population is stored.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RegistryMode {
    /// Legacy materialized registry below [`AUTO_VIRTUAL_THRESHOLD`],
    /// virtual above (the default).
    #[default]
    Auto,
    /// Force the eager registry with closed-form range shards — the
    /// bit-parity reference for `virtual`. Requires an IID split.
    Materialized,
    /// Force the lazy registry: range shards + sparse overlay.
    /// Requires an IID split.
    Virtual,
}

impl RegistryMode {
    pub fn name(self) -> &'static str {
        match self {
            RegistryMode::Auto => "auto",
            RegistryMode::Materialized => "materialized",
            RegistryMode::Virtual => "virtual",
        }
    }

    /// Whether this mode, at this population, runs the legacy
    /// scheme-partitioned construction (`federation::shard`, which
    /// consumes seeded RNG draws and supports non-IID splits).
    pub fn uses_legacy_partition(self, num_agents: usize) -> bool {
        self == RegistryMode::Auto && num_agents <= AUTO_VIRTUAL_THRESHOLD
    }

    /// Whether this mode, at this population, resolves to the virtual
    /// registry.
    pub fn resolves_virtual(self, num_agents: usize) -> bool {
        match self {
            RegistryMode::Auto => num_agents > AUTO_VIRTUAL_THRESHOLD,
            RegistryMode::Materialized => false,
            RegistryMode::Virtual => true,
        }
    }
}

impl std::str::FromStr for RegistryMode {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(RegistryMode::Auto),
            "materialized" => Ok(RegistryMode::Materialized),
            "virtual" => Ok(RegistryMode::Virtual),
            other => bail!("unknown registry mode {other:?} (auto | materialized | virtual)"),
        }
    }
}

impl std::fmt::Display for RegistryMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Mutable per-agent state for virtual registries, created on first
/// touch with the exact defaults `Agent::new` uses.
#[derive(Clone, Debug)]
pub struct AgentOverlay {
    pub reputation: f64,
    pub times_sampled: usize,
    pub epochs_trained: usize,
    pub last_loss: f64,
}

impl Default for AgentOverlay {
    fn default() -> Self {
        Self {
            reputation: 0.5,
            times_sampled: 0,
            epochs_trained: 0,
            last_loss: f64::NAN,
        }
    }
}

/// The agent population, materialized or virtual (see module docs).
#[derive(Clone, Debug)]
pub enum AgentRegistry {
    /// Every agent eagerly constructed.
    Materialized { agents: Vec<Agent> },
    /// Agents derived on demand; only touched agents occupy memory.
    Virtual {
        num_agents: usize,
        /// Size of the virtual train index space (≥ the dataset's
        /// train split, so every agent owns at least one sample).
        total_train: usize,
        overlay: BTreeMap<usize, AgentOverlay>,
    },
}

impl AgentRegistry {
    /// Materialized registry from a scheme partition (the legacy path).
    pub fn from_partition(shards: Vec<Vec<usize>>) -> Self {
        AgentRegistry::Materialized { agents: super::from_partition(shards) }
    }

    /// Materialized registry from pre-built agents (tests, benches).
    pub fn from_agents(agents: Vec<Agent>) -> Self {
        AgentRegistry::Materialized { agents }
    }

    /// Materialized registry over closed-form range shards — the
    /// parity reference for [`AgentRegistry::virtualized`]: identical
    /// shard contents, built eagerly.
    pub fn materialized_range(num_agents: usize, total_train: usize) -> Self {
        let agents = (0..num_agents)
            .map(|id| {
                let (lo, hi) = shard_range(total_train, num_agents, id);
                Agent::new(id, (lo..hi).collect())
            })
            .collect();
        AgentRegistry::Materialized { agents }
    }

    /// Virtual registry: nothing allocated until an agent is touched.
    pub fn virtualized(num_agents: usize, total_train: usize) -> Self {
        AgentRegistry::Virtual { num_agents, total_train, overlay: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        match self {
            AgentRegistry::Materialized { agents } => agents.len(),
            AgentRegistry::Virtual { num_agents, .. } => *num_agents,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, AgentRegistry::Virtual { .. })
    }

    /// Agent `id`'s train shard. O(1) for virtual registries; cloned
    /// index list for materialized ones (cohort-bounded — only sampled
    /// agents are asked).
    pub fn shard(&self, id: usize) -> ShardSpec {
        match self {
            AgentRegistry::Materialized { agents } => {
                ShardSpec::Indices(agents[id].shard.clone())
            }
            AgentRegistry::Virtual { num_agents, total_train, .. } => {
                let (lo, hi) = shard_range(*total_train, *num_agents, id);
                ShardSpec::Range { lo, hi }
            }
        }
    }

    /// Agent `id`'s shard size (the sample-weighted stream weight).
    pub fn shard_len(&self, id: usize) -> usize {
        match self {
            AgentRegistry::Materialized { agents } => agents[id].shard.len(),
            AgentRegistry::Virtual { num_agents, total_train, .. } => {
                let (lo, hi) = shard_range(*total_train, *num_agents, id);
                hi - lo
            }
        }
    }

    /// Reputation in [0, 1]; 0.5 for never-touched agents.
    pub fn reputation(&self, id: usize) -> f64 {
        match self {
            AgentRegistry::Materialized { agents } => agents[id].reputation,
            AgentRegistry::Virtual { overlay, .. } => {
                overlay.get(&id).map_or(0.5, |o| o.reputation)
            }
        }
    }

    /// Most recent local loss; NaN for never-trained agents.
    pub fn last_loss(&self, id: usize) -> f64 {
        match self {
            AgentRegistry::Materialized { agents } => agents[id].last_loss,
            AgentRegistry::Virtual { overlay, .. } => {
                overlay.get(&id).map_or(f64::NAN, |o| o.last_loss)
            }
        }
    }

    pub fn times_sampled(&self, id: usize) -> usize {
        match self {
            AgentRegistry::Materialized { agents } => agents[id].times_sampled,
            AgentRegistry::Virtual { overlay, .. } => {
                overlay.get(&id).map_or(0, |o| o.times_sampled)
            }
        }
    }

    /// Record a completed local round — the same EWMA as
    /// [`Agent::record_round`], bit-for-bit (pinned by a unit test), so
    /// reputation trajectories agree across registry forms.
    pub fn record_round(&mut self, id: usize, loss: f64, epochs: usize) {
        match self {
            AgentRegistry::Materialized { agents } => agents[id].record_round(loss, epochs),
            AgentRegistry::Virtual { overlay, .. } => {
                let o = overlay.entry(id).or_default();
                let improved = o.last_loss.is_nan() || loss < o.last_loss;
                let target = if improved { 1.0 } else { 0.0 };
                o.reputation = 0.8 * o.reputation + 0.2 * target;
                o.last_loss = loss;
                o.times_sampled += 1;
                o.epochs_trained += epochs;
            }
        }
    }

    /// How many agents hold allocated mutable state — the memory-
    /// contract observable: for virtual registries this is the overlay
    /// population (≤ agents ever trained), never the population size.
    pub fn touched(&self) -> usize {
        match self {
            AgentRegistry::Materialized { agents } => agents.len(),
            AgentRegistry::Virtual { overlay, .. } => overlay.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_and_displays() {
        for (text, mode) in [
            ("auto", RegistryMode::Auto),
            ("materialized", RegistryMode::Materialized),
            ("Virtual", RegistryMode::Virtual),
        ] {
            assert_eq!(text.parse::<RegistryMode>().unwrap(), mode);
        }
        assert!("eager".parse::<RegistryMode>().is_err());
        assert_eq!(RegistryMode::Virtual.to_string(), "virtual");
    }

    #[test]
    fn auto_resolves_by_population() {
        assert!(RegistryMode::Auto.uses_legacy_partition(10));
        assert!(RegistryMode::Auto.uses_legacy_partition(AUTO_VIRTUAL_THRESHOLD));
        assert!(RegistryMode::Auto.resolves_virtual(AUTO_VIRTUAL_THRESHOLD + 1));
        assert!(!RegistryMode::Materialized.uses_legacy_partition(10));
        assert!(!RegistryMode::Materialized.resolves_virtual(1_000_000));
        assert!(RegistryMode::Virtual.resolves_virtual(2));
    }

    #[test]
    fn virtual_and_range_materialized_agree_on_reads() {
        for &(agents, total) in &[(4usize, 10usize), (64, 64), (7, 1024)] {
            let m = AgentRegistry::materialized_range(agents, total);
            let v = AgentRegistry::virtualized(agents, total);
            assert_eq!(m.len(), v.len());
            for id in 0..agents {
                assert_eq!(m.shard(id).to_order(), v.shard(id).to_order());
                assert_eq!(m.shard_len(id), v.shard_len(id));
                assert_eq!(m.reputation(id).to_bits(), v.reputation(id).to_bits());
                assert!(m.last_loss(id).is_nan() && v.last_loss(id).is_nan());
            }
        }
    }

    /// The overlay EWMA must be bit-identical to `Agent::record_round`
    /// (parity of reputation-dependent samplers rests on it).
    #[test]
    fn overlay_record_round_matches_agent_bitwise() {
        let mut m = AgentRegistry::from_agents(vec![Agent::new(0, vec![0, 1])]);
        let mut v = AgentRegistry::virtualized(1, 2);
        for &loss in &[1.0, 0.4, 0.9, 0.2, 0.2] {
            m.record_round(0, loss, 3);
            v.record_round(0, loss, 3);
            assert_eq!(m.reputation(0).to_bits(), v.reputation(0).to_bits());
            assert_eq!(m.last_loss(0).to_bits(), v.last_loss(0).to_bits());
            assert_eq!(m.times_sampled(0), v.times_sampled(0));
        }
    }

    #[test]
    fn overlay_is_sparse_in_touched_agents() {
        let mut r = AgentRegistry::virtualized(1_000_000, 1_000_000);
        assert_eq!(r.touched(), 0);
        for id in [3usize, 999_999, 500_000] {
            r.record_round(id, 0.5, 1);
        }
        r.record_round(3, 0.4, 1); // re-touch allocates nothing new
        assert_eq!(r.touched(), 3);
        assert_eq!(r.times_sampled(3), 2);
        // Untouched neighbours still read defaults.
        assert_eq!(r.reputation(4), 0.5);
        assert_eq!(r.shard_len(4), 1);
    }

    #[test]
    fn million_agent_shards_cover_the_index_space() {
        let n = 1_000_000usize;
        let r = AgentRegistry::virtualized(n, n);
        // Spot-check boundaries without iterating the population.
        assert_eq!(r.shard(0).to_order(), vec![0]);
        assert_eq!(r.shard(n - 1).to_order(), vec![n - 1]);
        assert_eq!(r.shard_len(n / 2), 1);
    }
}
