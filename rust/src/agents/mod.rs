//! Agents — the primary FL entity (paper §3.2.1).
//!
//! TorchFL decouples the agent from "an integer id" so research on
//! reputation-based sampling, incentive mechanisms, and poisoning
//! defenses can attach state to it. `Agent` mirrors that: a unique id, a
//! data shard, and extensible metadata (reputation, counters, arbitrary
//! key/value pairs) that samplers and aggregators read and update.

use std::collections::BTreeMap;

pub mod registry;

pub use registry::{AgentRegistry, RegistryMode, AUTO_VIRTUAL_THRESHOLD};

/// One federated client.
#[derive(Clone, Debug)]
pub struct Agent {
    /// Unique identifier within the experiment.
    pub id: usize,
    /// Indices into the dataset's train split owned by this agent.
    pub shard: Vec<usize>,
    /// Reputation score in [0, 1]; samplers may use it (paper cites
    /// reputation-based sampling as a motivating extension).
    pub reputation: f64,
    /// How many rounds this agent has been sampled into.
    pub times_sampled: usize,
    /// How many local epochs this agent has run in total.
    pub epochs_trained: usize,
    /// Most recent local training loss (NaN before first training).
    pub last_loss: f64,
    /// Free-form metadata for custom extensions.
    pub metadata: BTreeMap<String, f64>,
}

impl Agent {
    /// Create an agent with a data shard and default metadata.
    pub fn new(id: usize, shard: Vec<usize>) -> Self {
        Self {
            id,
            shard,
            reputation: 0.5,
            times_sampled: 0,
            epochs_trained: 0,
            last_loss: f64::NAN,
            metadata: BTreeMap::new(),
        }
    }

    /// Number of local samples.
    pub fn num_samples(&self) -> usize {
        self.shard.len()
    }

    /// Record the outcome of a local round; nudges reputation toward
    /// 1 when the local loss improved, toward 0 otherwise (simple EWMA —
    /// a stand-in for the richer mechanisms the paper cites).
    pub fn record_round(&mut self, loss: f64, epochs: usize) {
        let improved = self.last_loss.is_nan() || loss < self.last_loss;
        let target = if improved { 1.0 } else { 0.0 };
        self.reputation = 0.8 * self.reputation + 0.2 * target;
        self.last_loss = loss;
        self.times_sampled += 1;
        self.epochs_trained += epochs;
    }
}

/// Build one agent per shard of a partition.
pub fn from_partition(shards: Vec<Vec<usize>>) -> Vec<Agent> {
    shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| Agent::new(id, shard))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_agent_defaults() {
        let a = Agent::new(3, vec![1, 2, 3]);
        assert_eq!(a.id, 3);
        assert_eq!(a.num_samples(), 3);
        assert!((a.reputation - 0.5).abs() < 1e-12);
        assert_eq!(a.times_sampled, 0);
        assert!(a.last_loss.is_nan());
    }

    #[test]
    fn reputation_rises_on_improvement() {
        let mut a = Agent::new(0, vec![]);
        a.record_round(1.0, 2); // first round counts as improvement
        a.record_round(0.5, 2);
        a.record_round(0.3, 2);
        assert!(a.reputation > 0.5, "rep={}", a.reputation);
        assert_eq!(a.times_sampled, 3);
        assert_eq!(a.epochs_trained, 6);
    }

    #[test]
    fn reputation_falls_on_regression() {
        let mut a = Agent::new(0, vec![]);
        a.record_round(0.5, 1);
        for _ in 0..5 {
            a.record_round(2.0, 1);
            a.last_loss = 0.5; // keep regressing relative to a good loss
        }
        assert!(a.reputation < 0.5, "rep={}", a.reputation);
    }

    #[test]
    fn from_partition_assigns_sequential_ids() {
        let agents = from_partition(vec![vec![0, 1], vec![2], vec![]]);
        assert_eq!(agents.len(), 3);
        assert_eq!(agents[0].id, 0);
        assert_eq!(agents[2].id, 2);
        assert_eq!(agents[1].shard, vec![2]);
    }
}
