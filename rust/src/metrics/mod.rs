//! Metrics — round/epoch records and accumulators (paper §4.2.1, Fig 8–9).
//!
//! Structured records the entrypoint emits to the loggers: per-round
//! global metrics (Fig 8 series) and per-agent local metrics (Fig 9
//! series). Plain data + a tiny accumulator; serialisation lives in
//! `loggers`.

/// Global model metrics after one federation round (one Fig 8 point).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean local training loss over the sampled agents.
    pub train_loss: f64,
    /// Mean local training accuracy over the sampled agents.
    pub train_acc: f64,
    /// Global model eval loss (NaN if not evaluated this round).
    pub eval_loss: f64,
    /// Global model eval accuracy (NaN if not evaluated this round).
    pub eval_acc: f64,
    /// Ids of the sampled agents.
    pub sampled: Vec<usize>,
    /// Ids of sampled agents that dropped out of the round.
    pub dropped: Vec<usize>,
    /// Ids of agents whose updates the defense rejected.
    pub rejected: Vec<usize>,
    /// Wall-clock seconds for the round.
    pub secs: f64,
    /// Simulated seconds the round spanned on the engine's clock
    /// (0 under the degenerate zero-latency policy).
    pub sim_secs: f64,
}

/// One engine event, as surfaced to the loggers (the `engine` module's
/// per-event channel: JSONL `kind = "event"` lines, the
/// `<name>_events.csv` file).
#[derive(Clone, Debug, PartialEq)]
pub struct EventRecord {
    /// Seconds since the start of the run on the engine's clock —
    /// simulated (virtual clock) or measured (wall clock).
    pub time: f64,
    /// Event tag: `client_finished`, `delta_arrived`, `round_deadline`,
    /// or `eval_due`.
    pub kind: &'static str,
    /// The round the event was processed in.
    pub round: usize,
    /// Originating agent (client events only).
    pub agent_id: Option<usize>,
    /// For `delta_arrived`: rounds between dispatch and application
    /// (0 = fresh, >0 = buffered stale update).
    pub staleness: Option<u64>,
}

/// One agent's local-training metrics for one round (one Fig 9 point).
#[derive(Clone, Debug)]
pub struct AgentRecord {
    pub round: usize,
    pub agent_id: usize,
    /// Per-local-epoch mean training loss.
    pub epoch_losses: Vec<f64>,
    /// Per-local-epoch training accuracy.
    pub epoch_accs: Vec<f64>,
    pub num_samples: usize,
    pub secs: f64,
}

impl AgentRecord {
    pub fn final_loss(&self) -> f64 {
        self.epoch_losses.last().copied().unwrap_or(f64::NAN)
    }

    pub fn final_acc(&self) -> f64 {
        self.epoch_accs.last().copied().unwrap_or(f64::NAN)
    }
}

/// Streaming mean/min/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn add(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_tracks_stats() {
        let mut a = Accumulator::default();
        for v in [2.0, 4.0, 6.0] {
            a.add(v);
        }
        assert_eq!(a.n, 3);
        assert!((a.mean() - 4.0).abs() < 1e-12);
        assert_eq!(a.min, 2.0);
        assert_eq!(a.max, 6.0);
    }

    #[test]
    fn accumulator_ignores_nan() {
        let mut a = Accumulator::default();
        a.add(f64::NAN);
        a.add(1.0);
        assert_eq!(a.n, 1);
        assert_eq!(a.mean(), 1.0);
    }

    #[test]
    fn empty_accumulator_is_nan() {
        assert!(Accumulator::default().mean().is_nan());
    }

    #[test]
    fn agent_record_final_values() {
        let r = AgentRecord {
            round: 1,
            agent_id: 99,
            epoch_losses: vec![2.0, 1.5],
            epoch_accs: vec![0.3, 0.5],
            num_samples: 100,
            secs: 0.1,
        };
        assert_eq!(r.final_loss(), 1.5);
        assert_eq!(r.final_acc(), 0.5);
    }
}
