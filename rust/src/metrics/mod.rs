//! Metrics — round/epoch records and accumulators (paper §4.2.1, Fig 8–9).
//!
//! Structured records the entrypoint emits to the loggers: per-round
//! global metrics (Fig 8 series) and per-agent local metrics (Fig 9
//! series). Plain data + a tiny accumulator; serialisation lives in
//! `loggers`.

/// How a round ended: with an aggregate, or skipped with the global
/// model byte-unchanged.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoundOutcome {
    /// Arrived updates were aggregated into a new global model.
    #[default]
    Aggregated,
    /// The round was skipped; the global model is unchanged.
    Skipped(SkipReason),
}

/// Why a round was skipped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SkipReason {
    /// Every sampled client failed at dispatch (or none were sampled)
    /// and nothing was in flight.
    EmptyCohort,
    /// The round closed with no usable updates: zero arrivals before
    /// the deadline, every arrival corrupt, or the defense rejected
    /// everything.
    NoUpdates,
    /// Fewer arrivals than the recovery policy's quorum.
    Quorum,
}

impl RoundOutcome {
    /// Stable snake_case tag, used in round logs.
    pub fn name(self) -> &'static str {
        match self {
            RoundOutcome::Aggregated => "aggregated",
            RoundOutcome::Skipped(SkipReason::EmptyCohort) => "skipped_empty_cohort",
            RoundOutcome::Skipped(SkipReason::NoUpdates) => "skipped_no_updates",
            RoundOutcome::Skipped(SkipReason::Quorum) => "skipped_quorum",
        }
    }

    /// True for any [`RoundOutcome::Skipped`] variant.
    pub fn is_skipped(self) -> bool {
        matches!(self, RoundOutcome::Skipped(_))
    }
}

/// Per-round failure/recovery counters (all zero on a fault-free round).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Failed client attempts observed while this round was open
    /// (any reason: dropout, crash, lost delta, offline, corrupt).
    pub failures: u32,
    /// Retry attempts dispatched.
    pub retries: u32,
    /// Deltas rejected by the integrity checksum.
    pub corrupt_rejected: u32,
    /// Replacement clients resampled after permanent failures.
    pub replacements: u32,
}

/// Global model metrics after one federation round (one Fig 8 point).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean local training loss over the sampled agents.
    pub train_loss: f64,
    /// Mean local training accuracy over the sampled agents.
    pub train_acc: f64,
    /// Global model eval loss (NaN if not evaluated this round).
    pub eval_loss: f64,
    /// Global model eval accuracy (NaN if not evaluated this round).
    pub eval_acc: f64,
    /// Ids of the sampled agents.
    pub sampled: Vec<usize>,
    /// Ids of sampled agents that dropped out of the round.
    pub dropped: Vec<usize>,
    /// Ids of agents whose updates the defense rejected.
    pub rejected: Vec<usize>,
    /// Wall-clock seconds for the round.
    pub secs: f64,
    /// Simulated seconds the round spanned on the engine's clock
    /// (0 under the degenerate zero-latency policy).
    pub sim_secs: f64,
    /// Whether the round aggregated or was skipped (and why).
    pub outcome: RoundOutcome,
    /// Failure/recovery counters for the round.
    pub recovery: RecoveryStats,
    /// Updates the adversary plan perturbed this round (Byzantine
    /// clients drawn; 0 with `adversary = "none"`). A poisoned delta
    /// still passes the integrity checksum — this counter is the
    /// ground truth the robust rules are up against.
    pub adversarial: u32,
    /// Fraction of update mass the aggregation rule excluded
    /// (trim/median/reservoir rules; 0 for plain averaging and on
    /// skipped rounds).
    pub trimmed_frac: f64,
}

/// One engine event, as surfaced to the loggers (the `engine` module's
/// per-event channel: JSONL `kind = "event"` lines, the
/// `<name>_events.csv` file).
#[derive(Clone, Debug, PartialEq)]
pub struct EventRecord {
    /// Seconds since the start of the run on the engine's clock —
    /// simulated (virtual clock) or measured (wall clock).
    pub time: f64,
    /// Event tag: `client_finished`, `delta_arrived`, `round_deadline`,
    /// `eval_due`, `client_failed`, `retry_due`, `availability_changed`,
    /// or `delta_rejected`.
    pub kind: &'static str,
    /// The round the event was processed in.
    pub round: usize,
    /// Originating agent (client events only).
    pub agent_id: Option<usize>,
    /// For `delta_arrived`: rounds between dispatch and application
    /// (0 = fresh, >0 = buffered stale update).
    pub staleness: Option<u64>,
    /// For `client_failed`: why the attempt failed (`dropout`, `crash`,
    /// `delta_lost`, `offline`, `corrupt`).
    pub reason: Option<&'static str>,
    /// Which worker process/thread produced the event — `Some(i)` only
    /// in distributed topologies, where `i` indexes the leader's worker
    /// table; `None` for single-process runs and leader-side events.
    pub worker: Option<usize>,
}

/// One agent's local-training metrics for one round (one Fig 9 point).
#[derive(Clone, Debug, PartialEq)]
pub struct AgentRecord {
    pub round: usize,
    pub agent_id: usize,
    /// Per-local-epoch mean training loss.
    pub epoch_losses: Vec<f64>,
    /// Per-local-epoch training accuracy.
    pub epoch_accs: Vec<f64>,
    pub num_samples: usize,
    pub secs: f64,
}

impl AgentRecord {
    pub fn final_loss(&self) -> f64 {
        self.epoch_losses.last().copied().unwrap_or(f64::NAN)
    }

    pub fn final_acc(&self) -> f64 {
        self.epoch_accs.last().copied().unwrap_or(f64::NAN)
    }
}

/// Streaming mean/min/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    pub n: usize,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn add(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_tracks_stats() {
        let mut a = Accumulator::default();
        for v in [2.0, 4.0, 6.0] {
            a.add(v);
        }
        assert_eq!(a.n, 3);
        assert!((a.mean() - 4.0).abs() < 1e-12);
        assert_eq!(a.min, 2.0);
        assert_eq!(a.max, 6.0);
    }

    #[test]
    fn accumulator_ignores_nan() {
        let mut a = Accumulator::default();
        a.add(f64::NAN);
        a.add(1.0);
        assert_eq!(a.n, 1);
        assert_eq!(a.mean(), 1.0);
    }

    #[test]
    fn empty_accumulator_is_nan() {
        assert!(Accumulator::default().mean().is_nan());
    }

    #[test]
    fn agent_record_final_values() {
        let r = AgentRecord {
            round: 1,
            agent_id: 99,
            epoch_losses: vec![2.0, 1.5],
            epoch_accs: vec![0.3, 0.5],
            num_samples: 100,
            secs: 0.1,
        };
        assert_eq!(r.final_loss(), 1.5);
        assert_eq!(r.final_acc(), 0.5);
    }

    #[test]
    fn round_outcome_tags_and_default() {
        assert_eq!(RoundOutcome::default(), RoundOutcome::Aggregated);
        assert!(!RoundOutcome::Aggregated.is_skipped());
        for (o, tag) in [
            (RoundOutcome::Aggregated, "aggregated"),
            (RoundOutcome::Skipped(SkipReason::EmptyCohort), "skipped_empty_cohort"),
            (RoundOutcome::Skipped(SkipReason::NoUpdates), "skipped_no_updates"),
            (RoundOutcome::Skipped(SkipReason::Quorum), "skipped_quorum"),
        ] {
            assert_eq!(o.name(), tag);
            assert_eq!(o.is_skipped(), o != RoundOutcome::Aggregated);
        }
        assert_eq!(RecoveryStats::default(), RecoveryStats::default());
    }
}
