//! Multi-process federation: framed transports + leader/worker halves.
//!
//! The engine's streaming reduce already forced every delta through one
//! integer representation — 2^-40 fixed-point i64 terms folded into an
//! order-invariant lock-striped accumulator. This module puts exactly
//! that representation on the wire: workers quantize locally with
//! [`crate::aggregators::quantize_weighted`] and the leader folds the
//! received terms with `push_quantized`, so the wire format *is* the
//! in-memory contract and a multi-process round lands on bits identical
//! to the single-process engine under any arrival order.
//!
//! Three transports implement the same length-prefixed frame protocol
//! (see [`frame`]):
//!
//! | topology         | carrier                              |
//! |------------------|--------------------------------------|
//! | `inproc:N`       | in-process channels (worker threads) |
//! | `multiprocess:N` | Unix-domain sockets (spawned procs)  |
//! | `tcp:<addr>`     | TCP (externally started workers)     |
//!
//! Failure semantics are split in two at [`Transport::recv_timeout`]:
//! a frame whose *envelope* is broken (bad magic, insane length, EOF
//! mid-frame) is unrecoverable and surfaces as `Err`; a frame whose
//! envelope is intact but whose *content* fails the digest surfaces as
//! [`Received::Corrupt`], which the leader routes through the existing
//! `RecoveryPolicy` retry/backoff machinery as a `Resend`.

pub mod frame;
mod leader;
mod worker;

pub use frame::{Message, WIRE_VERSION};
pub(crate) use leader::run_distributed;
pub use worker::worker_main;

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::util::error::{bail, Context, Result};

/// Polling granularity for "wait on several peers at once" loops.
pub(crate) const POLL_SLICE: Duration = Duration::from_millis(25);

/// One successfully framed receive.
#[derive(Debug)]
pub enum Received {
    /// A decoded message plus its on-the-wire frame size in bytes
    /// (header + payload + digest), for communication accounting.
    Msg(Message, usize),
    /// The envelope was intact but the content failed the frame digest
    /// or payload decode — ask the sender to resend.
    Corrupt(String),
}

/// A reliable, ordered, framed byte channel to one peer.
///
/// Implementations deliver whole frames (as produced by
/// [`frame::encode_frame`]) in order. `recv_timeout` distinguishes
/// *idle* (`Ok(None)`: no frame started within the timeout) from
/// *broken* (`Err`: the peer hung up or committed to a frame and then
/// stalled or sent garbage framing) from *corrupt content*
/// (`Ok(Some(Received::Corrupt))`).
pub trait Transport: Send {
    /// Human-readable peer name for error messages and logs.
    fn peer(&self) -> &str;

    /// Send one already-encoded frame.
    fn send_raw(&mut self, bytes: &[u8]) -> Result<()>;

    /// Receive one frame, waiting at most `timeout` for it to start.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Received>>;

    /// Encode and send one message.
    fn send(&mut self, msg: &Message) -> Result<()> {
        let bytes = frame::encode_frame(msg)?;
        self.send_raw(&bytes)
    }
}

// ---------------------------------------------------------------------
// In-process channel transport
// ---------------------------------------------------------------------

/// Channel-backed transport: one side of an [`inproc_pair`]. Frames are
/// moved as owned byte vectors over `mpsc`, so the protocol (and its
/// digest check) is exercised end to end without any OS sockets.
pub struct InProc {
    peer: String,
    tx: mpsc::Sender<Vec<u8>>,
    rx: mpsc::Receiver<Vec<u8>>,
}

/// Build a connected (leader-side, worker-side) transport pair.
pub fn inproc_pair(leader_peer: &str, worker_peer: &str) -> (InProc, InProc) {
    let (to_worker, from_leader) = mpsc::channel();
    let (to_leader, from_worker) = mpsc::channel();
    let leader = InProc { peer: leader_peer.to_string(), tx: to_worker, rx: from_worker };
    let worker = InProc { peer: worker_peer.to_string(), tx: to_leader, rx: from_leader };
    (leader, worker)
}

impl Transport for InProc {
    fn peer(&self) -> &str {
        &self.peer
    }

    fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        if self.tx.send(bytes.to_vec()).is_err() {
            bail!("in-process peer {} hung up", self.peer);
        }
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Received>> {
        let bytes = match self.rx.recv_timeout(timeout) {
            Ok(b) => b,
            Err(mpsc::RecvTimeoutError::Timeout) => return Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                bail!("in-process peer {} hung up", self.peer)
            }
        };
        let n = bytes.len();
        match frame::decode_frame(&bytes)
            .with_context(|| format!("broken frame from {}", self.peer))?
        {
            Ok(msg) => Ok(Some(Received::Msg(msg, n))),
            Err(e) => Ok(Some(Received::Corrupt(e.to_string()))),
        }
    }
}

// ---------------------------------------------------------------------
// Socket transports (Unix-domain and TCP)
// ---------------------------------------------------------------------

/// The socket surface the framed transport needs: blocking reads and
/// writes plus a settable read timeout. Implemented for [`UnixStream`]
/// and [`TcpStream`]; both report an expired `SO_RCVTIMEO` as
/// `WouldBlock`/`TimedOut`, which [`SocketTransport`] maps to "idle"
/// only *before* the first header byte of a frame.
pub trait IoStream: Read + Write + Send {
    fn set_timeout(&self, timeout: Option<Duration>) -> io::Result<()>;
}

impl IoStream for UnixStream {
    fn set_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(timeout)
    }
}

impl IoStream for TcpStream {
    fn set_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(timeout)
    }
}

/// Framed transport over a connected byte stream.
pub struct SocketTransport<S: IoStream> {
    peer: String,
    stream: S,
}

impl<S: IoStream> SocketTransport<S> {
    pub fn new(peer: impl Into<String>, stream: S) -> Self {
        Self { peer: peer.into(), stream }
    }
}

fn is_idle(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

impl<S: IoStream> Transport for SocketTransport<S> {
    fn peer(&self) -> &str {
        &self.peer
    }

    fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream
            .write_all(bytes)
            .and_then(|_| self.stream.flush())
            .with_context(|| format!("sending to {}", self.peer))
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Received>> {
        self.stream
            .set_timeout(Some(timeout.max(Duration::from_millis(1))))
            .with_context(|| format!("setting read timeout on {}", self.peer))?;
        let mut header = [0u8; frame::HEADER_LEN];
        // The first byte decides idle vs. broken: nothing arriving
        // within the timeout is a quiet peer, not a protocol error.
        match self.stream.read(&mut header[..1]) {
            Ok(0) => bail!("connection to {} closed", self.peer),
            Ok(_) => {}
            Err(e) if is_idle(&e) => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("reading from {}", self.peer)),
        }
        // Past the first byte the sender has committed to a frame; a
        // timeout or EOF mid-frame means the stream can never re-sync,
        // so everything below is fatal (outer Err), never Corrupt.
        self.stream
            .read_exact(&mut header[1..])
            .with_context(|| format!("frame header truncated from {}", self.peer))?;
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        if magic != frame::MAGIC {
            bail!("bad frame magic {magic:#010x} from {}", self.peer);
        }
        let len = u32::from_le_bytes(header[5..9].try_into().unwrap()) as usize;
        if len > frame::MAX_PAYLOAD {
            bail!("frame payload of {len} bytes from {} exceeds the cap", self.peer);
        }
        let mut rest = vec![0u8; len + frame::DIGEST_LEN];
        self.stream
            .read_exact(&mut rest)
            .with_context(|| format!("frame body truncated from {}", self.peer))?;
        let mut buf = Vec::with_capacity(frame::HEADER_LEN + rest.len());
        buf.extend_from_slice(&header);
        buf.extend_from_slice(&rest);
        let n = buf.len();
        match frame::decode_frame(&buf)
            .with_context(|| format!("broken frame from {}", self.peer))?
        {
            Ok(msg) => Ok(Some(Received::Msg(msg, n))),
            Err(e) => Ok(Some(Received::Corrupt(e.to_string()))),
        }
    }
}

// ---------------------------------------------------------------------
// Connect / accept helpers
// ---------------------------------------------------------------------

/// Connect a worker to a leader at `uds:<path>` or `tcp:<host:port>`.
pub fn connect(addr: &str) -> Result<Box<dyn Transport>> {
    let addr = addr.trim();
    if let Some(path) = addr.strip_prefix("uds:") {
        let stream = UnixStream::connect(path)
            .with_context(|| format!("connecting to leader socket {path:?}"))?;
        Ok(Box::new(SocketTransport::new(format!("leader@{path}"), stream)))
    } else if let Some(tcp) = addr.strip_prefix("tcp:") {
        let stream = TcpStream::connect(tcp)
            .with_context(|| format!("connecting to leader at {tcp:?}"))?;
        stream.set_nodelay(true).ok();
        Ok(Box::new(SocketTransport::new(format!("leader@{tcp}"), stream)))
    } else {
        bail!("bad connect address {addr:?} (uds:<path> | tcp:<host:port>)");
    }
}

/// Accept with a deadline: both listeners poll non-blocking so a worker
/// that never comes up fails the run instead of hanging it.
fn accept_deadline<S>(
    mut accept: impl FnMut() -> io::Result<S>,
    deadline: Instant,
    what: &str,
) -> Result<S> {
    loop {
        match accept() {
            Ok(s) => return Ok(s),
            Err(e) if is_idle(&e) => {
                if Instant::now() >= deadline {
                    bail!("timed out waiting for {what} to connect");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).with_context(|| format!("accepting {what}")),
        }
    }
}

/// Accept one worker connection on a Unix-domain listener.
pub(crate) fn accept_uds(
    listener: &UnixListener,
    deadline: Instant,
    what: &str,
) -> Result<UnixStream> {
    listener.set_nonblocking(true).context("unix listener nonblocking")?;
    let s = accept_deadline(|| listener.accept().map(|(s, _)| s), deadline, what)?;
    s.set_nonblocking(false).context("unix stream blocking")?;
    Ok(s)
}

/// Accept one worker connection on a TCP listener.
pub(crate) fn accept_tcp(
    listener: &TcpListener,
    deadline: Instant,
    what: &str,
) -> Result<TcpStream> {
    listener.set_nonblocking(true).context("tcp listener nonblocking")?;
    let s = accept_deadline(|| listener.accept().map(|(s, _)| s), deadline, what)?;
    s.set_nonblocking(false).context("tcp stream blocking")?;
    s.set_nodelay(true).ok();
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use frame::corrupt_payload;

    fn hello() -> Message {
        Message::Hello { version: WIRE_VERSION }
    }

    #[test]
    fn inproc_round_trips_and_reports_idle() {
        let (mut leader, mut worker) = inproc_pair("worker-0", "leader");
        assert_eq!(leader.peer(), "worker-0");
        leader.send(&hello()).unwrap();
        match worker.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(Received::Msg(m, n)) => {
                assert_eq!(m, hello());
                assert!(n > frame::HEADER_LEN + frame::DIGEST_LEN);
            }
            other => panic!("expected Hello, got {other:?}"),
        }
        // Nothing pending: idle, not an error.
        assert!(worker.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
        // Dropping one side breaks the channel for good.
        drop(worker);
        assert!(leader.recv_timeout(Duration::from_millis(10)).is_err());
        assert!(leader.send(&hello()).is_err());
    }

    #[test]
    fn inproc_flags_payload_corruption_as_resendable() {
        let (mut leader, mut worker) = inproc_pair("w", "l");
        let mut bytes = frame::encode_frame(&Message::Resend { round: 3, agent_id: 7 }).unwrap();
        corrupt_payload(&mut bytes);
        leader.send_raw(&bytes).unwrap();
        match worker.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(Received::Corrupt(why)) => assert!(why.contains("digest"), "{why}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn uds_socket_transport_frames_idles_and_rejects_corruption() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ferrisfl-test-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path).unwrap();
        let client = UnixStream::connect(&path).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        let server = accept_uds(&listener, deadline, "test worker").unwrap();
        let _ = std::fs::remove_file(&path);

        let mut a = SocketTransport::new("b", client);
        let mut b = SocketTransport::new("a", server);

        // Idle before anything is sent.
        assert!(b.recv_timeout(Duration::from_millis(10)).unwrap().is_none());

        a.send(&hello()).unwrap();
        match b.recv_timeout(Duration::from_secs(5)).unwrap() {
            Some(Received::Msg(m, _)) => assert_eq!(m, hello()),
            other => panic!("expected Hello, got {other:?}"),
        }

        // Payload corruption: envelope fine, digest fails -> Corrupt.
        let mut bytes = frame::encode_frame(&Message::Resend { round: 1, agent_id: 2 }).unwrap();
        corrupt_payload(&mut bytes);
        a.send_raw(&bytes).unwrap();
        match b.recv_timeout(Duration::from_secs(5)).unwrap() {
            Some(Received::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }

        // Bad magic is fatal framing, not Corrupt.
        let mut bad = frame::encode_frame(&hello()).unwrap();
        bad[0] ^= 0xFF;
        a.send_raw(&bad).unwrap();
        assert!(b.recv_timeout(Duration::from_secs(5)).is_err());
    }
}
