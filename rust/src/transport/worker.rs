//! Worker half of the multi-process federation protocol.
//!
//! A worker is a full [`Entrypoint`] rebuilt from the leader's wired
//! config (`FlParams::to_wire_toml`): dataset synthesis, sharding, and
//! local-training RNG streams are all pure functions of that config, so
//! the worker's shard table is bit-identical to the leader's without
//! shipping any data. Each `Assign` trains its agents in order with the
//! exact single-process `run_local` path, quantizes the delta to the
//! streaming reduce's weighted 2^-40 fixed-point terms, and pushes one
//! framed `Delta` back per agent.
//!
//! Clean frames for the current round are cached, so a `Resend` (after
//! the leader rejects a corrupt frame or times out) replays the cached
//! bytes instead of retraining — retries cost wire time, not compute.
//!
//! Fault injection: `FERRISFL_WIRE_CHAOS=N` corrupts one payload byte
//! of this worker's first `N` *initial* delta sends (resends are always
//! clean), which exercises the leader's digest-reject → `Resend` path
//! end to end while leaving the final model untouched.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::aggregators::{quantize_weighted, quantized_checksum};
use crate::config::FlParams;
use crate::entrypoint::worker::{run_local, with_runtime, LocalJob};
use crate::entrypoint::Entrypoint;
use crate::runtime::Manifest;
use crate::transport::frame::{self, Message};
use crate::transport::{connect, Received, Transport, WIRE_VERSION};
use crate::util::env;
use crate::util::error::{bail, Context, Result};

/// How long one blocking wait on the command channel lasts before
/// looping; workers idle through these slices while the leader
/// aggregates and evaluates between rounds.
const IDLE_SLICE: Duration = Duration::from_millis(200);

/// Entry point for the `ferrisfl worker` subcommand: connect to the
/// leader at `uds:<path>` or `tcp:<host:port>` and serve rounds until
/// `Shutdown`.
pub fn worker_main(addr: &str) -> Result<()> {
    serve(connect(addr)?)
}

/// Serve the leader on an established transport. On error, a best-
/// effort `WorkerError` frame tells the leader why before returning.
pub(crate) fn serve(mut t: Box<dyn Transport>) -> Result<()> {
    let res = serve_inner(&mut *t);
    if let Err(e) = &res {
        let _ = t.send(&Message::WorkerError { message: e.to_string() });
    }
    res
}

fn serve_inner(t: &mut dyn Transport) -> Result<()> {
    t.send(&Message::Hello { version: WIRE_VERSION })?;
    let config = match recv_command(t)? {
        Message::Init { config } => config,
        other => bail!("expected Init from the leader, got {}", other.kind_name()),
    };
    let params = FlParams::from_toml(&config).context("worker rejected the wire config")?;
    let ep = Entrypoint::new(params, Arc::new(Manifest::native()))
        .context("worker failed to build its experiment")?;

    // Injected corruption budget (tests): corrupt the first N initial
    // delta sends of this process, then behave.
    let mut chaos = env::wire_chaos();
    // Clean encoded frames for the current round, for Resend replays.
    let mut cache: HashMap<(u64, u32), Vec<u8>> = HashMap::new();
    let mut cached_round = u64::MAX;

    loop {
        match recv_command(t)? {
            Message::Assign { round, agents, global } => {
                if round != cached_round {
                    cache.clear();
                    cached_round = round;
                }
                let global = Arc::new(global);
                for (agent_id, weight) in agents {
                    let bytes = train_one(&ep, round, agent_id, weight, Arc::clone(&global))?;
                    cache.insert((round, agent_id), bytes.clone());
                    if chaos > 0 {
                        chaos -= 1;
                        let mut bad = bytes;
                        frame::corrupt_payload(&mut bad);
                        t.send_raw(&bad)?;
                    } else {
                        t.send_raw(&bytes)?;
                    }
                }
            }
            Message::Resend { round, agent_id } => {
                let Some(bytes) = cache.get(&(round, agent_id)) else {
                    bail!(
                        "leader asked to resend round {round} agent {agent_id}, \
                         which this worker never trained"
                    );
                };
                t.send_raw(bytes)?;
            }
            Message::Shutdown => return Ok(()),
            other => bail!("unexpected {} from the leader", other.kind_name()),
        }
    }
}

/// Train one assigned agent with the single-process local path and
/// encode its framed `Delta`. The quantisation is the same kernel the
/// in-memory accumulator applies, so the frame carries exactly the
/// terms a single-process round would have folded.
fn train_one(
    ep: &Entrypoint,
    round: u64,
    agent_id: u32,
    weight: u64,
    global: Arc<Vec<f32>>,
) -> Result<Vec<u8>> {
    let a = agent_id as usize;
    if a >= ep.registry.len() {
        bail!("assigned agent {agent_id} is out of range ({} agents)", ep.registry.len());
    }
    let job = LocalJob {
        agent_id: a,
        round: round as usize,
        // The wire never carries shards: the worker's registry resolves
        // the same agent→shard map from the wired config (num_agents,
        // registry mode, seed) the leader used.
        shard: ep.registry.shard(a),
        global,
        lr: ep.params.lr,
        local_epochs: ep.params.local_epochs,
        max_steps_per_epoch: ep.params.max_local_steps,
        seed: ep.params.seed,
    };
    let (mut update, record) =
        with_runtime(&ep.manifest, &ep.key, |rt| run_local(rt, &ep.dataset, &job))?;
    // Byzantine clients poison their own delta before quantize+frame:
    // the framed terms carry the attack, the digest is computed over
    // the poisoned bits (integrity, not honesty), and the draw is the
    // same pure function of (seed, agent, round) the single-process
    // paths use — so the attack replays bit-identically here.
    ep.params.adversary.perturb(ep.params.seed, agent_id as u64, round, &mut update.delta);
    let terms = quantize_weighted(&update.delta, weight)?;
    let digest = quantized_checksum(&terms);
    frame::encode_frame(&Message::Delta { round, agent_id, weight, digest, terms, record })
}

/// Block until the leader's next command. A corrupt *command* frame is
/// fatal for the worker — only deltas have a resend path.
fn recv_command(t: &mut dyn Transport) -> Result<Message> {
    loop {
        match t.recv_timeout(IDLE_SLICE)? {
            None => continue,
            Some(Received::Msg(msg, _)) => return Ok(msg),
            Some(Received::Corrupt(why)) => bail!("corrupt command frame from the leader: {why}"),
        }
    }
}
