//! Leader half of the multi-process federation protocol.
//!
//! `run_distributed` drives the same observable round loop as the
//! engine's degenerate policy — identical sampler/dropout RNG draws,
//! identical stream weights, identical cohort-order metric folds, and
//! the same exact integer reduce — but local training happens in
//! spawned workers that push framed, quantised deltas back over a
//! [`Transport`]. Because the wire carries the streaming accumulator's
//! own weighted fixed-point terms, the final model is bit-identical to
//! a single-process run at the same seed, under any arrival order.
//!
//! Failure handling reuses the recovery config: a frame rejected by the
//! digest (or a straggling worker hitting `transport.timeout_secs`)
//! counts a failure, sleeps `faults.backoff` (no jitter — wall-clock
//! retries, not simulated ones), and sends `Resend`; `faults.retry`
//! bounds attempts per worker per round, after which the run fails
//! rather than silently diverge from the single-process result.

use std::collections::VecDeque;
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::aggregators::{quantized_checksum, StreamKind, StreamingAccumulator};
use crate::config::Topology;
use crate::engine::Backoff;
use crate::entrypoint::{CommStats, Entrypoint, RunResult};
use crate::incentives::ContributionTracker;
use crate::loggers::Logger;
use crate::metrics::{
    Accumulator, AgentRecord, EventRecord, RecoveryStats, RoundOutcome, RoundRecord, SkipReason,
};
use crate::profiler::SimpleProfiler;
use crate::transport::frame::Message;
use crate::transport::{
    accept_tcp, accept_uds, inproc_pair, Received, SocketTransport, Transport, POLL_SLICE,
    WIRE_VERSION,
};
use crate::util::env;
use crate::util::error::{bail, Context, Result};

/// Distinguishes socket paths when one process runs several
/// distributed experiments (tests, benches).
static SOCKET_SALT: AtomicU64 = AtomicU64::new(0);

/// One spawned worker to reap at shutdown.
enum WorkerHandle {
    /// `inproc:N` — a thread running [`super::worker::serve`].
    Thread(JoinHandle<Result<()>>),
    /// `multiprocess:N` — a spawned `ferrisfl worker` child.
    Process(Child),
    /// `tcp:<addr>` — somebody else's process; nothing to reap.
    External,
}

/// The connected worker fleet. Dropping it kills any child processes
/// still alive (the error path); the happy path reaps via
/// [`Fleet::shutdown`] first, which leaves nothing for `Drop`.
struct Fleet {
    transports: Vec<Box<dyn Transport>>,
    handles: Vec<WorkerHandle>,
    socket_path: Option<PathBuf>,
}

impl Fleet {
    /// Send `Shutdown` everywhere, then join/reap every worker,
    /// surfacing worker-side errors.
    fn shutdown(&mut self) -> Result<()> {
        for t in self.transports.iter_mut() {
            t.send(&Message::Shutdown)?;
        }
        // Drop the leader-side channel ends so in-process workers that
        // miss the frame still observe a disconnect.
        self.transports.clear();
        for h in std::mem::take(&mut self.handles) {
            match h {
                WorkerHandle::Thread(j) => match j.join() {
                    Ok(res) => res.context("in-process worker failed")?,
                    Err(_) => bail!("in-process worker thread panicked"),
                },
                WorkerHandle::Process(mut c) => {
                    let status = c.wait().context("waiting for a worker process")?;
                    if !status.success() {
                        bail!("a worker process exited with {status}");
                    }
                }
                WorkerHandle::External => {}
            }
        }
        Ok(())
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for h in &mut self.handles {
            if let WorkerHandle::Process(c) = h {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
        if let Some(p) = &self.socket_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Run a distributed experiment: spawn/await the fleet, handshake,
/// drive the rounds, and shut the fleet down.
pub(crate) fn run_distributed(ep: &mut Entrypoint, logger: &mut dyn Logger) -> Result<RunResult> {
    let Some(stream_kind) = ep.stream_kind() else {
        bail!(
            "distributed topologies stream every delta, but aggregator {:?} (or an active \
             defense/compressor) needs the materialized cohort; run with topology = \"single\", \
             or use a sketch-based robust rule (sketch-median | sketch-trim | geomedian), \
             which streams",
            ep.params.aggregator
        );
    };
    let timeout = Duration::from_secs_f64(ep.params.transport_timeout_secs);
    let config = ep.params.to_wire_toml();
    let mut fleet = spawn_fleet(ep)?;
    handshake(&mut fleet, &config, timeout)?;
    let result = drive_rounds(ep, logger, &mut fleet, stream_kind, timeout)?;
    fleet.shutdown()?;
    Ok(result)
}

/// Bring up the worker fleet for the configured topology.
fn spawn_fleet(ep: &Entrypoint) -> Result<Fleet> {
    let timeout = Duration::from_secs_f64(ep.params.transport_timeout_secs);
    match &ep.params.topology {
        Topology::Single => bail!("run_distributed called with the single topology"),
        Topology::InProc { workers } => {
            let mut transports = Vec::new();
            let mut handles = Vec::new();
            for w in 0..*workers {
                let (leader_side, worker_side) = inproc_pair(&format!("worker-{w}"), "leader");
                let handle = std::thread::Builder::new()
                    .name(format!("ffl-worker-{w}"))
                    .spawn(move || super::worker::serve(Box::new(worker_side)))
                    .context("spawning an in-process worker thread")?;
                transports.push(Box::new(leader_side) as Box<dyn Transport>);
                handles.push(WorkerHandle::Thread(handle));
            }
            Ok(Fleet { transports, handles, socket_path: None })
        }
        Topology::MultiProcess { workers } => {
            let salt = SOCKET_SALT.fetch_add(1, Ordering::Relaxed);
            let path = std::env::temp_dir().join(format!(
                "ferrisfl-{}-{}-{salt}.sock",
                std::process::id(),
                ep.params.seed
            ));
            let _ = std::fs::remove_file(&path);
            let listener = UnixListener::bind(&path)
                .with_context(|| format!("binding leader socket {path:?}"))?;
            let bin = worker_binary()?;
            let addr = format!("uds:{}", path.display());
            let mut handles = Vec::new();
            for w in 0..*workers {
                let child = Command::new(&bin)
                    .args(["worker", "--connect", &addr])
                    .spawn()
                    .with_context(|| format!("spawning worker process {w} from {bin:?}"))?;
                handles.push(WorkerHandle::Process(child));
            }
            let deadline = Instant::now() + timeout;
            let mut transports = Vec::new();
            for w in 0..*workers {
                let stream = accept_uds(&listener, deadline, &format!("worker-{w}"))?;
                transports.push(
                    Box::new(SocketTransport::new(format!("worker-{w}"), stream))
                        as Box<dyn Transport>,
                );
            }
            Ok(Fleet { transports, handles, socket_path: Some(path) })
        }
        Topology::Tcp { addr, workers } => {
            let listener = TcpListener::bind(addr.as_str())
                .with_context(|| format!("binding leader address {addr:?}"))?;
            eprintln!(
                "ferrisfl: listening on tcp:{addr}; start {workers} worker(s) with \
                 `ferrisfl worker --connect tcp:{addr}`"
            );
            let deadline = Instant::now() + timeout;
            let mut transports = Vec::new();
            for w in 0..*workers {
                let stream = accept_tcp(&listener, deadline, &format!("worker-{w}"))?;
                transports.push(
                    Box::new(SocketTransport::new(format!("worker-{w}"), stream))
                        as Box<dyn Transport>,
                );
            }
            let handles = (0..*workers).map(|_| WorkerHandle::External).collect();
            Ok(Fleet { transports, handles, socket_path: None })
        }
    }
}

/// The binary to spawn `multiprocess` workers from:
/// `FERRISFL_WORKER_BIN` (tests point it at the freshly built binary),
/// else this very executable.
fn worker_binary() -> Result<PathBuf> {
    match env::worker_bin() {
        Some(bin) => Ok(PathBuf::from(bin)),
        None => std::env::current_exe().context("resolving the worker binary"),
    }
}

/// Expect `Hello` from every worker, answer with the wired config.
fn handshake(fleet: &mut Fleet, config: &str, timeout: Duration) -> Result<()> {
    for (w, t) in fleet.transports.iter_mut().enumerate() {
        let deadline = Instant::now() + timeout;
        match recv_until(&mut **t, deadline)? {
            Some(Received::Msg(Message::Hello { version }, _)) => {
                if version != WIRE_VERSION {
                    bail!(
                        "worker {w} speaks wire version {version}, leader speaks {WIRE_VERSION}"
                    );
                }
            }
            Some(Received::Msg(Message::WorkerError { message }, _)) => {
                bail!("worker {w} failed during handshake: {message}")
            }
            Some(Received::Msg(other, _)) => {
                bail!("expected Hello from worker {w}, got {}", other.kind_name())
            }
            Some(Received::Corrupt(why)) => bail!("corrupt Hello from worker {w}: {why}"),
            None => bail!("worker {w} never said Hello"),
        }
        t.send(&Message::Init { config: config.to_string() })?;
    }
    Ok(())
}

/// Wait until `deadline` for one frame; `None` means the peer stayed
/// silent the whole time.
fn recv_until(t: &mut dyn Transport, deadline: Instant) -> Result<Option<Received>> {
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Ok(None);
        }
        if let Some(r) = t.recv_timeout(left.min(POLL_SLICE))? {
            return Ok(Some(r));
        }
    }
}

/// Round-robin the cohort over `n` workers: cohort index `i` goes to
/// worker `i % n`, carrying its agent id and stream weight.
fn partition_cohort(sampled: &[usize], weights: &[u64], n: usize) -> Vec<Vec<(u32, u64)>> {
    let mut assign: Vec<Vec<(u32, u64)>> = vec![Vec::new(); n];
    for (i, &aid) in sampled.iter().enumerate() {
        assign[i % n].push((aid as u32, weights[i]));
    }
    assign
}

/// Count a rejected/lost delta against the wire retry budget and ask
/// worker `w` to resend `agent_id`; bail when the budget is spent.
#[allow(clippy::too_many_arguments)]
fn reject_and_resend(
    t: &mut dyn Transport,
    logger: &mut dyn Logger,
    stats: &mut RecoveryStats,
    attempts: &mut u32,
    budget: u32,
    backoff: &Backoff,
    round: usize,
    agent_id: u32,
    w: usize,
    why: &str,
    now: f64,
) -> Result<()> {
    stats.failures += 1;
    stats.corrupt_rejected += 1;
    logger.log_event(&EventRecord {
        time: now,
        kind: "delta_rejected",
        round,
        agent_id: Some(agent_id as usize),
        staleness: None,
        reason: Some("corrupt"),
        worker: Some(w),
    })?;
    if *attempts >= budget {
        bail!("worker {w} exhausted {budget} wire retries in round {round}: {why}");
    }
    // Wall-clock backoff with zero jitter: wire retries are real
    // sleeps, not simulated delays, and must not consume RNG draws.
    let delay = backoff.delay_secs(*attempts, 0.0);
    *attempts += 1;
    if delay > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(delay));
    }
    stats.retries += 1;
    logger.log_event(&EventRecord {
        time: now,
        kind: "retry_due",
        round,
        agent_id: Some(agent_id as usize),
        staleness: None,
        reason: None,
        worker: Some(w),
    })?;
    t.send(&Message::Resend { round: round as u64, agent_id })?;
    Ok(())
}

/// The distributed round loop. Observable order matches the engine's
/// degenerate path step for step; only where the training happens
/// differs.
fn drive_rounds(
    ep: &mut Entrypoint,
    logger: &mut dyn Logger,
    fleet: &mut Fleet,
    stream_kind: StreamKind,
    timeout: Duration,
) -> Result<RunResult> {
    let n = fleet.transports.len();
    let t_run = Instant::now();
    let mut profiler = SimpleProfiler::new();
    let mut rounds = Vec::new();
    let mut agent_records = Vec::new();
    let mut comm = CommStats::default();
    let mut dropped_log = Vec::new();
    let mut rejected_log = Vec::new();
    let k = ep.params.sampled_per_round();
    let fault_plan = ep.params.fault_plan();
    let budget = ep.params.retry;
    let backoff = ep.params.backoff.clone();

    for round in 0..ep.params.global_epochs {
        let t_round = Instant::now();

        // 1. sample A^t and apply dropout — the exact RNG draws of the
        // single-process paths, so cohorts match round for round.
        let mut sampled =
            profiler.time("sampling", || ep.sampler.sample(&ep.registry, k, &mut ep.rng))?;
        let mut dropped = Vec::new();
        fault_plan.apply_dropout(&mut ep.rng, &mut sampled, &mut dropped);
        if sampled.is_empty() {
            dropped_log.push(dropped.clone());
            rejected_log.push(Vec::new());
            let rec = RoundRecord {
                round,
                train_loss: f64::NAN,
                train_acc: f64::NAN,
                eval_loss: f64::NAN,
                eval_acc: f64::NAN,
                sampled,
                dropped,
                rejected: Vec::new(),
                secs: t_round.elapsed().as_secs_f64(),
                sim_secs: 0.0,
                outcome: RoundOutcome::Skipped(SkipReason::EmptyCohort),
                recovery: RecoveryStats::default(),
                adversarial: 0,
                trimmed_frac: 0.0,
            };
            logger.log_round(&rec)?;
            rounds.push(rec);
            continue;
        }

        // 2. the streaming accumulator (reused across rounds) and the
        // per-agent stream weights, exactly as the engine computes them.
        let p = ep.global.len();
        let acc = if ep.stream_acc.as_ref().is_some_and(|a| a.len() == p) {
            let a = ep.stream_acc.as_ref().unwrap();
            a.reset();
            Arc::clone(a)
        } else {
            let a = Arc::new(StreamingAccumulator::new(p));
            ep.stream_acc = Some(Arc::clone(&a));
            a
        };
        let stream_weights: Vec<u64> = match stream_kind {
            StreamKind::SampleWeighted => {
                let ws: Vec<u64> =
                    sampled.iter().map(|&aid| ep.registry.shard_len(aid) as u64).collect();
                if ws.iter().sum::<u64>() == 0 {
                    vec![1; ws.len()]
                } else {
                    ws
                }
            }
            _ => vec![1; sampled.len()],
        };

        // 3. assign shards of the cohort round-robin and collect the
        // framed deltas in whatever order they arrive — the integer
        // reduce makes arrival order irrelevant.
        let t_local = Instant::now();
        let assign = partition_cohort(&sampled, &stream_weights, n);
        for (w, t) in fleet.transports.iter_mut().enumerate() {
            t.send(&Message::Assign {
                round: round as u64,
                agents: assign[w].clone(),
                global: ep.global.clone(),
            })
            .with_context(|| format!("assigning round {round} to worker {w}"))?;
        }

        let mut pending: Vec<VecDeque<(u32, u64)>> =
            assign.iter().map(|a| a.iter().copied().collect()).collect();
        let mut got: Vec<Option<AgentRecord>> = vec![None; sampled.len()];
        let mut attempts = vec![0u32; n];
        let mut stats = RecoveryStats::default();
        let mut outstanding = sampled.len();
        let mut deadline = Instant::now() + timeout;
        while outstanding > 0 {
            let mut progressed = false;
            for w in 0..n {
                if pending[w].is_empty() {
                    continue;
                }
                let now = t_run.elapsed().as_secs_f64();
                match fleet.transports[w].recv_timeout(POLL_SLICE)? {
                    None => {}
                    Some(Received::Msg(
                        Message::Delta { round: dr, agent_id, weight, digest, terms, record },
                        frame_len,
                    )) => {
                        if dr != round as u64 {
                            bail!("worker {w} answered round {dr} during round {round}");
                        }
                        let Some(pos) =
                            pending[w].iter().position(|&(aid, _)| aid == agent_id)
                        else {
                            let ci = sampled.iter().position(|&a| a == agent_id as usize);
                            if ci.is_some_and(|ci| got[ci].is_some()) {
                                // A slow original racing a timeout-
                                // triggered resend: drop the duplicate
                                // (the reduce already folded it once).
                                continue;
                            }
                            bail!(
                                "worker {w} sent a delta for agent {agent_id}, which it \
                                 does not own in round {round}"
                            );
                        };
                        let expected_w = pending[w][pos].1;
                        // Defense in depth behind the frame digest: the
                        // terms must also hash to the delta checksum
                        // and carry the assigned weight and length.
                        if weight != expected_w
                            || terms.len() != p
                            || quantized_checksum(&terms) != digest
                        {
                            reject_and_resend(
                                &mut *fleet.transports[w],
                                logger,
                                &mut stats,
                                &mut attempts[w],
                                budget,
                                &backoff,
                                round,
                                agent_id,
                                w,
                                "delta content failed verification",
                                now,
                            )?;
                            progressed = true;
                            continue;
                        }
                        // Sketch-based robust rules fold each verified
                        // frame's terms into their bounded state — the
                        // same wire terms the reduce folds, so the
                        // observation is bit-identical to every other
                        // topology. Duplicates were dropped above.
                        if ep.aggregator.observes_updates() {
                            ep.aggregator.observe_quantized(
                                round as u64,
                                agent_id as u64,
                                &terms,
                                weight,
                            )?;
                        }
                        acc.push_quantized(&terms, weight)?;
                        comm.dense_bytes += (terms.len() * 4) as u64;
                        comm.wire_bytes += frame_len as u64;
                        logger.log_event(&EventRecord {
                            time: now,
                            kind: "client_finished",
                            round,
                            agent_id: Some(agent_id as usize),
                            staleness: None,
                            reason: None,
                            worker: Some(w),
                        })?;
                        logger.log_event(&EventRecord {
                            time: now,
                            kind: "delta_arrived",
                            round,
                            agent_id: Some(agent_id as usize),
                            staleness: Some(0),
                            reason: None,
                            worker: Some(w),
                        })?;
                        let _ = pending[w].remove(pos);
                        let ci = sampled
                            .iter()
                            .position(|&a| a == agent_id as usize)
                            .expect("delta for an unsampled agent");
                        got[ci] = Some(record);
                        outstanding -= 1;
                        progressed = true;
                    }
                    Some(Received::Msg(Message::WorkerError { message }, _)) => {
                        bail!("worker {w} failed: {message}")
                    }
                    Some(Received::Msg(other, _)) => {
                        bail!("unexpected {} from worker {w}", other.kind_name())
                    }
                    Some(Received::Corrupt(why)) => {
                        // Streams deliver in order and workers send
                        // their assignment in order, so the corrupt
                        // frame is the first outstanding delta.
                        let (agent_id, _) = *pending[w].front().expect("checked non-empty");
                        reject_and_resend(
                            &mut *fleet.transports[w],
                            logger,
                            &mut stats,
                            &mut attempts[w],
                            budget,
                            &backoff,
                            round,
                            agent_id,
                            w,
                            &why,
                            now,
                        )?;
                        progressed = true;
                    }
                }
            }
            if progressed {
                deadline = Instant::now() + timeout;
            } else if Instant::now() >= deadline {
                // Stragglers: spend a retry per lagging worker on its
                // first outstanding delta, or give up loudly.
                for w in 0..n {
                    let Some(&(agent_id, _)) = pending[w].front() else { continue };
                    let now = t_run.elapsed().as_secs_f64();
                    stats.failures += 1;
                    if attempts[w] >= budget {
                        bail!(
                            "timed out waiting for worker {w} (agent {agent_id}) in \
                             round {round} after {budget} retries"
                        );
                    }
                    attempts[w] += 1;
                    stats.retries += 1;
                    logger.log_event(&EventRecord {
                        time: now,
                        kind: "retry_due",
                        round,
                        agent_id: Some(agent_id as usize),
                        staleness: None,
                        reason: Some("offline"),
                        worker: Some(w),
                    })?;
                    fleet.transports[w]
                        .send(&Message::Resend { round: round as u64, agent_id })?;
                }
                deadline = Instant::now() + timeout;
            }
        }
        profiler.record("local_training", t_local.elapsed().as_secs_f64());

        // 4. fold local metrics in cohort order — the engine's drain
        // order — so the f64 accumulations are bit-identical too.
        let mut train_loss = Accumulator::default();
        let mut train_acc = Accumulator::default();
        for (i, &aid) in sampled.iter().enumerate() {
            let record = got[i].take().expect("collected every delta");
            train_loss.add(record.final_loss());
            train_acc.add(record.final_acc());
            ep.registry.record_round(aid, record.final_loss(), ep.params.local_epochs);
            logger.log_agent(&record)?;
            agent_records.push(record);
        }
        rejected_log.push(Vec::new());
        dropped_log.push(dropped.clone());

        // 5. aggregate: one finalize pass over the integer reduce, the
        // same state fold as single-process streaming rounds.
        // (Contribution scores need materialized f32 deltas, which
        // never exist leader-side on the wire path; they stay empty.)
        let t_agg = Instant::now();
        let mean = acc.finalize()?;
        let new_global = ep.aggregator.apply_streamed(&ep.global, &mean)?;
        ep.global = new_global;
        profiler.record("aggregation", t_agg.elapsed().as_secs_f64());

        // Byzantine accounting: workers poison on-device, so the leader
        // never sees the honest bits — but the draw is a pure function
        // of (seed, agent, round), so it can be reconstructed exactly.
        let adversarial = sampled
            .iter()
            .filter(|&&aid| {
                ep.params.adversary.is_adversarial(ep.params.seed, aid as u64, round as u64)
            })
            .count() as u32;

        // 6. evaluate on the leader's own pool at the configured cadence.
        let do_eval = ep.params.eval_every > 0 && (round + 1) % ep.params.eval_every == 0;
        let eval = if do_eval {
            logger.log_event(&EventRecord {
                time: t_run.elapsed().as_secs_f64(),
                kind: "eval_due",
                round,
                agent_id: None,
                staleness: None,
                reason: None,
                worker: None,
            })?;
            let t_eval = Instant::now();
            let es = ep.evaluate()?;
            profiler.record("evaluation", t_eval.elapsed().as_secs_f64());
            Some(es)
        } else {
            None
        };

        let rec = RoundRecord {
            round,
            train_loss: train_loss.mean(),
            train_acc: train_acc.mean(),
            eval_loss: eval.map_or(f64::NAN, |e| e.mean_loss()),
            eval_acc: eval.map_or(f64::NAN, |e| e.accuracy()),
            sampled,
            dropped,
            rejected: Vec::new(),
            secs: t_round.elapsed().as_secs_f64(),
            sim_secs: 0.0,
            outcome: RoundOutcome::Aggregated,
            recovery: stats,
            adversarial,
            trimmed_frac: ep.aggregator.trimmed_frac(),
        };
        logger.log_round(&rec)?;
        rounds.push(rec);
    }

    let final_eval = ep.evaluate()?;
    profiler.stop();
    logger.finish()?;
    Ok(RunResult {
        rounds,
        agent_records,
        final_eval,
        profiler,
        comm,
        contributions: ContributionTracker::new(),
        dropped: dropped_log,
        defense_rejected: rejected_log,
        sim_secs: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_partition_is_round_robin_with_weights() {
        let sampled = vec![9, 4, 7, 2, 5];
        let weights = vec![10, 20, 30, 40, 50];
        let assign = partition_cohort(&sampled, &weights, 2);
        assert_eq!(assign[0], vec![(9, 10), (7, 30), (5, 50)]);
        assert_eq!(assign[1], vec![(4, 20), (2, 40)]);
        // One worker gets the whole cohort in order.
        let all = partition_cohort(&sampled, &weights, 1);
        assert_eq!(all[0].len(), 5);
        assert_eq!(all[0][0], (9, 10));
    }
}
