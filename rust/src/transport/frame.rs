//! Length-prefixed wire frames for the multi-process executor.
//!
//! A frame is `magic u32 | kind u8 | payload_len u32 | payload |
//! digest u64`, all little-endian. The payload of a `Delta` frame
//! carries the streaming reduce's 2⁻⁴⁰ fixed-point quantised i64 terms
//! (see [`crate::aggregators::quantize_weighted`]) — the wire format
//! *is* the in-memory contract, so a leader that folds wire terms via
//! `push_quantized` lands on bits identical to a single-process run.
//!
//! Two failure tiers, matching [`super::Received`]:
//!
//! - **Corrupt frame** — the envelope (magic + length) parsed, so the
//!   stream is still in sync, but the trailing digest or the payload
//!   decode failed. The receiver reports it and asks for a resend; the
//!   retry budget is [`crate::config::FlParams::retry`].
//! - **Broken stream** — bad magic, EOF mid-frame, or an insane length:
//!   framing is lost and the connection is declared dead.
//!
//! The digest is the same SplitMix64 chain as
//! [`crate::aggregators::delta_checksum`] (over the raw frame bytes
//! here; `Delta` payloads additionally carry the semantic
//! `quantized_checksum` of their terms, verified before the
//! accumulator push).

use crate::metrics::AgentRecord;
use crate::util::error::{bail, Result};
use crate::util::rng;

/// Wire protocol version, exchanged in `Hello`.
pub const WIRE_VERSION: u32 = 1;

/// Frame magic: `b"FFL1"` little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"FFL1");

/// `magic u32 + kind u8 + payload_len u32`.
pub const HEADER_LEN: usize = 9;

/// Trailing SplitMix64 digest.
pub const DIGEST_LEN: usize = 8;

/// Sanity cap on payload length (256 MiB ≈ a 32M-parameter delta);
/// anything larger means framing is lost.
pub const MAX_PAYLOAD: usize = 1 << 28;

/// Everything that crosses the wire between leader and workers.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Worker → leader, once after connect: protocol handshake.
    Hello { version: u32 },
    /// Leader → worker, once: the full experiment config as TOML text
    /// ([`crate::config::FlParams::to_wire_toml`]). The worker rebuilds
    /// the *entire* deterministic state — dataset, shards, runtime —
    /// from this plus its own binary, so only config crosses the wire.
    Init { config: String },
    /// Leader → worker, per round: train these agents against `global`.
    /// `agents` carries `(agent_id, stream_weight)` pairs — the weight
    /// depends on the whole cohort (uniform fallback when every shard
    /// is empty), which only the leader can see.
    Assign {
        round: u64,
        agents: Vec<(u32, u64)>,
        global: Vec<f32>,
    },
    /// Worker → leader: one agent's quantised weighted delta plus its
    /// training record. `digest` is `quantized_checksum(&terms)`,
    /// verified leader-side before the accumulator push.
    Delta {
        round: u64,
        agent_id: u32,
        weight: u64,
        digest: u64,
        terms: Vec<i64>,
        record: AgentRecord,
    },
    /// Leader → worker: the delta for `(round, agent_id)` arrived
    /// corrupt — send it again (workers cache the round's encoded
    /// deltas, so a resend is a lookup, not a retrain).
    Resend { round: u64, agent_id: u32 },
    /// Leader → worker: run complete, exit cleanly.
    Shutdown,
    /// Worker → leader: fatal worker-side failure, with the error text.
    WorkerError { message: String },
}

impl Message {
    fn kind(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::Init { .. } => 2,
            Message::Assign { .. } => 3,
            Message::Delta { .. } => 4,
            Message::Resend { .. } => 5,
            Message::Shutdown => 6,
            Message::WorkerError { .. } => 7,
        }
    }

    /// Human-readable kind tag for logs and errors.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "hello",
            Message::Init { .. } => "init",
            Message::Assign { .. } => "assign",
            Message::Delta { .. } => "delta",
            Message::Resend { .. } => "resend",
            Message::Shutdown => "shutdown",
            Message::WorkerError { .. } => "worker_error",
        }
    }
}

// ---------------------------------------------------------------------
// Payload encode/decode — hand-rolled little-endian, zero dependencies.

struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    fn new() -> Self {
        Self { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn i64s(&mut self, v: &[i64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn f64s(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            bail!(
                "frame payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len().saturating_sub(self.pos)
            );
        };
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| crate::err!("frame string is not UTF-8: {e}"))?
            .to_string())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i64s(&mut self) -> Result<Vec<i64>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(8).unwrap_or(usize::MAX))?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(8).unwrap_or(usize::MAX))?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "frame payload has {} trailing bytes after decode",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

fn encode_payload(msg: &Message) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    match msg {
        Message::Hello { version } => w.u32(*version),
        Message::Init { config } => w.str(config),
        Message::Assign {
            round,
            agents,
            global,
        } => {
            w.u64(*round);
            w.u32(agents.len() as u32);
            for &(aid, weight) in agents {
                w.u32(aid);
                w.u64(weight);
            }
            w.f32s(global);
        }
        Message::Delta {
            round,
            agent_id,
            weight,
            digest,
            terms,
            record,
        } => {
            w.u64(*round);
            w.u32(*agent_id);
            w.u64(*weight);
            w.u64(*digest);
            w.i64s(terms);
            w.f64s(&record.epoch_losses);
            w.f64s(&record.epoch_accs);
            w.u64(record.num_samples as u64);
            w.f64(record.secs);
        }
        Message::Resend { round, agent_id } => {
            w.u64(*round);
            w.u32(*agent_id);
        }
        Message::Shutdown => {}
        Message::WorkerError { message } => w.str(message),
    }
    w.buf
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Message> {
    let mut r = PayloadReader::new(payload);
    let msg = match kind {
        1 => Message::Hello { version: r.u32()? },
        2 => Message::Init { config: r.str()? },
        3 => {
            let round = r.u64()?;
            let n = r.u32()? as usize;
            let mut agents = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                agents.push((r.u32()?, r.u64()?));
            }
            let global = r.f32s()?;
            Message::Assign {
                round,
                agents,
                global,
            }
        }
        4 => {
            let round = r.u64()?;
            let agent_id = r.u32()?;
            let weight = r.u64()?;
            let digest = r.u64()?;
            let terms = r.i64s()?;
            let epoch_losses = r.f64s()?;
            let epoch_accs = r.f64s()?;
            let num_samples = r.u64()? as usize;
            let secs = r.f64()?;
            Message::Delta {
                round,
                agent_id,
                weight,
                digest,
                terms,
                record: AgentRecord {
                    round: round as usize,
                    agent_id: agent_id as usize,
                    epoch_losses,
                    epoch_accs,
                    num_samples,
                    secs,
                },
            }
        }
        5 => Message::Resend {
            round: r.u64()?,
            agent_id: r.u32()?,
        },
        6 => Message::Shutdown,
        7 => Message::WorkerError { message: r.str()? },
        k => bail!("unknown frame kind {k}"),
    };
    r.finish()?;
    Ok(msg)
}

// ---------------------------------------------------------------------
// Frame envelope.

/// Frame digest: SplitMix64 chain over kind, payload length, and the
/// payload in 8-byte little-endian chunks (zero-padded tail). Pure
/// integer math — bit-identical on every platform.
pub fn frame_digest(kind: u8, payload: &[u8]) -> u64 {
    let seed = 0xFEED_F4A3_E001_0000u64 ^ ((kind as u64) << 56) ^ payload.len() as u64;
    let mut h = rng::splitmix64_mix(seed);
    for chunk in payload.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h = rng::splitmix64_mix(h ^ u64::from_le_bytes(word));
    }
    h
}

/// Encode a message into one complete wire frame.
pub fn encode_frame(msg: &Message) -> Result<Vec<u8>> {
    let payload = encode_payload(msg);
    if payload.len() > MAX_PAYLOAD {
        bail!(
            "{} frame payload of {} bytes exceeds the {} byte cap",
            msg.kind_name(),
            payload.len(),
            MAX_PAYLOAD
        );
    }
    let kind = msg.kind();
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + DIGEST_LEN);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&frame_digest(kind, &payload).to_le_bytes());
    Ok(out)
}

/// Decode one complete frame from a byte buffer (the in-proc transport
/// and the codec tests; socket transports stream the same layout).
///
/// The outer `Err` means framing itself is broken (bad magic, insane
/// or mismatched length); the inner `Err` means the envelope parsed
/// but the content is corrupt (digest mismatch, payload decode
/// failure) — a stream receiver can stay in sync and request a resend.
pub fn decode_frame(bytes: &[u8]) -> Result<Result<Message>> {
    if bytes.len() < HEADER_LEN + DIGEST_LEN {
        bail!(
            "frame of {} bytes is shorter than the {}-byte envelope",
            bytes.len(),
            HEADER_LEN + DIGEST_LEN
        );
    }
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad frame magic {magic:#010x} (expected {MAGIC:#010x})");
    }
    let kind = bytes[4];
    let len = u32::from_le_bytes(bytes[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        bail!("frame payload length {len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    if bytes.len() != HEADER_LEN + len + DIGEST_LEN {
        bail!(
            "frame length mismatch: header says {} payload bytes, buffer holds {}",
            len,
            bytes.len() - HEADER_LEN - DIGEST_LEN
        );
    }
    let payload = &bytes[HEADER_LEN..HEADER_LEN + len];
    let digest = u64::from_le_bytes(bytes[HEADER_LEN + len..].try_into().unwrap());
    let want = frame_digest(kind, payload);
    if digest != want {
        return Ok(Err(crate::err!(
            "frame digest mismatch: got {digest:#018x}, computed {want:#018x}"
        )));
    }
    Ok(decode_payload(kind, payload))
}

/// Flip one bit inside the *payload* region of an encoded frame,
/// leaving the envelope (magic + length) intact — the deterministic
/// corruption the chaos knob [`crate::util::env::wire_chaos`] injects.
/// A stream receiver stays in sync, fails the digest, and routes the
/// sender through the resend path. No-op on empty payloads.
pub fn corrupt_payload(frame: &mut [u8]) {
    let len = frame.len().saturating_sub(HEADER_LEN + DIGEST_LEN);
    if len == 0 {
        return;
    }
    frame[HEADER_LEN + len / 2] ^= 0x10;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn zoo(rng: &mut Rng) -> Vec<Message> {
        let record = AgentRecord {
            round: 3,
            agent_id: 17,
            epoch_losses: vec![1.25, 0.5],
            epoch_accs: vec![0.25, 0.875],
            num_samples: 60,
            secs: 0.125,
        };
        vec![
            Message::Hello {
                version: WIRE_VERSION,
            },
            Message::Init {
                config: "name = \"wire\"\n[fl]\nseed = 42\n".into(),
            },
            Message::Assign {
                round: 0,
                agents: vec![],
                global: vec![],
            },
            Message::Assign {
                round: 9,
                agents: vec![(3, 60), (81, 1), (4, 7)],
                global: (0..517).map(|_| rng.next_gaussian() * 0.1).collect(),
            },
            Message::Delta {
                round: 3,
                agent_id: 17,
                weight: 60,
                digest: 0xDEAD_BEEF_0123_4567,
                terms: (0..1031).map(|_| rng.next_u64() as i64 >> 20).collect(),
                record,
            },
            Message::Resend {
                round: 3,
                agent_id: 17,
            },
            Message::Shutdown,
            Message::WorkerError {
                message: "shard went missing".into(),
            },
        ]
    }

    /// Round-trip property over the message zoo: decode(encode(m)) == m
    /// for every variant, including empty vectors and odd lengths.
    #[test]
    fn round_trip_over_message_zoo() {
        let mut rng = Rng::new(0xf1a9);
        for msg in zoo(&mut rng) {
            let bytes = encode_frame(&msg).unwrap();
            let back = decode_frame(&bytes).unwrap().unwrap();
            assert_eq!(back, msg, "{} frame", msg.kind_name());
        }
    }

    /// Truncated frames at every boundary are *framing* errors (outer
    /// Err), never silent misdecodes.
    #[test]
    fn truncated_frames_are_framing_errors() {
        let mut rng = Rng::new(0x07c1);
        let bytes = encode_frame(&zoo(&mut rng)[4]).unwrap();
        for cut in [0, 4, HEADER_LEN - 1, HEADER_LEN, bytes.len() - 1] {
            assert!(
                decode_frame(&bytes[..cut]).is_err(),
                "cut at {cut} must be a framing error"
            );
        }
    }

    /// A bit-flip in the payload leaves the envelope parseable but
    /// fails the digest: inner Err — the resend path, not a dead
    /// stream.
    #[test]
    fn bit_flipped_payloads_fail_the_digest_but_keep_framing() {
        let mut rng = Rng::new(0xb17f);
        for msg in zoo(&mut rng) {
            let clean = encode_frame(&msg).unwrap();
            let mut bad = clean.clone();
            corrupt_payload(&mut bad);
            if bad == clean {
                continue; // empty payload: nothing to corrupt
            }
            let inner = decode_frame(&bad).unwrap();
            assert!(inner.is_err(), "{}: corrupt payload must fail", msg.kind_name());
        }
    }

    /// Wrong length field or wrong magic: framing is lost, fatal.
    #[test]
    fn wrong_length_and_bad_magic_are_fatal() {
        let mut rng = Rng::new(0x0bad);
        let bytes = encode_frame(&zoo(&mut rng)[3]).unwrap();
        let mut wrong_len = bytes.clone();
        wrong_len[5] ^= 0x01; // length field
        assert!(decode_frame(&wrong_len).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(decode_frame(&bad_magic).is_err());
        let mut huge = bytes;
        huge[5..9].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(decode_frame(&huge).is_err());
    }

    #[test]
    fn digest_is_a_pure_function_of_kind_and_payload() {
        assert_eq!(frame_digest(4, b"abc"), frame_digest(4, b"abc"));
        assert_ne!(frame_digest(4, b"abc"), frame_digest(5, b"abc"));
        assert_ne!(frame_digest(4, b"abc"), frame_digest(4, b"abd"));
        assert_ne!(frame_digest(4, b""), frame_digest(4, b"\0"));
    }
}
