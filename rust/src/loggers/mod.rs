//! Loggers — Lightning-logger analogues (paper §3.3.1).
//!
//! TorchFL inherits CSV/TensorBoard/MLflow loggers from Lightning; we
//! provide the same fan-out shape: a [`Logger`] trait, [`CsvLogger`] and
//! [`JsonlLogger`] file sinks, a [`ConsoleLogger`], and [`MultiLogger`]
//! to broadcast. Global (per-round) and per-agent channels are separate
//! files, which is how the paper collects "granular metrics for
//! individual agents" (§4.2.1) without post-hoc filtering.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::metrics::{AgentRecord, EventRecord, RecoveryStats, RoundRecord};
use crate::util::error::{Context, Result};
use crate::util::Json;

/// Sink for experiment records.
pub trait Logger: Send {
    fn log_round(&mut self, rec: &RoundRecord) -> Result<()>;
    fn log_agent(&mut self, rec: &AgentRecord) -> Result<()>;
    /// One engine event (arrival, deadline, eval) — the per-event
    /// channel of the round engine. Default: ignore.
    fn log_event(&mut self, _rec: &EventRecord) -> Result<()> {
        Ok(())
    }
    /// Flush buffers (called at experiment end).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// No-op logger.
#[derive(Default)]
pub struct NullLogger;

impl Logger for NullLogger {
    fn log_round(&mut self, _: &RoundRecord) -> Result<()> {
        Ok(())
    }

    fn log_agent(&mut self, _: &AgentRecord) -> Result<()> {
        Ok(())
    }
}

/// Prints a one-line summary per round (and nothing per agent).
#[derive(Default)]
pub struct ConsoleLogger {
    /// Also print each agent line (verbose).
    pub verbose: bool,
}

impl Logger for ConsoleLogger {
    fn log_round(&mut self, r: &RoundRecord) -> Result<()> {
        let eval = if r.eval_loss.is_nan() {
            String::new()
        } else {
            format!(
                " | eval loss {:.4} acc {:.3}",
                r.eval_loss, r.eval_acc
            )
        };
        let mut extras = String::new();
        if !r.dropped.is_empty() {
            extras.push_str(&format!(" | {} dropped", r.dropped.len()));
        }
        if !r.rejected.is_empty() {
            extras.push_str(&format!(" | {} rejected", r.rejected.len()));
        }
        if r.sim_secs > 0.0 {
            extras.push_str(&format!(" | sim {:.2}s", r.sim_secs));
        }
        if r.outcome.is_skipped() {
            extras.push_str(&format!(" | {}", r.outcome.name()));
        }
        if r.recovery != RecoveryStats::default() {
            let s = r.recovery;
            extras.push_str(&format!(
                " | {} failed/{} retried/{} corrupt/{} replaced",
                s.failures, s.retries, s.corrupt_rejected, s.replacements
            ));
        }
        if r.adversarial > 0 {
            extras.push_str(&format!(" | {} byzantine", r.adversarial));
        }
        if r.trimmed_frac > 0.0 {
            extras.push_str(&format!(" | trimmed {:.0}%", r.trimmed_frac * 100.0));
        }
        println!(
            "[round {:>3}] train loss {:.4} acc {:.3}{} | {} agents{} | {:.2}s",
            r.round,
            r.train_loss,
            r.train_acc,
            eval,
            r.sampled.len(),
            extras,
            r.secs
        );
        Ok(())
    }

    fn log_agent(&mut self, r: &AgentRecord) -> Result<()> {
        if self.verbose {
            println!(
                "  [agent {:>3}] round {} loss {:.4} acc {:.3} ({} samples)",
                r.agent_id,
                r.round,
                r.final_loss(),
                r.final_acc(),
                r.num_samples
            );
        }
        Ok(())
    }

    fn log_event(&mut self, r: &EventRecord) -> Result<()> {
        if self.verbose {
            let agent = r.agent_id.map_or(String::new(), |a| format!(" agent {a}"));
            let stale = match r.staleness {
                Some(s) if s > 0 => format!(" (stale {s})"),
                _ => String::new(),
            };
            let why = r.reason.map_or(String::new(), |w| format!(" [{w}]"));
            let via = r.worker.map_or(String::new(), |w| format!(" via w{w}"));
            println!(
                "  [t={:>9.3}s] {}{}{}{}{} round {}",
                r.time, r.kind, agent, stale, why, via, r.round
            );
        }
        Ok(())
    }
}

/// CSV sink: `<dir>/<name>_rounds.csv` + `<dir>/<name>_agents.csv` +
/// `<dir>/<name>_events.csv` (the engine's per-event channel).
pub struct CsvLogger {
    rounds: BufWriter<File>,
    agents: BufWriter<File>,
    events: BufWriter<File>,
}

impl CsvLogger {
    pub fn create(dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating log dir {dir:?}"))?;
        let mut rounds = BufWriter::new(
            File::create(dir.join(format!("{name}_rounds.csv")))
                .context("creating rounds csv")?,
        );
        let mut agents = BufWriter::new(
            File::create(dir.join(format!("{name}_agents.csv")))
                .context("creating agents csv")?,
        );
        let mut events = BufWriter::new(
            File::create(dir.join(format!("{name}_events.csv")))
                .context("creating events csv")?,
        );
        // New columns append after the legacy ones, so downstream
        // consumers indexing by position keep working (pinned by
        // `csv_fault_columns_append_after_the_legacy_ones`).
        writeln!(
            rounds,
            "round,train_loss,train_acc,eval_loss,eval_acc,num_sampled,num_dropped,num_rejected,secs,sim_secs,outcome,failures,retries,corrupt_rejected,replacements,adversarial,trimmed_frac"
        )?;
        writeln!(
            agents,
            "round,agent_id,final_loss,final_acc,num_samples,secs"
        )?;
        writeln!(events, "time,kind,round,agent_id,staleness,reason,worker")?;
        Ok(Self { rounds, agents, events })
    }
}

impl Logger for CsvLogger {
    fn log_round(&mut self, r: &RoundRecord) -> Result<()> {
        writeln!(
            self.rounds,
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            r.round,
            r.train_loss,
            r.train_acc,
            r.eval_loss,
            r.eval_acc,
            r.sampled.len(),
            r.dropped.len(),
            r.rejected.len(),
            r.secs,
            r.sim_secs,
            r.outcome.name(),
            r.recovery.failures,
            r.recovery.retries,
            r.recovery.corrupt_rejected,
            r.recovery.replacements,
            r.adversarial,
            r.trimmed_frac
        )?;
        Ok(())
    }

    fn log_agent(&mut self, r: &AgentRecord) -> Result<()> {
        writeln!(
            self.agents,
            "{},{},{},{},{},{}",
            r.round,
            r.agent_id,
            r.final_loss(),
            r.final_acc(),
            r.num_samples,
            r.secs
        )?;
        Ok(())
    }

    fn log_event(&mut self, r: &EventRecord) -> Result<()> {
        let agent = r.agent_id.map_or(String::new(), |a| a.to_string());
        let stale = r.staleness.map_or(String::new(), |s| s.to_string());
        let why = r.reason.unwrap_or("");
        let via = r.worker.map_or(String::new(), |w| w.to_string());
        writeln!(
            self.events,
            "{},{},{},{},{},{},{}",
            r.time, r.kind, r.round, agent, stale, why, via
        )?;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.rounds.flush()?;
        self.agents.flush()?;
        self.events.flush()?;
        Ok(())
    }
}

/// JSONL sink: one JSON object per record, both channels in one file
/// (discriminated by a `kind` field) — convenient for ad-hoc analysis.
pub struct JsonlLogger {
    out: BufWriter<File>,
}

impl JsonlLogger {
    pub fn create(dir: impl AsRef<Path>, name: &str) -> Result<Self> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let out = BufWriter::new(
            File::create(dir.join(format!("{name}.jsonl")))
                .context("creating jsonl log")?,
        );
        Ok(Self { out })
    }
}

impl Logger for JsonlLogger {
    fn log_round(&mut self, r: &RoundRecord) -> Result<()> {
        let j = Json::obj(vec![
            ("kind", Json::str("round")),
            ("round", Json::num(r.round as f64)),
            ("train_loss", Json::num(r.train_loss)),
            ("train_acc", Json::num(r.train_acc)),
            ("eval_loss", Json::num(r.eval_loss)),
            ("eval_acc", Json::num(r.eval_acc)),
            (
                "sampled",
                Json::Arr(r.sampled.iter().map(|&i| Json::num(i as f64)).collect()),
            ),
            (
                "dropped",
                Json::Arr(r.dropped.iter().map(|&i| Json::num(i as f64)).collect()),
            ),
            (
                "rejected",
                Json::Arr(r.rejected.iter().map(|&i| Json::num(i as f64)).collect()),
            ),
            ("secs", Json::num(r.secs)),
            ("sim_secs", Json::num(r.sim_secs)),
            ("outcome", Json::str(r.outcome.name())),
            ("failures", Json::num(r.recovery.failures as f64)),
            ("retries", Json::num(r.recovery.retries as f64)),
            ("corrupt_rejected", Json::num(r.recovery.corrupt_rejected as f64)),
            ("replacements", Json::num(r.recovery.replacements as f64)),
            ("adversarial", Json::num(r.adversarial as f64)),
            ("trimmed_frac", Json::num(r.trimmed_frac)),
        ]);
        writeln!(self.out, "{}", j.to_string())?;
        Ok(())
    }

    fn log_agent(&mut self, r: &AgentRecord) -> Result<()> {
        let j = Json::obj(vec![
            ("kind", Json::str("agent")),
            ("round", Json::num(r.round as f64)),
            ("agent_id", Json::num(r.agent_id as f64)),
            (
                "epoch_losses",
                Json::Arr(r.epoch_losses.iter().map(|&v| Json::num(v)).collect()),
            ),
            (
                "epoch_accs",
                Json::Arr(r.epoch_accs.iter().map(|&v| Json::num(v)).collect()),
            ),
            ("num_samples", Json::num(r.num_samples as f64)),
            ("secs", Json::num(r.secs)),
        ]);
        writeln!(self.out, "{}", j.to_string())?;
        Ok(())
    }

    fn log_event(&mut self, r: &EventRecord) -> Result<()> {
        let mut pairs = vec![
            ("kind", Json::str("event")),
            ("event", Json::str(r.kind)),
            ("time", Json::num(r.time)),
            ("round", Json::num(r.round as f64)),
        ];
        if let Some(a) = r.agent_id {
            pairs.push(("agent_id", Json::num(a as f64)));
        }
        if let Some(s) = r.staleness {
            pairs.push(("staleness", Json::num(s as f64)));
        }
        if let Some(w) = r.reason {
            pairs.push(("reason", Json::str(w)));
        }
        if let Some(w) = r.worker {
            pairs.push(("worker", Json::num(w as f64)));
        }
        writeln!(self.out, "{}", Json::obj(pairs).to_string())?;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Broadcast to several loggers.
pub struct MultiLogger {
    pub sinks: Vec<Box<dyn Logger>>,
}

impl MultiLogger {
    pub fn new(sinks: Vec<Box<dyn Logger>>) -> Self {
        Self { sinks }
    }
}

impl Logger for MultiLogger {
    fn log_round(&mut self, r: &RoundRecord) -> Result<()> {
        for s in &mut self.sinks {
            s.log_round(r)?;
        }
        Ok(())
    }

    fn log_agent(&mut self, r: &AgentRecord) -> Result<()> {
        for s in &mut self.sinks {
            s.log_agent(r)?;
        }
        Ok(())
    }

    fn log_event(&mut self, r: &EventRecord) -> Result<()> {
        for s in &mut self.sinks {
            s.log_event(r)?;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        for s in &mut self.sinks {
            s.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::metrics::{RoundOutcome, SkipReason};

    fn sample_round() -> RoundRecord {
        RoundRecord {
            round: 3,
            train_loss: 1.25,
            train_acc: 0.5,
            eval_loss: 1.0,
            eval_acc: 0.6,
            sampled: vec![1, 4],
            dropped: vec![7],
            rejected: vec![],
            secs: 0.25,
            sim_secs: 0.0,
            outcome: RoundOutcome::Aggregated,
            recovery: RecoveryStats::default(),
            adversarial: 0,
            trimmed_frac: 0.0,
        }
    }

    fn sample_event() -> EventRecord {
        EventRecord {
            time: 1.5,
            kind: "delta_arrived",
            round: 3,
            agent_id: Some(4),
            staleness: Some(1),
            reason: None,
            worker: None,
        }
    }

    fn sample_agent() -> AgentRecord {
        AgentRecord {
            round: 3,
            agent_id: 4,
            epoch_losses: vec![2.0, 1.0],
            epoch_accs: vec![0.2, 0.7],
            num_samples: 50,
            secs: 0.1,
        }
    }

    #[test]
    fn csv_logger_writes_all_channels() {
        let dir = std::env::temp_dir().join(format!("ferrisfl-csv-{}", std::process::id()));
        let mut l = CsvLogger::create(&dir, "t").unwrap();
        l.log_round(&sample_round()).unwrap();
        l.log_agent(&sample_agent()).unwrap();
        l.log_event(&sample_event()).unwrap();
        l.finish().unwrap();
        let rounds = std::fs::read_to_string(dir.join("t_rounds.csv")).unwrap();
        assert!(rounds.lines().count() == 2);
        assert!(rounds.contains("3,1.25,0.5,1,0.6,2,1,0,0.25,0"));
        let agents = std::fs::read_to_string(dir.join("t_agents.csv")).unwrap();
        assert!(agents.contains("3,4,1,0.7,50,0.1"));
        let events = std::fs::read_to_string(dir.join("t_events.csv")).unwrap();
        assert!(events.starts_with("time,kind,round,agent_id,staleness"));
        assert!(events.contains("1.5,delta_arrived,3,4,1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_fault_columns_append_after_the_legacy_ones() {
        // The legacy column order is pinned: fault/recovery columns only
        // ever APPEND, so positional consumers of old logs keep working.
        let dir = std::env::temp_dir().join(format!("ferrisfl-csvf-{}", std::process::id()));
        let mut l = CsvLogger::create(&dir, "t").unwrap();
        let mut r = sample_round();
        r.outcome = RoundOutcome::Skipped(SkipReason::Quorum);
        r.recovery =
            RecoveryStats { failures: 3, retries: 2, corrupt_rejected: 1, replacements: 1 };
        l.log_round(&r).unwrap();
        let mut e = sample_event();
        e.kind = "client_failed";
        e.staleness = None;
        e.reason = Some("crash");
        l.log_event(&e).unwrap();
        l.finish().unwrap();
        let rounds = std::fs::read_to_string(dir.join("t_rounds.csv")).unwrap();
        assert!(rounds.starts_with(
            "round,train_loss,train_acc,eval_loss,eval_acc,num_sampled,num_dropped,\
             num_rejected,secs,sim_secs,outcome,failures,retries,corrupt_rejected,replacements"
        ));
        assert!(rounds.contains("0.25,0,skipped_quorum,3,2,1,1"), "{rounds}");
        let events = std::fs::read_to_string(dir.join("t_events.csv")).unwrap();
        assert!(events.starts_with("time,kind,round,agent_id,staleness,reason"));
        assert!(events.contains("1.5,client_failed,3,4,,crash"), "{events}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_adversary_columns_append_after_the_recovery_ones() {
        // Same append-only contract as the fault columns: adversary /
        // robustness counters land after `replacements`.
        let dir = std::env::temp_dir().join(format!("ferrisfl-csva-{}", std::process::id()));
        let mut l = CsvLogger::create(&dir, "t").unwrap();
        let mut r = sample_round();
        r.adversarial = 2;
        r.trimmed_frac = 0.4;
        l.log_round(&r).unwrap();
        l.finish().unwrap();
        let rounds = std::fs::read_to_string(dir.join("t_rounds.csv")).unwrap();
        let header = rounds.lines().next().unwrap();
        assert!(header.ends_with("replacements,adversarial,trimmed_frac"), "{header}");
        assert!(rounds.contains("aggregated,0,0,0,0,2,0.4"), "{rounds}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn worker_attribution_appends_to_the_event_channel() {
        // Distributed runs tag events with the producing worker's index;
        // the column appends after `reason`, single-process rows leave
        // it empty.
        let dir = std::env::temp_dir().join(format!("ferrisfl-csvw-{}", std::process::id()));
        let mut l = CsvLogger::create(&dir, "t").unwrap();
        l.log_event(&sample_event()).unwrap();
        let mut e = sample_event();
        e.worker = Some(1);
        l.log_event(&e).unwrap();
        l.finish().unwrap();
        let events = std::fs::read_to_string(dir.join("t_events.csv")).unwrap();
        assert!(events.starts_with("time,kind,round,agent_id,staleness,reason,worker"));
        assert!(events.contains("1.5,delta_arrived,3,4,1,,\n"), "{events}");
        assert!(events.contains("1.5,delta_arrived,3,4,1,,1"), "{events}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_logger_emits_valid_json() {
        let dir =
            std::env::temp_dir().join(format!("ferrisfl-jsonl-{}", std::process::id()));
        let mut l = JsonlLogger::create(&dir, "t").unwrap();
        l.log_round(&sample_round()).unwrap();
        l.log_agent(&sample_agent()).unwrap();
        l.log_event(&sample_event()).unwrap();
        l.finish().unwrap();
        let text = std::fs::read_to_string(dir.join("t.jsonl")).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert!(matches!(
                v.req("kind").unwrap().as_str().unwrap(),
                "round" | "agent" | "event"
            ));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_logger_broadcasts() {
        let mut m = MultiLogger::new(vec![
            Box::new(NullLogger),
            Box::new(NullLogger),
        ]);
        m.log_round(&sample_round()).unwrap();
        m.log_agent(&sample_agent()).unwrap();
        m.log_event(&sample_event()).unwrap();
        m.finish().unwrap();
    }
}
