//! # FerrisFL
//!
//! A performant library for bootstrapping federated-learning experiments —
//! a Rust reproduction of *TorchFL* (arXiv:2211.00735) with pluggable
//! execution backends.
//!
//! ## Architecture
//!
//! The FL control plane (datasets + sharding, agents, samplers,
//! aggregators, defenses, compression, the experiment entrypoint,
//! loggers, profilers, and the reproduction harness) is backend-agnostic:
//! every model operation goes through the
//! [`runtime::ModelExecutor`] trait, which covers the five runtime ops —
//! SGD step, Adam step, masked eval, FedAvg aggregation, and model
//! loading. Two backends implement it:
//!
//! - **native** (default) — [`runtime::native`], a pure-rust MLP
//!   forward/backward engine. Hermetic: no Python, no XLA, no AOT
//!   artifacts, zero external crates. Local training fans out across the
//!   [`util::threadpool::WorkerPool`] (one simulated client device per
//!   worker) and large FedAvg aggregations shard the parameter range
//!   across a process-wide pool.
//! - **pjrt** (optional, `--features pjrt`) — [`runtime::pjrt`]: the
//!   three-layer AOT path of the original design. L2 (python/compile)
//!   lowers a JAX model zoo to HLO text via `make artifacts`; L1
//!   (python/compile/kernels) supplies Pallas kernels for the compute
//!   hot-spots; this crate compiles and executes them through the PJRT C
//!   API (needs the vendored `xla` crate).
//!
//! Backends are selected per run: `--backend native|pjrt` on the CLI,
//! `backend = "..."` under `[run]` in config TOML, or
//! `FlParams::backend` / `TrainConfig::backend` in code.
//!
//! ## Verifying
//!
//! The tier-1 check is `cargo build --release && cargo test -q`, and it
//! passes on a clean checkout — the native backend needs nothing outside
//! this repository. PJRT-specific integration tests self-skip unless the
//! `pjrt` feature is enabled *and* `artifacts/manifest.json` exists.
//!
//! ## Round engine
//!
//! Rounds execute on an event-driven engine ([`engine`]) with a
//! simulated clock: per-client latency models, round deadlines with
//! partial participation, and FedBuff-style buffered aggregation are
//! scheduling policies over one event queue. The default policy (no
//! latency, no deadline, virtual clock) reproduces the classic
//! lockstep loop bit-for-bit; see [`engine`] for the event taxonomy
//! and [`config::FlParams::round_policy`] for the knobs.
//!
//! Seeded fault injection ([`engine::FaultPlan`]: crashes, delta
//! loss/corruption, availability churn) and recovery
//! ([`engine::RecoveryPolicy`]: retry/backoff, resampling, quorum)
//! layer on top of the same queue and replay bit-identically from
//! `(seed, plan)` at any worker count.
//!
//! ## Distributed execution
//!
//! Set `topology = "multiprocess:N"` (or `inproc:N` / `tcp:<addr>`) and
//! the same experiment runs as a leader plus `N` workers over framed
//! transports ([`transport`]): the wire carries the streaming reduce's
//! own fixed-point terms, so the final model is bit-identical to the
//! single-process run at the same seed, under any arrival order —
//! including frames rejected by the digest and recovered by resends.
//!
//! Quickstart: `cargo run --release --example quickstart`, or
//! `cargo run --release -- run --config configs/quickstart.toml`.
//! In code, start from [`Experiment::builder`](prelude::Experiment::builder)
//! via [`prelude`].

pub mod agents;
pub mod aggregators;
pub mod benchutil;
pub mod compression;
pub mod config;
pub mod datasets;
pub mod defense;
pub mod engine;
pub mod entrypoint;
pub mod federation;
pub mod incentives;
pub mod loggers;
pub mod metrics;
pub mod profiler;
pub mod repro;
pub mod runtime;
pub mod samplers;
pub mod transport;
pub mod util;
pub mod zoo;

/// One-stop imports for building and running experiments:
/// `use ferrisfl::prelude::*;`.
pub mod prelude {
    pub use crate::agents::{AgentRegistry, RegistryMode};
    pub use crate::config::{FlParams, Mode, Optimizer, Topology};
    pub use crate::engine::{
        AdversaryPlan, Availability, Backoff, Clock, ClockKind, Event, EventQueue, FailureReason,
        FaultPlan, LatencyModel, RecoveryPolicy, RoundPolicy, SimTime, VirtualClock, WallClock,
    };
    pub use crate::entrypoint::{Entrypoint, Experiment, ExperimentBuilder, RunResult};
    pub use crate::federation::Scheme;
    pub use crate::loggers::{
        ConsoleLogger, CsvLogger, JsonlLogger, Logger, MultiLogger, NullLogger,
    };
    pub use crate::metrics::{
        AgentRecord, EventRecord, RecoveryStats, RoundOutcome, RoundRecord, SkipReason,
    };
    pub use crate::runtime::{BackendKind, EvalStats, Manifest};
    pub use crate::util::error::{Error, Result};
    pub use crate::util::Parallelism;
}
