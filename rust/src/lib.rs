//! # FerrisFL
//!
//! A performant library for bootstrapping federated-learning experiments —
//! a Rust reproduction of *TorchFL* (arXiv:2211.00735) with pluggable
//! execution backends.
//!
//! ## Architecture
//!
//! The FL control plane (datasets + sharding, agents, samplers,
//! aggregators, defenses, compression, the experiment entrypoint,
//! loggers, profilers, and the reproduction harness) is backend-agnostic:
//! every model operation goes through the
//! [`runtime::ModelExecutor`] trait, which covers the five runtime ops —
//! SGD step, Adam step, masked eval, FedAvg aggregation, and model
//! loading. Two backends implement it:
//!
//! - **native** (default) — [`runtime::native`], a pure-rust MLP
//!   forward/backward engine. Hermetic: no Python, no XLA, no AOT
//!   artifacts, zero external crates. Local training fans out across the
//!   [`util::threadpool::WorkerPool`] (one simulated client device per
//!   worker) and large FedAvg aggregations shard the parameter range
//!   across a process-wide pool.
//! - **pjrt** (optional, `--features pjrt`) — [`runtime::pjrt`]: the
//!   three-layer AOT path of the original design. L2 (python/compile)
//!   lowers a JAX model zoo to HLO text via `make artifacts`; L1
//!   (python/compile/kernels) supplies Pallas kernels for the compute
//!   hot-spots; this crate compiles and executes them through the PJRT C
//!   API (needs the vendored `xla` crate).
//!
//! Backends are selected per run: `--backend native|pjrt` on the CLI,
//! `backend = "..."` under `[run]` in config TOML, or
//! `FlParams::backend` / `TrainConfig::backend` in code.
//!
//! ## Verifying
//!
//! The tier-1 check is `cargo build --release && cargo test -q`, and it
//! passes on a clean checkout — the native backend needs nothing outside
//! this repository. PJRT-specific integration tests self-skip unless the
//! `pjrt` feature is enabled *and* `artifacts/manifest.json` exists.
//!
//! Quickstart: `cargo run --release --example quickstart`, or
//! `cargo run --release -- run --config configs/quickstart.toml`.

pub mod agents;
pub mod aggregators;
pub mod benchutil;
pub mod compression;
pub mod config;
pub mod datasets;
pub mod defense;
pub mod entrypoint;
pub mod federation;
pub mod incentives;
pub mod loggers;
pub mod metrics;
pub mod profiler;
pub mod repro;
pub mod runtime;
pub mod samplers;
pub mod util;
pub mod zoo;
