//! # FerrisFL
//!
//! A performant library for bootstrapping federated-learning experiments —
//! a Rust + JAX + Pallas reproduction of *TorchFL* (arXiv:2211.00735).
//!
//! Three layers, python never on the request path:
//! - **L3 (this crate)** — the FL coordinator: datasets + sharding,
//!   agents, samplers, aggregators, the experiment entrypoint, loggers,
//!   profilers, and the reproduction harness for every table/figure in
//!   the paper.
//! - **L2 (python/compile, build-time)** — the JAX model zoo, AOT-lowered
//!   to HLO text by `make artifacts`.
//! - **L1 (python/compile/kernels, build-time)** — Pallas kernels for the
//!   compute hot-spots (MXU matmul/dense/conv, fused softmax-xent, FedAvg
//!   aggregation).
//!
//! Quickstart: see `examples/quickstart.rs`, or run
//! `cargo run --release -- run --config configs/quickstart.toml`.

pub mod agents;
pub mod benchutil;
pub mod aggregators;
pub mod compression;
pub mod config;
pub mod defense;
pub mod datasets;
pub mod entrypoint;
pub mod federation;
pub mod incentives;
pub mod loggers;
pub mod metrics;
pub mod profiler;
pub mod repro;
pub mod runtime;
pub mod samplers;
pub mod util;
pub mod zoo;
