//! Synthetic datasets — the datamodules substrate (paper §3.1, Table 1).
//!
//! The paper's datamodules wrap torchvision datasets; our substitute
//! (DESIGN.md Substitution #1) generates class-structured images from the
//! per-class latent templates built at artifact time:
//!
//! `sample(i) = clip(roll(template[label(i)], jitter_i) + noise_i) - 0.5`
//!
//! Labels and corruptions are derived deterministically from
//! `(dataset seed, split, index)` via split RNG streams, so any shard of
//! any dataset can be regenerated on any worker without storing data —
//! the whole "data pipeline" is O(templates) memory.

use std::collections::HashMap;

use crate::runtime::{DatasetInfo, Manifest};
use crate::util::error::{bail, Result};
use crate::util::Rng;

/// Which split a sample comes from (affects its RNG stream).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

impl Split {
    fn salt(self) -> u64 {
        match self {
            Split::Train => 0x7121,
            Split::Test => 0x7e57,
        }
    }

    fn cache_tag(self) -> u8 {
        match self {
            Split::Train => 0,
            Split::Test => 1,
        }
    }
}

/// A generated batch, laid out for the runtime ABI.
#[derive(Clone, Debug)]
pub struct Batch {
    /// `f32[n * H * W * C]`, row-major NHWC.
    pub x: Vec<f32>,
    /// `i32[n]` labels.
    pub y: Vec<i32>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// Reusable batch storage for the zero-allocation step path: hold one
/// per training/eval loop and gather every batch into it with
/// [`Dataset::gather_into`]. Buffers grow to the largest batch seen and
/// are then reused — steady-state gathering allocates nothing.
#[derive(Debug, Default)]
pub struct BatchBuf {
    x: Vec<f32>,
    y: Vec<i32>,
    /// Examples and per-example length of the last gather, so the
    /// filled window can be re-viewed after the buffer crossed a
    /// thread boundary (the synthesis pipeline's helper fills it, the
    /// training thread views it).
    last_n: usize,
    last_ex: usize,
}

impl BatchBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// View of the most recent gather into this buffer (empty before
    /// any gather).
    pub fn view(&self) -> BatchView<'_> {
        BatchView {
            x: &self.x[..self.last_n * self.last_ex],
            y: &self.y[..self.last_n],
        }
    }
}

/// A zero-copy view of the batch most recently gathered into a
/// [`BatchBuf`], laid out for the runtime ABI.
#[derive(Clone, Copy, Debug)]
pub struct BatchView<'a> {
    /// `f32[n * H * W * C]`, row-major NHWC.
    pub x: &'a [f32],
    /// `i32[n]` labels.
    pub y: &'a [i32],
}

impl BatchView<'_> {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }
}

/// Default float budget of a [`SynthCache`]: 8M floats (32 MiB) per
/// holder — enough to cache every train+test example of the largest
/// built-in dataset (synth-cifar10/100: 2560 examples × 3072 floats).
/// Set `FERRISFL_SYNTH_CACHE=0` to disable caching entirely.
const SYNTH_CACHE_FLOATS: usize = 8 << 20;

/// Worker-local cache of synthesized examples.
///
/// Sample synthesis is a pure function of `(dataset identity, split,
/// index)`, yet the per-pixel RNG makes it a visible fraction of round
/// walltime: every local epoch after the first re-synthesizes the same
/// shard, and every round's evaluation re-synthesizes the same test
/// split. Each worker thread holds one `SynthCache` keyed by the
/// dataset identity (name ⊕ seed ⊕ templates, so a different dataset or
/// epoch-seed self-invalidates); cached rows come back as a memcpy.
///
/// Insertion stops once the float budget is exhausted — shard indices
/// are stable across rounds, so first-come retention keeps exactly the
/// working set hot without eviction bookkeeping.
pub struct SynthCache {
    /// Identity of the dataset currently cached (None = empty).
    identity: Option<u64>,
    /// `(split, sample index)` → row slot.
    slots: HashMap<(u8, usize), u32>,
    x: Vec<f32>,
    y: Vec<i32>,
    ex: usize,
    max_floats: usize,
}

impl Default for SynthCache {
    fn default() -> Self {
        Self::new()
    }
}

impl SynthCache {
    pub fn new() -> Self {
        let max_floats = if crate::util::env::synth_cache_enabled() {
            SYNTH_CACHE_FLOATS
        } else {
            0
        };
        Self::with_budget(max_floats)
    }

    /// A cache bounded to `max_floats` stored floats (0 disables it).
    pub fn with_budget(max_floats: usize) -> Self {
        Self {
            identity: None,
            slots: HashMap::new(),
            x: Vec::new(),
            y: Vec::new(),
            ex: 0,
            max_floats,
        }
    }

    /// Cached examples currently held.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Point the cache at a dataset identity, clearing it on change.
    fn ensure(&mut self, identity: u64, ex: usize) {
        if self.identity != Some(identity) || self.ex != ex {
            self.identity = Some(identity);
            self.ex = ex;
            self.slots.clear();
            self.x.clear();
            self.y.clear();
        }
    }

    fn slot_of(&self, split: Split, index: usize) -> Option<u32> {
        self.slots.get(&(split.cache_tag(), index)).copied()
    }

    fn row(&self, slot: u32) -> (&[f32], i32) {
        let lo = slot as usize * self.ex;
        (&self.x[lo..lo + self.ex], self.y[slot as usize])
    }

    fn insert(&mut self, split: Split, index: usize, row: &[f32], label: i32) {
        if self.x.len() + self.ex > self.max_floats {
            return; // budget full: keep the resident working set
        }
        let slot = self.y.len() as u32;
        self.x.extend_from_slice(row);
        self.y.push(label);
        self.slots.insert((split.cache_tag(), index), slot);
    }
}

/// A synthetic dataset: templates + deterministic sample synthesis.
pub struct Dataset {
    pub info: DatasetInfo,
    /// `f32[num_classes * H * W * C]` class templates.
    templates: Vec<f32>,
    seed: u64,
    /// Hash of (name, seed, templates): the identity a [`SynthCache`]
    /// is keyed by, so caches self-invalidate across datasets.
    identity: u64,
}

fn dataset_identity(name: &str, seed: u64, templates: &[f32]) -> u64 {
    let mut h = crate::runtime::native::fnv1a(name) ^ seed.rotate_left(17);
    for &t in templates {
        h ^= t.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Procedural class templates for manifests with no template files (the
/// native backend): per-class gaussian patterns around mid-grey, seeded
/// by the dataset *name* so templates are a fixed property of the
/// dataset — independent of run seeds, workers, and threads (the native
/// analogue of the template files `make artifacts` writes).
pub fn native_templates(info: &DatasetInfo) -> Vec<f32> {
    let ex = info.example_len();
    let mut templates = Vec::with_capacity(info.num_classes * ex);
    let base = Rng::new(crate::runtime::native::fnv1a(&info.name) ^ 0x7e3);
    for class in 0..info.num_classes {
        let mut r = base.split(class as u64);
        for _ in 0..ex {
            templates.push((0.5 + 0.35 * r.next_gaussian()).clamp(0.0, 1.0));
        }
    }
    templates
}

impl Dataset {
    /// Load the templates for `name`: from the artifact directory, or
    /// synthesised procedurally when the manifest carries no template
    /// file (the native backend).
    pub fn load(manifest: &Manifest, name: &str, seed: u64) -> Result<Self> {
        let info = manifest.dataset(name)?.clone();
        let templates = if info.template_file.is_empty() {
            native_templates(&info)
        } else {
            manifest.read_f32(&info.template_file)?
        };
        let want = info.num_classes * info.example_len();
        if templates.len() != want {
            bail!(
                "{name}: template file has {} floats, want {want}",
                templates.len()
            );
        }
        let identity = dataset_identity(&info.name, seed, &templates);
        Ok(Self {
            info,
            templates,
            seed,
            identity,
        })
    }

    /// Build a dataset from raw parts (tests / benches).
    pub fn from_parts(info: DatasetInfo, templates: Vec<f32>, seed: u64) -> Self {
        let identity = dataset_identity(&info.name, seed, &templates);
        Self {
            info,
            templates,
            seed,
            identity,
        }
    }

    pub fn num_train(&self) -> usize {
        self.info.train_n
    }

    pub fn num_test(&self) -> usize {
        self.info.test_n
    }

    /// Label of sample `index` in `split`.
    ///
    /// Labels are a deterministic pseudo-random function of the index, so
    /// the *global* class distribution is uniform — matching the balanced
    /// datasets in paper Table 1 (MNIST/CIFAR are class-balanced).
    pub fn label(&self, split: Split, index: usize) -> usize {
        let mut r = Rng::new(self.seed ^ split.salt()).split(index as u64);
        r.next_below(self.info.num_classes as u64) as usize
    }

    /// All labels of a split (used by the federation layer for sharding).
    pub fn labels(&self, split: Split) -> Vec<usize> {
        let n = match split {
            Split::Train => self.info.train_n,
            Split::Test => self.info.test_n,
        };
        (0..n).map(|i| self.label(split, i)).collect()
    }

    /// Synthesize sample `index` of `split` into `out` (len H*W*C).
    ///
    /// Two passes, restructured for the SIMD layer but **bit-identical**
    /// to the original per-pixel loop (pinned by
    /// `restructured_synthesis_matches_pixelwise_reference`):
    ///
    /// 1. the torus-rolled template is copied row-wise (two contiguous
    ///    segments per row instead of a per-pixel `rem_euclid` gather);
    /// 2. noise + clamp run as one linear pass over `out` through the
    ///    dispatched `runtime::simd` noise kernel. The original
    ///    loop drew one gaussian per output element in linear order from
    ///    a sequential SplitMix64 stream; SplitMix64 is counter-based,
    ///    so gaussian `k` is recomputed from counter draws `2k+1`/`2k+2`
    ///    — lanes are independent, and every dispatch level reproduces
    ///    the scalar stream bit-for-bit (so `SynthCache` contents never
    ///    depend on the ISA).
    pub fn synthesize_into(&self, split: Split, index: usize, out: &mut [f32]) {
        let ex = self.info.example_len();
        debug_assert_eq!(out.len(), ex);
        let label = self.label(split, index);
        // Separate stream for the corruption so label/corruption are
        // independent.
        let mut r = Rng::new(self.seed ^ split.salt() ^ 0xC0FFEE).split(index as u64);
        let (h, w, c) = (self.info.height, self.info.width, self.info.channels);
        let j = self.info.jitter;
        let dy = if j > 0 { r.range_i64(-j, j) } else { 0 };
        let dx = if j > 0 { r.range_i64(-j, j) } else { 0 };
        let tpl = &self.templates[label * ex..(label + 1) * ex];
        // torus roll, matching numpy.roll in python/compile/datagen.py:
        // out row yy = template row (yy - dy) mod h, shifted right by
        // s = dx mod w columns (with wraparound).
        let rowf = w * c;
        let s = dx.rem_euclid(w as i64) as usize;
        for yy in 0..h {
            let sy = (yy as i64 - dy).rem_euclid(h as i64) as usize;
            let srow = &tpl[sy * rowf..(sy + 1) * rowf];
            let drow = &mut out[yy * rowf..(yy + 1) * rowf];
            drow[..s * c].copy_from_slice(&srow[(w - s) * c..]);
            drow[s * c..].copy_from_slice(&srow[..(w - s) * c]);
        }
        // `r` now sits exactly where the old loop started drawing
        // per-pixel gaussians; hand its state to the counter-mode pass.
        (crate::runtime::simd::kernels().synth_noise)(out, self.info.noise, r.state());
    }

    /// Synthesize a batch for the given sample indices into `buf`,
    /// reusing its storage, and return a borrowed view. The steady-state
    /// path of `worker::run_local` and the trainers: no allocation once
    /// `buf` has seen the loop's batch size.
    pub fn gather_into<'a>(
        &self,
        split: Split,
        indices: &[usize],
        buf: &'a mut BatchBuf,
    ) -> BatchView<'a> {
        let ex = self.info.example_len();
        let need = indices.len() * ex;
        if buf.x.len() < need {
            buf.x.resize(need, 0.0);
        }
        if buf.y.len() < indices.len() {
            buf.y.resize(indices.len(), 0);
        }
        for (i, &idx) in indices.iter().enumerate() {
            self.synthesize_into(split, idx, &mut buf.x[i * ex..(i + 1) * ex]);
            buf.y[i] = self.label(split, idx) as i32;
        }
        buf.last_n = indices.len();
        buf.last_ex = ex;
        BatchView {
            x: &buf.x[..need],
            y: &buf.y[..indices.len()],
        }
    }

    /// [`Self::gather_into`] through a worker-local [`SynthCache`]:
    /// indices already synthesized on this worker are copied out of the
    /// cache (a memcpy) instead of re-running the per-pixel RNG; misses
    /// are synthesized once and then cached (until the cache's float
    /// budget fills). Results are identical to `gather_into` —
    /// synthesis is a pure function of `(identity, split, index)`.
    pub fn gather_cached<'a>(
        &self,
        split: Split,
        indices: &[usize],
        buf: &'a mut BatchBuf,
        cache: &mut SynthCache,
    ) -> BatchView<'a> {
        let ex = self.info.example_len();
        let need = indices.len() * ex;
        if buf.x.len() < need {
            buf.x.resize(need, 0.0);
        }
        if buf.y.len() < indices.len() {
            buf.y.resize(indices.len(), 0);
        }
        cache.ensure(self.identity, ex);
        for (i, &idx) in indices.iter().enumerate() {
            let row = &mut buf.x[i * ex..(i + 1) * ex];
            // Slot handle first (Copy), so the hit path's cache borrow
            // never overlaps the miss path's insertion.
            if let Some(slot) = cache.slot_of(split, idx) {
                let (cx, cy) = cache.row(slot);
                row.copy_from_slice(cx);
                buf.y[i] = cy;
            } else {
                self.synthesize_into(split, idx, row);
                let label = self.label(split, idx) as i32;
                buf.y[i] = label;
                cache.insert(split, idx, row, label);
            }
        }
        buf.last_n = indices.len();
        buf.last_ex = ex;
        BatchView {
            x: &buf.x[..need],
            y: &buf.y[..indices.len()],
        }
    }

    /// Synthesize a batch for the given sample indices (owned storage).
    pub fn batch(&self, split: Split, indices: &[usize]) -> Batch {
        let mut buf = BatchBuf::new();
        self.gather_into(split, indices, &mut buf);
        Batch { x: buf.x, y: buf.y }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_info() -> DatasetInfo {
        DatasetInfo {
            name: "tiny".into(),
            group: "TEST".into(),
            height: 4,
            width: 4,
            channels: 1,
            num_classes: 3,
            train_n: 60,
            test_n: 30,
            real_train_n: 600,
            real_test_n: 300,
            noise: 0.1,
            jitter: 1,
            template_file: "none".into(),
        }
    }

    fn tiny_dataset(seed: u64) -> Dataset {
        let info = tiny_info();
        let ex = info.example_len();
        let templates: Vec<f32> = (0..info.num_classes * ex)
            .map(|i| (i % 7) as f32 / 7.0)
            .collect();
        Dataset::from_parts(info, templates, seed)
    }

    /// The pre-SIMD synthesis loop, verbatim: one `rem_euclid` template
    /// gather and one sequential `next_gaussian` per output element.
    /// The restructured two-pass `synthesize_into` must reproduce it
    /// bit-for-bit (same RNG stream via counter-mode draws), so cached
    /// rows and golden values are unchanged by the rewrite.
    fn synthesize_reference(d: &Dataset, split: Split, index: usize, out: &mut [f32]) {
        let ex = d.info.example_len();
        let label = d.label(split, index);
        let mut r = Rng::new(d.seed ^ split.salt() ^ 0xC0FFEE).split(index as u64);
        let (h, w, c) = (d.info.height, d.info.width, d.info.channels);
        let j = d.info.jitter;
        let dy = if j > 0 { r.range_i64(-j, j) } else { 0 };
        let dx = if j > 0 { r.range_i64(-j, j) } else { 0 };
        let tpl = &d.templates[label * ex..(label + 1) * ex];
        let noise = d.info.noise;
        for yy in 0..h {
            let sy = (yy as i64 - dy).rem_euclid(h as i64) as usize;
            for xx in 0..w {
                let sx = (xx as i64 - dx).rem_euclid(w as i64) as usize;
                for ch in 0..c {
                    let v = tpl[(sy * w + sx) * c + ch] + noise * r.next_gaussian();
                    out[(yy * w + xx) * c + ch] = v.clamp(-0.5, 1.5) - 0.5;
                }
            }
        }
    }

    #[test]
    fn restructured_synthesis_matches_pixelwise_reference() {
        // Jittered, jitter-free, multi-channel, and non-square shapes;
        // both splits; a spread of indices. Bit-identical everywhere.
        let mut cases = vec![tiny_dataset(42)];
        let mut no_jitter = tiny_info();
        no_jitter.jitter = 0;
        let ex = no_jitter.example_len();
        let t: Vec<f32> = (0..no_jitter.num_classes * ex).map(|i| (i % 5) as f32 / 5.0).collect();
        cases.push(Dataset::from_parts(no_jitter, t, 7));
        let mut wide = tiny_info();
        wide.width = 7;
        wide.height = 3;
        wide.channels = 2;
        wide.jitter = 2;
        let ex = wide.example_len();
        let t: Vec<f32> = (0..wide.num_classes * ex).map(|i| (i % 9) as f32 / 9.0).collect();
        cases.push(Dataset::from_parts(wide, t, 9));
        let m = Manifest::native();
        cases.push(Dataset::load(&m, "synth-cifar10", 3).unwrap());

        for d in &cases {
            let ex = d.info.example_len();
            let mut got = vec![0.0f32; ex];
            let mut want = vec![0.0f32; ex];
            for split in [Split::Train, Split::Test] {
                for index in [0usize, 1, 13, 57] {
                    d.synthesize_into(split, index, &mut got);
                    synthesize_reference(d, split, index, &mut want);
                    let same =
                        got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits());
                    assert!(same, "{} {split:?} index {index}", d.info.name);
                }
            }
        }
    }

    #[test]
    fn synthesis_is_deterministic() {
        let d = tiny_dataset(42);
        let b1 = d.batch(Split::Train, &[0, 5, 17]);
        let b2 = d.batch(Split::Train, &[0, 5, 17]);
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn splits_are_independent() {
        let d = tiny_dataset(42);
        let tr = d.batch(Split::Train, &[3]);
        let te = d.batch(Split::Test, &[3]);
        assert_ne!(tr.x, te.x, "train/test index 3 must differ");
    }

    #[test]
    fn labels_roughly_uniform() {
        let d = tiny_dataset(7);
        let labels = d.labels(Split::Train);
        let mut counts = [0usize; 3];
        for l in labels {
            counts[l] += 1;
        }
        // 60 samples over 3 classes: each class within [10, 30].
        for (c, &n) in counts.iter().enumerate() {
            assert!((10..=30).contains(&n), "class {c}: {n}");
        }
    }

    #[test]
    fn values_in_range() {
        let d = tiny_dataset(9);
        let b = d.batch(Split::Train, &(0..20).collect::<Vec<_>>());
        assert!(b.x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
    }

    #[test]
    fn different_seeds_differ() {
        let a = tiny_dataset(1).batch(Split::Train, &[0]);
        let b = tiny_dataset(2).batch(Split::Train, &[0]);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn native_templates_are_deterministic_and_class_distinct() {
        let m = Manifest::native();
        let info = m.dataset("synth-mnist").unwrap();
        let t1 = native_templates(info);
        let t2 = native_templates(info);
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), info.num_classes * info.example_len());
        assert!(t1.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let ex = info.example_len();
        assert_ne!(t1[..ex], t1[ex..2 * ex], "classes must differ");
    }

    #[test]
    fn gather_into_reuses_storage_and_matches_batch() {
        let d = tiny_dataset(21);
        let mut buf = BatchBuf::new();
        let owned = d.batch(Split::Train, &[1, 2, 3]);
        let view = d.gather_into(Split::Train, &[1, 2, 3], &mut buf);
        assert_eq!(view.x, &owned.x[..]);
        assert_eq!(view.y, &owned.y[..]);
        assert_eq!(view.len(), 3);
        // A smaller follow-up batch reuses the same storage; the view is
        // windowed to the new batch length.
        let view = d.gather_into(Split::Train, &[7], &mut buf);
        assert_eq!(view.len(), 1);
        let single = d.batch(Split::Train, &[7]);
        assert_eq!(view.x, &single.x[..]);
        assert_eq!(view.y, &single.y[..]);
    }

    #[test]
    fn gather_cached_matches_uncached_and_hits() {
        let d = tiny_dataset(31);
        let mut buf = BatchBuf::new();
        let mut cache = SynthCache::with_budget(1 << 20);
        let idx = [3usize, 7, 3, 11];
        let want = d.batch(Split::Train, &idx);
        // Cold pass fills the cache; warm pass must be identical.
        for pass in 0..2 {
            let view = d.gather_cached(Split::Train, &idx, &mut buf, &mut cache);
            assert_eq!(view.x, &want.x[..], "pass {pass}");
            assert_eq!(view.y, &want.y[..], "pass {pass}");
        }
        assert_eq!(cache.len(), 3, "three distinct indices cached");
        // Train/test streams are distinct cache entries.
        let t = d.gather_cached(Split::Test, &[3], &mut buf, &mut cache);
        let t_want = d.batch(Split::Test, &[3]);
        assert_eq!(t.x, &t_want.x[..]);
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn synth_cache_invalidates_on_dataset_change() {
        let a = tiny_dataset(1);
        let b = tiny_dataset(2);
        let mut buf = BatchBuf::new();
        let mut cache = SynthCache::with_budget(1 << 20);
        a.gather_cached(Split::Train, &[0], &mut buf, &mut cache);
        assert_eq!(cache.len(), 1);
        // Different seed → different identity → cache resets, and the
        // gathered row matches dataset b, not stale a.
        let view = b.gather_cached(Split::Train, &[0], &mut buf, &mut cache);
        let want = b.batch(Split::Train, &[0]);
        assert_eq!(view.x, &want.x[..]);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn synth_cache_budget_caps_insertion_but_stays_correct() {
        let d = tiny_dataset(5);
        let ex = d.info.example_len();
        let mut buf = BatchBuf::new();
        // Room for exactly two rows.
        let mut cache = SynthCache::with_budget(2 * ex);
        let idx = [0usize, 1, 2, 3];
        let want = d.batch(Split::Train, &idx);
        let view = d.gather_cached(Split::Train, &idx, &mut buf, &mut cache);
        assert_eq!(view.x, &want.x[..]);
        assert_eq!(cache.len(), 2, "insertion stops at the budget");
        let view = d.gather_cached(Split::Train, &idx, &mut buf, &mut cache);
        assert_eq!(view.x, &want.x[..], "over-budget misses re-synthesize");
        // A zero-budget cache is a pure pass-through.
        let mut off = SynthCache::with_budget(0);
        let view = d.gather_cached(Split::Train, &idx, &mut buf, &mut off);
        assert_eq!(view.x, &want.x[..]);
        assert!(off.is_empty());
    }

    #[test]
    fn batchbuf_view_returns_last_gather() {
        let d = tiny_dataset(9);
        let mut buf = BatchBuf::new();
        let owned = d.batch(Split::Train, &[4, 5]);
        d.gather_into(Split::Train, &[4, 5], &mut buf);
        let view = buf.view();
        assert_eq!(view.x, &owned.x[..]);
        assert_eq!(view.y, &owned.y[..]);
        // A smaller follow-up gather re-windows the view.
        d.gather_into(Split::Train, &[6], &mut buf);
        assert_eq!(buf.view().len(), 1);
    }

    #[test]
    fn native_dataset_loads_without_files() {
        let m = Manifest::native();
        let d = Dataset::load(&m, "synth-cifar10", 7).unwrap();
        let b = d.batch(Split::Train, &[0, 1, 2]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.x.len(), 3 * d.info.example_len());
        assert!(b.x.iter().all(|v| v.is_finite()));
    }
}
