//! Aggregators — combining agent updates into the global model
//! (paper §3.2.3, Eq. 2).
//!
//! TorchFL ships FedAvg and FedSGD plus a customisation interface. We
//! implement those, two server-side-optimizer variants (FedOpt family),
//! and two Byzantine-robust rules the paper cites as motivating
//! extensions (poisoning defenses):
//!
//! - [`FedAvg`] — sample-weighted averaging (Eq. 2). Optionally offloads
//!   the weighted sum to the executor backend (the multithreaded native
//!   path, or the L1 Pallas kernel under PJRT); a pure-rust reference
//!   ([`fedavg_host`]) backs property tests and benches.
//! - [`FedSgd`] — equal-weight averaging (the FedSGD limit: one local
//!   step, gradients ≈ deltas).
//! - [`FedAvgM`] — server momentum over the aggregated pseudo-gradient.
//! - [`FedAdam`] — server Adam over the aggregated pseudo-gradient.
//! - [`CoordinateMedian`] — coordinate-wise median of deltas.
//! - [`TrimmedMean`] — coordinate-wise β-trimmed mean.
//! - [`SketchMedian`] / [`SketchTrimmedMean`] / [`GeoMedian`] —
//!   streaming-capable robust rules ([`robust`]): fixed per-coordinate
//!   memory independent of K, so Byzantine defense no longer forces
//!   the materialized K×P path.

pub mod robust;
pub mod streaming;

pub use robust::{GeoMedian, SketchMedian, SketchTrimmedMean, GEOMEDIAN_RESERVOIR};
pub use streaming::{
    delta_checksum, quantize_weighted, quantized_checksum, StreamingAccumulator,
};

use crate::runtime::ModelExecutor;
use crate::util::error::{bail, Result};

/// One agent's contribution to a round.
#[derive(Clone, Debug)]
pub struct Update {
    pub agent_id: usize,
    /// `delta_i = W_i^{t+1} - W^t` (Eq. 1), flat.
    pub delta: Vec<f32>,
    /// Local sample count (FedAvg weighting).
    pub num_samples: usize,
}

/// How a rule weights updates when reduced incrementally through a
/// [`StreamingAccumulator`] (the integer weight numerator per update;
/// the accumulator divides by the total at finalize).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamKind {
    /// Weight each update by its sample count (Eq. 2's Γ).
    SampleWeighted,
    /// Weight every update equally (the FedSGD limit).
    Uniform,
}

/// Strategy interface for the server-side aggregation rule.
pub trait Aggregator: Send {
    /// Produce the next global parameter vector.
    ///
    /// `rt` is the leader's executor: rules that are a weighted sum can
    /// route it through the backend's aggregation op when it is
    /// available, and fall back to the host reference otherwise; purely
    /// host-side rules (median/trim, server optimizers) ignore it.
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>>;

    /// `Some(kind)` when this rule is a function of the weighted mean
    /// delta only, so the entrypoint may reduce updates incrementally
    /// (workers push into a [`StreamingAccumulator`] as they finish):
    /// the reduce overlaps local training, the leader's aggregation
    /// collapses to one finalize pass, and no K×P copy is made for a
    /// pool fan-out. (The entrypoint still *retains* each delta,
    /// uncopied, until round end for incentive scoring.) Robust rules
    /// (median/trimmed-mean) need every delta and return `None` — the
    /// default — to keep the materialized path.
    fn stream_kind(&self) -> Option<StreamKind> {
        None
    }

    /// Fold a streamed weighted-mean delta `Δ̄` into the next global
    /// vector. Only invoked when [`Self::stream_kind`] opted in; the
    /// default is the plain FedAvg/FedSGD update `W^{t+1} = W^t + Δ̄`.
    /// Server-optimizer rules override this with their state update
    /// (and should [`check_streamed`] first).
    fn apply_streamed(&mut self, global: &[f32], mean: &[f32]) -> Result<Vec<f32>> {
        check_streamed(global, mean)?;
        Ok(global.iter().zip(mean).map(|(g, m)| g + m).collect())
    }

    /// `true` when this rule wants to see each update individually on
    /// the streaming path via [`Self::observe_quantized`] (the sketch
    /// rules in [`robust`]). Such rules still declare a
    /// [`Self::stream_kind`]; their [`Self::apply_streamed`] ignores
    /// the accumulator mean and finalizes the observed state instead.
    fn observes_updates(&self) -> bool {
        false
    }

    /// Feed one update's fixed-point wire terms
    /// ([`quantize_weighted`]) into the rule's streaming state.
    /// `round` is the collecting round (state from another round is
    /// discarded), `agent_id` the producer, and `weight` the integer
    /// weight baked into `terms`. Only invoked when
    /// [`Self::observes_updates`]; the default is a no-op.
    fn observe_quantized(
        &mut self,
        _round: u64,
        _agent_id: u64,
        _terms: &[i64],
        _weight: u64,
    ) -> Result<()> {
        Ok(())
    }

    /// Fraction of the last aggregation's update mass the rule
    /// excluded (0 for plain averaging) — surfaced per round as the
    /// `trimmed_frac` metric.
    fn trimmed_frac(&self) -> f64 {
        0.0
    }

    fn name(&self) -> &'static str;
}

/// Shape validation shared by every [`Aggregator::apply_streamed`]
/// implementation.
pub fn check_streamed(global: &[f32], mean: &[f32]) -> Result<()> {
    if mean.len() != global.len() {
        bail!(
            "streamed mean has {} params, global has {}",
            mean.len(),
            global.len()
        );
    }
    Ok(())
}

fn check(global: &[f32], updates: &[Update]) -> Result<()> {
    if updates.is_empty() {
        bail!("aggregate called with no updates");
    }
    for u in updates {
        if u.delta.len() != global.len() {
            bail!(
                "agent {} delta has {} params, global has {}",
                u.agent_id,
                u.delta.len(),
                global.len()
            );
        }
    }
    Ok(())
}

/// Sample-count weights normalised to the simplex (Γ in Eq. 2).
pub fn sample_weights(updates: &[Update]) -> Vec<f32> {
    let total: f64 = updates.iter().map(|u| u.num_samples as f64).sum();
    if total <= 0.0 {
        // all-zero sample counts: fall back to uniform
        return vec![1.0 / updates.len() as f32; updates.len()];
    }
    updates
        .iter()
        .map(|u| (u.num_samples as f64 / total) as f32)
        .collect()
}

/// Host-side reference for the weighted sum: `global + Σ w_i · delta_i`.
/// Property tests assert it matches the PJRT/Pallas path to 1e-5.
pub fn fedavg_host(global: &[f32], updates: &[Update], weights: &[f32]) -> Vec<f32> {
    let mut out = global.to_vec();
    for (u, &w) in updates.iter().zip(weights) {
        for (o, &d) in out.iter_mut().zip(&u.delta) {
            *o += w * d;
        }
    }
    out
}

/// FedAvg (Eq. 2): sample-weighted averaging.
///
/// Two execution paths, selected by `offload`:
/// - **host** (default): the straight rust loop. §Perf measured the
///   CPU-interpret Pallas path at 160x slower than this loop (14 ms vs
///   0.09 ms at P=102k; 775 ms vs 1.8 ms at P=1.1M) — on CPU the
///   kernel's K_pad x P marshalling + interpret grid loop dominates, so
///   the host loop is the honest hot path for small cohorts.
/// - **offload** (`fedavg-offload`, alias `fedavg-pjrt`): the backend's
///   aggregation op — the multithreaded native path, or the L1 Pallas
///   kernel under PJRT; property-tested against the host loop (1e-5).
#[derive(Default)]
pub struct FedAvg {
    pub offload: bool,
}

impl Aggregator for FedAvg {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        let weights = sample_weights(updates);
        match (self.offload, rt) {
            (true, Some(rt)) => {
                let deltas: Vec<Vec<f32>> =
                    updates.iter().map(|u| u.delta.clone()).collect();
                rt.aggregate(global, &deltas, &weights)
            }
            _ => Ok(fedavg_host(global, updates, &weights)),
        }
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        // The offload variant exists to exercise the backend's
        // aggregation op; keep it on the materialized path.
        (!self.offload).then_some(StreamKind::SampleWeighted)
    }

    fn name(&self) -> &'static str {
        "fedavg"
    }
}

/// FedSGD: equal-weight averaging.
#[derive(Default)]
pub struct FedSgd;

impl Aggregator for FedSgd {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        let w = 1.0 / updates.len() as f32;
        let weights = vec![w; updates.len()];
        match rt {
            Some(rt) => {
                let deltas: Vec<Vec<f32>> =
                    updates.iter().map(|u| u.delta.clone()).collect();
                rt.aggregate(global, &deltas, &weights)
            }
            None => Ok(fedavg_host(global, updates, &weights)),
        }
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        Some(StreamKind::Uniform)
    }

    fn name(&self) -> &'static str {
        "fedsgd"
    }
}

/// Server momentum (FedAvgM): `v ← β v + Δ̄`, `W ← W + η v`.
pub struct FedAvgM {
    pub beta: f32,
    pub server_lr: f32,
    velocity: Vec<f32>,
}

impl FedAvgM {
    pub fn new(beta: f32, server_lr: f32) -> Self {
        Self {
            beta,
            server_lr,
            velocity: Vec::new(),
        }
    }

    /// The momentum update over a mean pseudo-gradient, shared by the
    /// materialized and streamed paths.
    fn apply(&mut self, global: &[f32], mean: &[f32]) -> Vec<f32> {
        if self.velocity.len() != global.len() {
            self.velocity = vec![0.0; global.len()];
        }
        let mut out = global.to_vec();
        for i in 0..global.len() {
            self.velocity[i] = self.beta * self.velocity[i] + mean[i];
            out[i] += self.server_lr * self.velocity[i];
        }
        out
    }
}

impl Aggregator for FedAvgM {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        let weights = sample_weights(updates);
        // mean delta (pseudo-gradient), host side — the momentum state
        // lives here anyway.
        let mut mean = vec![0.0f32; global.len()];
        for (u, &w) in updates.iter().zip(&weights) {
            for (m, &d) in mean.iter_mut().zip(&u.delta) {
                *m += w * d;
            }
        }
        Ok(self.apply(global, &mean))
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        Some(StreamKind::SampleWeighted)
    }

    fn apply_streamed(&mut self, global: &[f32], mean: &[f32]) -> Result<Vec<f32>> {
        check_streamed(global, mean)?;
        Ok(self.apply(global, mean))
    }

    fn name(&self) -> &'static str {
        "fedavgm"
    }
}

/// Server Adam (FedAdam, Reddi et al.): Adam over the pseudo-gradient.
pub struct FedAdam {
    pub server_lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl FedAdam {
    pub fn new(server_lr: f32) -> Self {
        Self {
            server_lr,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    /// The Adam update over a mean pseudo-gradient, shared by the
    /// materialized and streamed paths.
    fn apply(&mut self, global: &[f32], g: &[f32]) -> Vec<f32> {
        if self.m.len() != global.len() {
            self.m = vec![0.0; global.len()];
            self.v = vec![0.0; global.len()];
            self.t = 0;
        }
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t);
        let bc2 = 1.0 - self.b2.powi(self.t);
        let mut out = global.to_vec();
        for i in 0..global.len() {
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g[i];
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g[i] * g[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            out[i] += self.server_lr * mhat / (vhat.sqrt() + self.eps);
        }
        out
    }
}

impl Aggregator for FedAdam {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        let weights = sample_weights(updates);
        let mut g = vec![0.0f32; global.len()];
        for (u, &w) in updates.iter().zip(&weights) {
            for (gi, &d) in g.iter_mut().zip(&u.delta) {
                *gi += w * d;
            }
        }
        Ok(self.apply(global, &g))
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        Some(StreamKind::SampleWeighted)
    }

    fn apply_streamed(&mut self, global: &[f32], mean: &[f32]) -> Result<Vec<f32>> {
        check_streamed(global, mean)?;
        Ok(self.apply(global, mean))
    }

    fn name(&self) -> &'static str {
        "fedadam"
    }
}

/// Coordinate-wise median of the deltas — robust to up to
/// ⌊(K-1)/2⌋ poisoned updates.
#[derive(Default)]
pub struct CoordinateMedian {
    /// Column scratch, reused across the P-loop and across rounds so
    /// the rule does one (re)allocation per cohort size, not P per
    /// round.
    col: Vec<f32>,
    last_trimmed: f64,
}

impl Aggregator for CoordinateMedian {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        let k = updates.len();
        let mut out = global.to_vec();
        self.col.resize(k, 0.0);
        for i in 0..global.len() {
            for (j, u) in updates.iter().enumerate() {
                self.col[j] = u.delta[i];
            }
            self.col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = if k % 2 == 1 {
                self.col[k / 2]
            } else {
                0.5 * (self.col[k / 2 - 1] + self.col[k / 2])
            };
            out[i] += med;
        }
        // A median keeps the middle rank(s); report the rest as
        // excluded mass.
        self.last_trimmed = (k as f64 - 1.0) / k as f64;
        Ok(out)
    }

    fn trimmed_frac(&self) -> f64 {
        self.last_trimmed
    }

    fn name(&self) -> &'static str {
        "median"
    }
}

/// Coordinate-wise β-trimmed mean: drop the ⌊βK⌋ largest and smallest
/// values per coordinate, average the rest.
pub struct TrimmedMean {
    pub beta: f64,
    /// Column scratch, reused across the P-loop and across rounds (see
    /// [`CoordinateMedian`]).
    col: Vec<f32>,
    last_trimmed: f64,
}

impl TrimmedMean {
    pub fn new(beta: f64) -> Self {
        assert!((0.0..0.5).contains(&beta), "beta must be in [0, 0.5)");
        Self {
            beta,
            col: Vec::new(),
            last_trimmed: 0.0,
        }
    }
}

impl Aggregator for TrimmedMean {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        let k = updates.len();
        let trim = ((k as f64) * self.beta).floor() as usize;
        if 2 * trim >= k {
            bail!("trimmed mean would drop all {k} updates (beta={})", self.beta);
        }
        let kept = k - 2 * trim;
        let mut out = global.to_vec();
        self.col.resize(k, 0.0);
        for i in 0..global.len() {
            for (j, u) in updates.iter().enumerate() {
                self.col[j] = u.delta[i];
            }
            self.col.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let s: f32 = self.col[trim..k - trim].iter().sum();
            out[i] += s / kept as f32;
        }
        self.last_trimmed = 2.0 * trim as f64 / k as f64;
        Ok(out)
    }

    fn trimmed_frac(&self) -> f64 {
        self.last_trimmed
    }

    fn name(&self) -> &'static str {
        "trimmed-mean"
    }
}

/// The aggregator name grammar — the one source of truth behind
/// [`from_name`]'s doc, its error text, and the CLI help, so the three
/// can't drift as rules are added.
pub const AGGREGATOR_HELP: &str = "fedavg | fedavg-offload | fedsgd | fedavgm[:beta,lr] | \
     fedadam[:lr] | median | trim[:beta] | sketch-median | sketch-trim[:beta] | \
     geomedian[:reservoir]";

/// Build an aggregator from its config name; the grammar is
/// [`AGGREGATOR_HELP`].
pub fn from_name(name: &str) -> Result<Box<dyn Aggregator>> {
    let t = name.trim().to_ascii_lowercase();
    match t.as_str() {
        "fedavg" => return Ok(Box::new(FedAvg::default())),
        // "fedavg-pjrt" kept as a config-compat alias for offload.
        "fedavg-offload" | "fedavg-pjrt" => return Ok(Box::new(FedAvg { offload: true })),
        "fedsgd" => return Ok(Box::new(FedSgd)),
        "median" => return Ok(Box::new(CoordinateMedian::default())),
        "fedavgm" => return Ok(Box::new(FedAvgM::new(0.9, 1.0))),
        "fedadam" => return Ok(Box::new(FedAdam::new(0.01))),
        "trim" => return Ok(Box::new(TrimmedMean::new(0.1))),
        "sketch-median" => return Ok(Box::new(SketchMedian::default())),
        "sketch-trim" => return Ok(Box::new(SketchTrimmedMean::new(0.1))),
        "geomedian" => return Ok(Box::new(GeoMedian::new(GEOMEDIAN_RESERVOIR))),
        _ => {}
    }
    if let Some(rest) = t.strip_prefix("fedavgm:") {
        let parts: Vec<&str> = rest.split(',').collect();
        if parts.len() != 2 {
            bail!("fedavgm:<beta>,<server_lr>");
        }
        return Ok(Box::new(FedAvgM::new(parts[0].parse()?, parts[1].parse()?)));
    }
    if let Some(rest) = t.strip_prefix("fedadam:") {
        return Ok(Box::new(FedAdam::new(rest.parse()?)));
    }
    if let Some(rest) = t.strip_prefix("trim:") {
        let beta: f64 = rest.parse()?;
        if !(0.0..0.5).contains(&beta) {
            bail!("trim fraction must be in [0, 0.5), got {beta}");
        }
        return Ok(Box::new(TrimmedMean::new(beta)));
    }
    if let Some(rest) = t.strip_prefix("sketch-trim:") {
        let beta: f64 = rest.parse()?;
        if !(0.0..0.5).contains(&beta) {
            bail!("trim fraction must be in [0, 0.5), got {beta}");
        }
        return Ok(Box::new(SketchTrimmedMean::new(beta)));
    }
    if let Some(rest) = t.strip_prefix("geomedian:") {
        let r: usize = rest.parse()?;
        if r == 0 {
            bail!("geomedian reservoir must be >= 1");
        }
        return Ok(Box::new(GeoMedian::new(r)));
    }
    bail!("unknown aggregator {name:?} ({AGGREGATOR_HELP})")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(id: usize, delta: Vec<f32>, n: usize) -> Update {
        Update {
            agent_id: id,
            delta,
            num_samples: n,
        }
    }

    #[test]
    fn sample_weights_normalised() {
        let ups = vec![
            upd(0, vec![0.0], 10),
            upd(1, vec![0.0], 30),
            upd(2, vec![0.0], 60),
        ];
        let w = sample_weights(&ups);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((w[2] / w[0] - 6.0).abs() < 1e-4);
    }

    #[test]
    fn sample_weights_zero_counts_fall_back_to_uniform() {
        let ups = vec![upd(0, vec![], 0), upd(1, vec![], 0)];
        let w = sample_weights(&ups);
        assert_eq!(w, vec![0.5, 0.5]);
    }

    #[test]
    fn fedavg_host_weighted_sum() {
        let global = vec![1.0, 2.0];
        let ups = vec![upd(0, vec![1.0, 0.0], 1), upd(1, vec![0.0, 2.0], 3)];
        let w = sample_weights(&ups);
        let out = fedavg_host(&global, &ups, &w);
        assert!((out[0] - 1.25).abs() < 1e-6);
        assert!((out[1] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn fedavg_weights_by_sample_count() {
        let global = vec![0.0];
        let ups = vec![upd(0, vec![1.0], 90), upd(1, vec![-1.0], 10)];
        let out = FedAvg::default().aggregate(&global, &ups, None).unwrap();
        assert!((out[0] - 0.8).abs() < 1e-5, "{out:?}");
    }

    #[test]
    fn fedsgd_weights_equally() {
        let global = vec![0.0];
        let ups = vec![upd(0, vec![1.0], 90), upd(1, vec![-1.0], 10)];
        let out = FedSgd.aggregate(&global, &ups, None).unwrap();
        assert!(out[0].abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn fedavgm_accumulates_momentum() {
        let global = vec![0.0];
        let ups = vec![upd(0, vec![1.0], 1)];
        let mut m = FedAvgM::new(0.9, 1.0);
        let g1 = m.aggregate(&global, &ups, None).unwrap();
        assert!((g1[0] - 1.0).abs() < 1e-6);
        // Same delta again: velocity = 0.9*1 + 1 = 1.9 on top of g1.
        let g2 = m.aggregate(&g1, &ups, None).unwrap();
        assert!((g2[0] - (1.0 + 1.9)).abs() < 1e-5, "{g2:?}");
    }

    #[test]
    fn fedadam_first_step_is_lr_sized() {
        let global = vec![0.0; 3];
        let ups = vec![upd(0, vec![0.5, -0.5, 0.25], 1)];
        let mut a = FedAdam::new(0.01);
        let out = a.aggregate(&global, &ups, None).unwrap();
        // Adam's first step has magnitude ~lr regardless of grad scale.
        for (i, &v) in out.iter().enumerate() {
            assert!((v.abs() - 0.01).abs() < 1e-4, "coord {i}: {v}");
        }
        assert!(out[1] < 0.0);
    }

    #[test]
    fn median_ignores_single_poisoned_delta() {
        let global = vec![0.0; 4];
        let mut ups: Vec<Update> =
            (0..4).map(|i| upd(i, vec![0.1; 4], 1)).collect();
        ups.push(upd(4, vec![1e6; 4], 1)); // poisoned
        let mut med = CoordinateMedian::default();
        let out = med.aggregate(&global, &ups, None).unwrap();
        assert!(out.iter().all(|&v| (v - 0.1).abs() < 1e-5), "{out:?}");
        assert!((med.trimmed_frac() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn median_even_count_averages_middle() {
        let global = vec![0.0];
        let ups = vec![
            upd(0, vec![1.0], 1),
            upd(1, vec![2.0], 1),
            upd(2, vec![3.0], 1),
            upd(3, vec![4.0], 1),
        ];
        let out = CoordinateMedian::default().aggregate(&global, &ups, None).unwrap();
        assert!((out[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let global = vec![0.0; 2];
        let ups = vec![
            upd(0, vec![-100.0, -100.0], 1),
            upd(1, vec![0.2, 0.2], 1),
            upd(2, vec![0.2, 0.2], 1),
            upd(3, vec![100.0, 100.0], 1),
        ];
        let mut tm = TrimmedMean::new(0.25);
        let out = tm.aggregate(&global, &ups, None).unwrap();
        assert!(out.iter().all(|&v| (v - 0.2).abs() < 1e-5), "{out:?}");
        assert!((tm.trimmed_frac() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mismatched_delta_len_is_error() {
        let global = vec![0.0; 3];
        let ups = vec![upd(0, vec![1.0], 1)];
        assert!(FedAvg::default().aggregate(&global, &ups, None).is_err());
    }

    #[test]
    fn empty_updates_is_error() {
        assert!(FedAvg::default().aggregate(&[0.0], &[], None).is_err());
    }

    #[test]
    fn from_name_parses_all() {
        for n in [
            "fedavg", "fedavg-offload", "fedavg-pjrt", "fedsgd", "fedavgm",
            "fedavgm:0.9,1.0", "fedadam", "fedadam:0.05", "median", "trim", "trim:0.2",
            "sketch-median", "sketch-trim", "sketch-trim:0.3", "geomedian", "geomedian:16",
        ] {
            assert!(from_name(n).is_ok(), "{n}");
        }
        assert!(from_name("bogus").is_err());
        assert!(from_name("fedavgm:1").is_err());
        // Out-of-range knobs are config errors, not panics.
        assert!(from_name("trim:0.5").is_err());
        assert!(from_name("sketch-trim:0.7").is_err());
        assert!(from_name("geomedian:0").is_err());
        // The rejection text carries the full grammar so it can't
        // drift from the help string.
        let err = from_name("bogus").unwrap_err().to_string();
        assert!(err.contains(AGGREGATOR_HELP), "{err}");
    }

    #[test]
    #[should_panic]
    fn trimmed_mean_rejects_bad_beta() {
        TrimmedMean::new(0.5);
    }

    // ------------------------------------------------ streaming parity

    /// Reduce `ups` through a [`StreamingAccumulator`] the way the
    /// entrypoint does for `agg`'s stream kind.
    fn stream_through(agg: &mut dyn Aggregator, global: &[f32], ups: &[Update]) -> Vec<f32> {
        let kind = agg.stream_kind().expect("rule must stream");
        let acc = StreamingAccumulator::new(global.len());
        let total: u64 = ups.iter().map(|u| u.num_samples as u64).sum();
        for u in ups {
            let w = match kind {
                StreamKind::SampleWeighted if total > 0 => u.num_samples as u64,
                _ => 1,
            };
            acc.push(&u.delta, w).unwrap();
        }
        agg.apply_streamed(global, &acc.finalize().unwrap()).unwrap()
    }

    /// Every FedAvg-family rule produces the same next global whether
    /// the cohort is materialized or streamed (within float tolerance),
    /// including across stateful rounds for the server optimizers.
    #[test]
    fn streamed_rules_match_materialized_across_rounds() {
        let mut rng = crate::util::Rng::new(0x51ab);
        let p = 400usize;
        // Deltas bounded away from zero: FedAdam's t=1 update is
        // ±lr·sign(ḡ), so a coordinate mean straddling zero would turn
        // an O(1e-9) accumulation-order difference into a 2·lr one.
        let make = |rng: &mut crate::util::Rng| -> Vec<Update> {
            (0..5)
                .map(|i| {
                    let delta = (0..p)
                        .map(|_| 0.005 + 0.02 * rng.next_gaussian().abs())
                        .collect();
                    upd(i, delta, 3 + i * 4)
                })
                .collect()
        };
        for name in ["fedavg", "fedsgd", "fedavgm", "fedadam"] {
            let mut mat = from_name(name).unwrap();
            let mut st = from_name(name).unwrap();
            let mut g_mat: Vec<f32> = (0..p).map(|_| rng.next_gaussian() * 0.1).collect();
            let mut g_st = g_mat.clone();
            for round in 0..3 {
                let ups = make(&mut rng);
                g_mat = mat.aggregate(&g_mat, &ups, None).unwrap();
                g_st = stream_through(st.as_mut(), &g_st, &ups);
                for (j, (a, b)) in g_mat.iter().zip(&g_st).enumerate() {
                    let tol = 2e-5 * a.abs().max(1.0);
                    assert!(
                        (a - b).abs() <= tol,
                        "{name} round {round} coord {j}: materialized {a} vs streamed {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn stream_kinds_are_as_designed() {
        assert_eq!(FedAvg::default().stream_kind(), Some(StreamKind::SampleWeighted));
        assert_eq!(FedAvg { offload: true }.stream_kind(), None);
        assert_eq!(FedSgd.stream_kind(), Some(StreamKind::Uniform));
        assert_eq!(FedAvgM::new(0.9, 1.0).stream_kind(), Some(StreamKind::SampleWeighted));
        assert_eq!(FedAdam::new(0.01).stream_kind(), Some(StreamKind::SampleWeighted));
        assert_eq!(CoordinateMedian::default().stream_kind(), None);
        assert_eq!(TrimmedMean::new(0.1).stream_kind(), None);
        // The sketch rules stream (uniform weights) and observe every
        // update; the exact robust rules stay materialized.
        for name in ["sketch-median", "sketch-trim:0.2", "geomedian:8"] {
            let a = from_name(name).unwrap();
            assert_eq!(a.stream_kind(), Some(StreamKind::Uniform), "{name}");
            assert!(a.observes_updates(), "{name}");
        }
        assert!(!FedAvg::default().observes_updates());
        assert!(!CoordinateMedian::default().observes_updates());
    }

    #[test]
    fn apply_streamed_checks_shape() {
        let mut a = FedAvg::default();
        assert!(a.apply_streamed(&[0.0; 3], &[0.0; 2]).is_err());
        let out = a.apply_streamed(&[1.0, 2.0], &[0.5, -0.5]).unwrap();
        assert_eq!(out, vec![1.5, 1.5]);
    }
}
