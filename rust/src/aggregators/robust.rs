//! Streaming-capable Byzantine-robust aggregation rules.
//!
//! The exact robust rules ([`CoordinateMedian`], [`TrimmedMean`]) need
//! every delta at once, which forces the K×P materialized path — the
//! memory wall this module breaks. The rules here consume updates one
//! at a time through [`Aggregator::observe_quantized`] into **fixed
//! per-coordinate state whose size is independent of K**:
//!
//! - [`SketchMedian`] — coordinate-wise median over a per-coordinate
//!   octave histogram ([`QuantileSketch`]).
//! - [`SketchTrimmedMean`] — coordinate-wise β-trimmed mean over the
//!   same sketch.
//! - [`GeoMedian`] — approximate geometric median: Weiszfeld iteration
//!   over a bounded, deterministically-sampled reservoir of deltas.
//!
//! ## Determinism contract
//!
//! Observations are the streaming reduce's own fixed-point wire terms
//! ([`quantize_weighted`] at weight 1 — these rules are
//! [`StreamKind::Uniform`]), so the engine path (deltas quantized
//! locally) and the distributed path (terms received off the wire in
//! `transport/leader.rs`) feed bit-identical integers. Sketch state is
//! purely integral and commutative (bucket counts + shifted sums), and
//! the reservoir selects by a pure priority hash of
//! `(round, agent_id)`, so the finalized model is bit-identical under
//! any arrival order, at any worker count, in any topology.
//!
//! ## Accuracy contract
//!
//! The sketch buckets magnitudes by octave (factor-of-two bands) on the
//! 2⁻⁴⁰ fixed-point grid, with a near-zero band below ~2.4e-4. The
//! median estimate is the mean of the bucket containing the median
//! rank, so its error is bounded by that bucket's width: at most a
//! factor of 2 in magnitude plus the near-zero band —
//! `|sketch − exact| ≤ |exact| + 2.4e-4` coordinate-wise, and exact
//! when the rank-adjacent updates agree (point masses). The trimmed
//! mean prorates partially-trimmed buckets by kept fraction. The
//! geometric median is exact up to Weiszfeld convergence whenever
//! K ≤ the reservoir size, and a subsample approximation beyond it.

use super::streaming::{FX_SCALE, FX_TERM_LIMIT};
use super::{check, check_streamed, Aggregator, StreamKind, Update};
use crate::runtime::ModelExecutor;
use crate::util::error::{bail, Result};
use crate::util::rng::splitmix64_mix;

#[cfg(doc)]
use super::{quantize_weighted, CoordinateMedian, TrimmedMean};

/// Magnitudes below `2^SKETCH_MIN_BITS` on the grid (≈ 2.4e-4 in delta
/// units) collapse into one near-zero band.
const SKETCH_MIN_BITS: u32 = 28;
/// Magnitudes at or above `2^SKETCH_MAX_BITS` (≈ 128.0 in delta units)
/// share the top octave.
const SKETCH_MAX_BITS: u32 = 47;
const SKETCH_OCTAVES: usize = (SKETCH_MAX_BITS - SKETCH_MIN_BITS + 1) as usize;
/// Buckets per coordinate: a signed octave pair per band + the
/// near-zero band. Fixed — this is what makes sketch memory
/// independent of K.
pub const SKETCH_BUCKETS: usize = 2 * SKETCH_OCTAVES + 1;
/// Per-bucket sums store `term >> SUM_SHIFT` so K updates of the
/// largest representable term stay within i64 (saturating on overflow).
/// Costs 2⁻²⁴ ≈ 6e-8 of delta resolution per term — noise next to the
/// octave width.
const SUM_SHIFT: u32 = 16;

/// Salt for the reservoir priority hash (b"GEOM").
const GEO_SALT: u64 = 0x4745_4F4D;

/// Default Weiszfeld reservoir size.
pub const GEOMEDIAN_RESERVOIR: usize = 32;
const WEISZFELD_ITERS: usize = 64;
const WEISZFELD_EPS: f64 = 1e-12;

/// Quantize one delta coordinate exactly as [`quantize_weighted`] does
/// at weight 1, so the materialized `aggregate()` path observes the
/// same integers the streamed path receives off the wire.
fn quantize1(d: f32) -> Result<i64> {
    if !d.is_finite() {
        bail!("non-finite delta term {d}");
    }
    let scaled = (d as f64).clamp(-FX_TERM_LIMIT, FX_TERM_LIMIT) * FX_SCALE;
    match i64::try_from(scaled as i128) {
        Ok(v) => Ok(v),
        Err(_) => bail!("delta term {d} overflows the fixed-point grid"),
    }
}

/// Undo the wire weight: round-half-away-from-zero division, so both
/// topologies recover the identical weight-1 term from a weighted one.
/// Weight 1 (the only weight Uniform rules see in practice) is exact.
fn unweight(term: i64, weight: u64) -> i64 {
    let w = weight.max(1) as i64;
    let half = w / 2;
    if term >= 0 {
        (term + half) / w
    } else {
        (term - half) / w
    }
}

/// Ascending-value bucket index of a grid term: negative octaves
/// largest-magnitude first, then the near-zero band, then positive
/// octaves smallest-magnitude first.
fn bucket_of(v: i64) -> usize {
    let mag = v.unsigned_abs();
    if mag < (1u64 << SKETCH_MIN_BITS) {
        return SKETCH_OCTAVES;
    }
    let bits = 64 - mag.leading_zeros();
    let oct = ((bits - 1).min(SKETCH_MAX_BITS) - SKETCH_MIN_BITS) as usize;
    if v < 0 {
        SKETCH_OCTAVES - 1 - oct
    } else {
        SKETCH_OCTAVES + 1 + oct
    }
}

/// Per-coordinate octave histogram on the streaming reduce's
/// fixed-point grid: `SKETCH_BUCKETS` buckets of (count, shifted sum)
/// per coordinate. Integral and commutative, so merging observations in
/// any order yields identical state.
pub struct QuantileSketch {
    params: usize,
    /// Updates observed since the last reset.
    k: u32,
    /// Round the current state belongs to; a new round resets first.
    round: u64,
    counts: Vec<u32>,
    sums: Vec<i64>,
}

impl QuantileSketch {
    pub fn new(params: usize) -> Self {
        Self {
            params,
            k: 0,
            round: 0,
            counts: vec![0; params * SKETCH_BUCKETS],
            sums: vec![0; params * SKETCH_BUCKETS],
        }
    }

    pub fn updates(&self) -> u32 {
        self.k
    }

    /// Bytes of sketch state — a function of P only, never of K.
    pub fn state_bytes(&self) -> usize {
        self.counts.len() * std::mem::size_of::<u32>()
            + self.sums.len() * std::mem::size_of::<i64>()
    }

    fn reset(&mut self, round: u64) {
        self.k = 0;
        self.round = round;
        self.counts.fill(0);
        self.sums.fill(0);
    }

    /// Fold one update's weighted terms in. Resizes on a parameter-count
    /// change and self-heals across skipped rounds by resetting when
    /// the collecting round moves on.
    fn observe(&mut self, round: u64, terms: &[i64], weight: u64) {
        if terms.len() != self.params {
            self.params = terms.len();
            self.counts = vec![0; self.params * SKETCH_BUCKETS];
            self.sums = vec![0; self.params * SKETCH_BUCKETS];
            self.k = 0;
            self.round = round;
        } else if round != self.round {
            self.reset(round);
        }
        for (i, &t) in terms.iter().enumerate() {
            let v = unweight(t, weight);
            let slot = i * SKETCH_BUCKETS + bucket_of(v);
            self.counts[slot] += 1;
            self.sums[slot] = self.sums[slot].saturating_add(v >> SUM_SHIFT);
        }
        self.k += 1;
    }

    /// Coordinate-wise median estimate: the mean of the bucket holding
    /// the lower-middle rank `(k−1)/2`, walking buckets in ascending
    /// value order.
    fn median(&self, out: &mut Vec<f32>) {
        let unit = (1u64 << SUM_SHIFT) as f64 / FX_SCALE;
        let rank = u64::from((self.k - 1) / 2);
        out.clear();
        for i in 0..self.params {
            let row = i * SKETCH_BUCKETS;
            let mut cum = 0u64;
            let mut med = 0.0f64;
            for b in 0..SKETCH_BUCKETS {
                let c = u64::from(self.counts[row + b]);
                if c > 0 && cum + c > rank {
                    med = self.sums[row + b] as f64 * unit / c as f64;
                    break;
                }
                cum += c;
            }
            out.push(med as f32);
        }
    }

    /// Coordinate-wise β-trimmed mean estimate: drop `⌊βk⌋` ranks off
    /// each tail, prorating partially-kept buckets by kept fraction.
    fn trimmed_mean(&self, beta: f64, out: &mut Vec<f32>) -> Result<usize> {
        let k = u64::from(self.k);
        let trim = (k as f64 * beta).floor() as u64;
        if 2 * trim >= k {
            bail!("trimmed mean with beta={beta} leaves no updates for k={k}");
        }
        let unit = (1u64 << SUM_SHIFT) as f64 / FX_SCALE;
        let (lo, hi) = (trim, k - trim);
        out.clear();
        for i in 0..self.params {
            let row = i * SKETCH_BUCKETS;
            let mut cum = 0u64;
            let mut total = 0.0f64;
            for b in 0..SKETCH_BUCKETS {
                let c = u64::from(self.counts[row + b]);
                if c == 0 {
                    continue;
                }
                let (b_lo, b_hi) = (cum, cum + c);
                cum = b_hi;
                let kept = b_hi.min(hi).saturating_sub(b_lo.max(lo));
                if kept == 0 {
                    continue;
                }
                total += self.sums[row + b] as f64 * unit * (kept as f64 / c as f64);
            }
            out.push((total / (hi - lo) as f64) as f32);
        }
        Ok(trim as usize)
    }
}

/// Coordinate-wise sketch median — the streaming counterpart of
/// [`CoordinateMedian`]; see the module docs for the accuracy contract.
#[derive(Default)]
pub struct SketchMedian {
    sketch: Option<QuantileSketch>,
    scratch: Vec<f32>,
    last_trimmed: f64,
}

impl SketchMedian {
    fn finalize(&mut self, global: &[f32]) -> Result<Vec<f32>> {
        let sketch = match self.sketch.as_mut() {
            Some(s) if s.updates() > 0 => s,
            _ => bail!("sketch-median finalized with no observed updates"),
        };
        let k = f64::from(sketch.updates());
        // A median keeps ~one rank per coordinate; report the rest as
        // trimmed mass, mirroring the exact rule.
        self.last_trimmed = (k - 1.0) / k;
        let mut med = std::mem::take(&mut self.scratch);
        sketch.median(&mut med);
        sketch.reset(0);
        let out = global.iter().zip(&med).map(|(g, m)| g + m).collect();
        self.scratch = med;
        Ok(out)
    }
}

impl Aggregator for SketchMedian {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        observe_materialized(self, updates)?;
        self.finalize(global)
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        Some(StreamKind::Uniform)
    }

    fn observes_updates(&self) -> bool {
        true
    }

    fn observe_quantized(
        &mut self,
        round: u64,
        _agent_id: u64,
        terms: &[i64],
        weight: u64,
    ) -> Result<()> {
        self.sketch
            .get_or_insert_with(|| QuantileSketch::new(terms.len()))
            .observe(round, terms, weight);
        Ok(())
    }

    fn apply_streamed(&mut self, global: &[f32], mean: &[f32]) -> Result<Vec<f32>> {
        check_streamed(global, mean)?;
        self.finalize(global)
    }

    fn trimmed_frac(&self) -> f64 {
        self.last_trimmed
    }

    fn name(&self) -> &'static str {
        "sketch-median"
    }
}

/// Coordinate-wise sketch β-trimmed mean — the streaming counterpart
/// of [`TrimmedMean`].
pub struct SketchTrimmedMean {
    pub beta: f64,
    sketch: Option<QuantileSketch>,
    scratch: Vec<f32>,
    last_trimmed: f64,
}

impl SketchTrimmedMean {
    pub fn new(beta: f64) -> Self {
        assert!(
            (0.0..0.5).contains(&beta),
            "trim fraction must be in [0, 0.5)"
        );
        Self {
            beta,
            sketch: None,
            scratch: Vec::new(),
            last_trimmed: 0.0,
        }
    }

    fn finalize(&mut self, global: &[f32]) -> Result<Vec<f32>> {
        let beta = self.beta;
        let sketch = match self.sketch.as_mut() {
            Some(s) if s.updates() > 0 => s,
            _ => bail!("sketch-trim finalized with no observed updates"),
        };
        let k = f64::from(sketch.updates());
        let mut mean = std::mem::take(&mut self.scratch);
        let trim = sketch.trimmed_mean(beta, &mut mean)?;
        self.last_trimmed = 2.0 * trim as f64 / k;
        sketch.reset(0);
        let out = global.iter().zip(&mean).map(|(g, m)| g + m).collect();
        self.scratch = mean;
        Ok(out)
    }
}

impl Aggregator for SketchTrimmedMean {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        observe_materialized(self, updates)?;
        self.finalize(global)
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        Some(StreamKind::Uniform)
    }

    fn observes_updates(&self) -> bool {
        true
    }

    fn observe_quantized(
        &mut self,
        round: u64,
        _agent_id: u64,
        terms: &[i64],
        weight: u64,
    ) -> Result<()> {
        self.sketch
            .get_or_insert_with(|| QuantileSketch::new(terms.len()))
            .observe(round, terms, weight);
        Ok(())
    }

    fn apply_streamed(&mut self, global: &[f32], mean: &[f32]) -> Result<Vec<f32>> {
        check_streamed(global, mean)?;
        self.finalize(global)
    }

    fn trimmed_frac(&self) -> f64 {
        self.last_trimmed
    }

    fn name(&self) -> &'static str {
        "sketch-trim"
    }
}

/// One retained delta: `(priority, agent_id, delta)`. The priority is a
/// pure hash of `(round, agent_id)`, so which updates the reservoir
/// keeps — and hence the finalized model — is independent of arrival
/// order and worker count.
type ReservoirEntry = (u64, u64, Vec<f32>);

/// Approximate geometric median: Weiszfeld iteration over a bounded
/// reservoir of at most `reservoir` deltas. Memory is `reservoir × P`,
/// independent of K; for K ≤ `reservoir` it is the exact (converged)
/// geometric median of all updates.
pub struct GeoMedian {
    pub reservoir: usize,
    round: u64,
    seen: u32,
    entries: Vec<ReservoirEntry>,
    last_trimmed: f64,
}

impl GeoMedian {
    pub fn new(reservoir: usize) -> Self {
        assert!(reservoir >= 1, "geomedian reservoir must be >= 1");
        Self {
            reservoir,
            round: 0,
            seen: 0,
            entries: Vec::new(),
            last_trimmed: 0.0,
        }
    }

    fn priority(round: u64, agent_id: u64) -> u64 {
        splitmix64_mix(splitmix64_mix(round ^ GEO_SALT) ^ agent_id)
    }

    fn reset(&mut self, round: u64) {
        self.round = round;
        self.seen = 0;
        self.entries.clear();
    }

    fn observe(&mut self, round: u64, agent_id: u64, delta: Vec<f32>) {
        if round != self.round {
            self.reset(round);
        }
        self.seen += 1;
        let entry = (Self::priority(round, agent_id), agent_id, delta);
        if self.entries.len() < self.reservoir {
            self.entries.push(entry);
            return;
        }
        // Keep the `reservoir` smallest (priority, agent) keys.
        let (worst, _) = self
            .entries
            .iter()
            .enumerate()
            .max_by_key(|(_, e)| (e.0, e.1))
            .map(|(i, e)| (i, (e.0, e.1)))
            .expect("reservoir is non-empty");
        if (entry.0, entry.1) < (self.entries[worst].0, self.entries[worst].1) {
            self.entries[worst] = entry;
        }
    }

    /// Weiszfeld fixed-point iteration in f64, from the coordinate
    /// mean. Pure arithmetic over the sorted reservoir: deterministic.
    fn weiszfeld(entries: &[ReservoirEntry]) -> Vec<f32> {
        let p = entries[0].2.len();
        let n = entries.len() as f64;
        let mut y: Vec<f64> = vec![0.0; p];
        for (_, _, x) in entries {
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi += f64::from(xi) / n;
            }
        }
        let mut next = vec![0.0f64; p];
        for _ in 0..WEISZFELD_ITERS {
            let mut wsum = 0.0f64;
            next.fill(0.0);
            for (_, _, x) in entries {
                let d2: f64 = x
                    .iter()
                    .zip(&y)
                    .map(|(&xi, yi)| (f64::from(xi) - yi).powi(2))
                    .sum();
                let w = 1.0 / d2.sqrt().max(WEISZFELD_EPS);
                wsum += w;
                for (ni, &xi) in next.iter_mut().zip(x) {
                    *ni += w * f64::from(xi);
                }
            }
            let mut moved = 0.0f64;
            for (yi, ni) in y.iter_mut().zip(&next) {
                let v = ni / wsum;
                moved += (v - *yi).powi(2);
                *yi = v;
            }
            if moved <= 1e-24 {
                break;
            }
        }
        y.iter().map(|&v| v as f32).collect()
    }

    fn finalize(&mut self, global: &[f32]) -> Result<Vec<f32>> {
        if self.entries.is_empty() {
            bail!("geomedian finalized with no observed updates");
        }
        self.entries.sort_unstable_by_key(|e| (e.0, e.1));
        let med = Self::weiszfeld(&self.entries);
        self.last_trimmed =
            f64::from(self.seen - self.entries.len() as u32) / f64::from(self.seen);
        self.reset(0);
        Ok(global.iter().zip(&med).map(|(g, m)| g + m).collect())
    }
}

impl Aggregator for GeoMedian {
    fn aggregate(
        &mut self,
        global: &[f32],
        updates: &[Update],
        _rt: Option<&dyn ModelExecutor>,
    ) -> Result<Vec<f32>> {
        check(global, updates)?;
        observe_materialized(self, updates)?;
        self.finalize(global)
    }

    fn stream_kind(&self) -> Option<StreamKind> {
        Some(StreamKind::Uniform)
    }

    fn observes_updates(&self) -> bool {
        true
    }

    fn observe_quantized(
        &mut self,
        round: u64,
        agent_id: u64,
        terms: &[i64],
        weight: u64,
    ) -> Result<()> {
        let delta: Vec<f32> = terms
            .iter()
            .map(|&t| (unweight(t, weight) as f64 / FX_SCALE) as f32)
            .collect();
        self.observe(round, agent_id, delta);
        Ok(())
    }

    fn apply_streamed(&mut self, global: &[f32], mean: &[f32]) -> Result<Vec<f32>> {
        check_streamed(global, mean)?;
        self.finalize(global)
    }

    fn trimmed_frac(&self) -> f64 {
        self.last_trimmed
    }

    fn name(&self) -> &'static str {
        "geomedian"
    }
}

/// Materialized-path shim: feed `aggregate()`'s updates through the
/// same quantize→observe pipeline the streamed path uses, so both
/// paths are bit-identical. Round 0 here is fine — observers reset on
/// finalize.
fn observe_materialized(agg: &mut dyn Aggregator, updates: &[Update]) -> Result<()> {
    let mut terms = Vec::with_capacity(updates[0].delta.len());
    for u in updates {
        terms.clear();
        for &d in &u.delta {
            terms.push(quantize1(d)?);
        }
        agg.observe_quantized(0, u.agent_id as u64, &terms, 1)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregators::{quantize_weighted, CoordinateMedian, TrimmedMean};

    fn upd(agent_id: usize, delta: Vec<f32>) -> Update {
        Update {
            agent_id,
            delta,
            num_samples: 10,
        }
    }

    #[test]
    fn quantize1_matches_the_wire_quantizer() {
        let delta = [0.5f32, -0.25, 1e-9, -3.75, 0.0, 100.0];
        let wire = quantize_weighted(&delta, 1).unwrap();
        let local: Vec<i64> = delta.iter().map(|&d| quantize1(d).unwrap()).collect();
        assert_eq!(wire, local);
    }

    #[test]
    fn bucket_order_is_ascending_in_value() {
        // Most-negative → near-zero → most-positive.
        let samples: Vec<i64> = vec![
            i64::MIN + 1,
            -(1 << 50),
            -(1 << 30),
            -(1 << 28),
            -(1 << 27),
            0,
            1 << 27,
            1 << 28,
            1 << 30,
            1 << 50,
            i64::MAX,
        ];
        let buckets: Vec<usize> = samples.iter().map(|&v| bucket_of(v)).collect();
        let mut sorted = buckets.clone();
        sorted.sort_unstable();
        assert_eq!(buckets, sorted, "bucket_of must be monotone: {buckets:?}");
        assert!(buckets.iter().all(|&b| b < SKETCH_BUCKETS));
        assert_eq!(bucket_of(0), SKETCH_OCTAVES);
    }

    #[test]
    fn sketch_median_is_exact_on_point_masses() {
        // 5 honest copies of v, 2 adversaries at -8v: the median bucket
        // holds only copies of v, so the estimate is exact (up to the
        // 2^-SUM_SHIFT grid shift).
        let v = vec![0.5f32, -0.25, 0.125];
        let mut updates: Vec<Update> = (0..5).map(|i| upd(i, v.clone())).collect();
        let poisoned: Vec<f32> = v.iter().map(|x| -8.0 * x).collect();
        updates.push(upd(5, poisoned.clone()));
        updates.push(upd(6, poisoned));
        let global = vec![0.0f32; 3];
        let out = SketchMedian::default()
            .aggregate(&global, &updates, None)
            .unwrap();
        for (o, e) in out.iter().zip(&v) {
            assert!((o - e).abs() < 1e-4, "median {o} vs exact {e}");
        }
    }

    #[test]
    fn sketch_median_tracks_exact_median_within_tolerance() {
        // Spread values across octaves; sketch error is bounded by the
        // containing bucket's width: |s - e| <= |e| + 2.5e-4.
        let k = 9;
        let p = 16;
        let mut updates = Vec::new();
        for a in 0..k {
            let delta: Vec<f32> = (0..p)
                .map(|i| {
                    let sign = if (a + i) % 2 == 0 { 1.0 } else { -1.0 };
                    sign * 0.01f32 * (1.5f32.powi(a as i32) + i as f32 * 0.1)
                })
                .collect();
            updates.push(upd(a, delta));
        }
        let global = vec![0.0f32; p];
        let sketch = SketchMedian::default()
            .aggregate(&global, &updates, None)
            .unwrap();
        let exact = CoordinateMedian::default()
            .aggregate(&global, &updates, None)
            .unwrap();
        for (s, e) in sketch.iter().zip(&exact) {
            assert!(
                (s - e).abs() <= e.abs() + 2.5e-4,
                "sketch {s} drifted from exact {e}"
            );
        }
    }

    #[test]
    fn sketch_trim_matches_exact_on_uniform_columns_and_drops_outliers() {
        // 8 honest updates sharing one value per coordinate + 2 wild
        // outliers; trim:0.2 drops exactly the outliers, and every kept
        // bucket is a point mass, so sketch == exact (up to grid shift).
        let v = vec![0.25f32, -0.5, 0.0625];
        let mut updates: Vec<Update> = (0..8).map(|i| upd(i, v.clone())).collect();
        updates.push(upd(8, vec![40.0, 40.0, 40.0]));
        updates.push(upd(9, vec![-40.0, -40.0, -40.0]));
        let global = vec![0.0f32; 3];
        let sketch = SketchTrimmedMean::new(0.2)
            .aggregate(&global, &updates, None)
            .unwrap();
        let exact = TrimmedMean::new(0.2)
            .aggregate(&global, &updates, None)
            .unwrap();
        for ((s, e), want) in sketch.iter().zip(&exact).zip(&v) {
            assert!((s - e).abs() < 1e-4, "sketch {s} vs exact {e}");
            assert!((s - want).abs() < 1e-4, "outliers leaked into {s}");
        }
    }

    #[test]
    fn robust_rules_tolerate_floor_half_sign_flips_where_fedavg_flips() {
        // The Byzantine tolerance property: with ⌊(K−1)/2⌋ = 4 of K = 9
        // updates sign-flipped and scaled (−9×), every robust rule
        // still recovers the honest value, while the FedAvg mean
        // points the *opposite* way — (5·v − 36·v)/9 = −31/9·v.
        let v = vec![0.25f32, -0.5, 0.0625];
        let poisoned: Vec<f32> = v.iter().map(|x| -9.0 * x).collect();
        let mut updates: Vec<Update> = (0..5).map(|i| upd(i, v.clone())).collect();
        updates.extend((5..9).map(|i| upd(i, poisoned.clone())));
        let global = vec![0.0f32; 3];

        let rules: Vec<(&str, Box<dyn Aggregator>)> = vec![
            ("median", Box::new(CoordinateMedian::default())),
            ("trim", Box::new(TrimmedMean::new(0.45))),
            ("sketch-median", Box::<SketchMedian>::default()),
            ("sketch-trim", Box::new(SketchTrimmedMean::new(0.45))),
            ("geomedian", Box::new(GeoMedian::new(GEOMEDIAN_RESERVOIR))),
        ];
        for (name, mut agg) in rules {
            let out = agg.aggregate(&global, &updates, None).unwrap();
            for (o, e) in out.iter().zip(&v) {
                assert!((o - e).abs() < 1e-3, "{name}: {o} strayed from honest {e}");
            }
        }

        let avg = super::super::FedAvg::default().aggregate(&global, &updates, None).unwrap();
        for (a, e) in avg.iter().zip(&v) {
            assert!(a * e < 0.0, "fedavg must flip sign under the attack: {a} vs honest {e}");
        }
    }

    #[test]
    fn sketch_state_is_independent_of_k() {
        let p = 64;
        let small = {
            let mut s = QuantileSketch::new(p);
            let terms = vec![1i64 << 30; p];
            for _ in 0..10 {
                s.observe(0, &terms, 1);
            }
            s.state_bytes()
        };
        let large = {
            let mut s = QuantileSketch::new(p);
            let terms = vec![1i64 << 30; p];
            for _ in 0..1000 {
                s.observe(0, &terms, 1);
            }
            s.state_bytes()
        };
        assert_eq!(small, large, "sketch memory must not grow with K");
    }

    #[test]
    fn observers_are_permutation_invariant_bit_for_bit() {
        let global = vec![0.1f32; 8];
        let mut updates: Vec<Update> = (0..7)
            .map(|a| {
                let delta: Vec<f32> = (0..8)
                    .map(|i| ((a * 13 + i * 7) as f32).sin() * 0.3)
                    .collect();
                upd(a, delta)
            })
            .collect();
        let mk: Vec<fn() -> Box<dyn Aggregator>> = vec![
            || Box::new(SketchMedian::default()),
            || Box::new(SketchTrimmedMean::new(0.2)),
            || Box::new(GeoMedian::new(4)),
            || Box::new(GeoMedian::new(GEOMEDIAN_RESERVOIR)),
        ];
        for make in mk {
            let forward = make().aggregate(&global, &updates, None).unwrap();
            updates.reverse();
            let backward = make().aggregate(&global, &updates, None).unwrap();
            updates.reverse();
            assert_eq!(forward, backward, "order changed the result");
        }
    }

    #[test]
    fn geomedian_resists_minority_point_attack() {
        // 3 honest at v, 2 adversaries at -8v: the geometric median of
        // the point cloud sits at v.
        let v = vec![0.5f32, -0.25, 0.125, 0.0];
        let mut updates: Vec<Update> = (0..3).map(|i| upd(i, v.clone())).collect();
        let poisoned: Vec<f32> = v.iter().map(|x| -8.0 * x).collect();
        updates.push(upd(3, poisoned.clone()));
        updates.push(upd(4, poisoned));
        let global = vec![0.0f32; 4];
        let mut agg = GeoMedian::new(GEOMEDIAN_RESERVOIR);
        let out = agg.aggregate(&global, &updates, None).unwrap();
        for (o, e) in out.iter().zip(&v) {
            assert!((o - e).abs() < 1e-3, "geomedian {o} vs honest {e}");
        }
        assert_eq!(agg.trimmed_frac(), 0.0, "no reservoir eviction at K=5");
    }

    #[test]
    fn geomedian_reservoir_is_bounded_and_reports_trim() {
        let p = 4;
        let global = vec![0.0f32; p];
        let updates: Vec<Update> = (0..50).map(|a| upd(a, vec![0.25f32; p])).collect();
        let mut agg = GeoMedian::new(8);
        let out = agg.aggregate(&global, &updates, None).unwrap();
        for o in &out {
            assert!((o - 0.25).abs() < 1e-5);
        }
        assert!((agg.trimmed_frac() - 42.0 / 50.0).abs() < 1e-12);
    }

    #[test]
    fn observers_reset_between_rounds() {
        let global = vec![0.0f32; 2];
        let mut agg = SketchMedian::default();
        // Round 3 observes garbage that is never finalized …
        agg.observe_quantized(3, 0, &[i64::MAX / 2, i64::MAX / 2], 1)
            .unwrap();
        // … then round 4 starts: the stale state must not leak in.
        agg.observe_quantized(4, 1, &quantize_weighted(&[0.5, -0.5], 1).unwrap(), 1)
            .unwrap();
        let out = agg.apply_streamed(&global, &[0.0, 0.0]).unwrap();
        assert!((out[0] - 0.5).abs() < 1e-4);
        assert!((out[1] + 0.5).abs() < 1e-4);
    }

    #[test]
    fn finalize_without_observations_is_an_error() {
        let global = vec![0.0f32; 2];
        assert!(SketchMedian::default()
            .apply_streamed(&global, &[0.0, 0.0])
            .is_err());
        assert!(SketchTrimmedMean::new(0.2)
            .apply_streamed(&global, &[0.0, 0.0])
            .is_err());
        assert!(GeoMedian::new(4).apply_streamed(&global, &[0.0, 0.0]).is_err());
    }
}
