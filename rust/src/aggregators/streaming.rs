//! Streaming delta aggregation — the lock-striped incremental reduce
//! behind the round pipeline.
//!
//! The materialized path collects every agent's `delta_i` on the leader
//! and only then runs the reduce (historically with an extra K×P copy
//! into `'static` pool jobs). The streaming path inverts that: each
//! worker pushes its finished delta into a shared
//! [`StreamingAccumulator`] *as the agent completes*, so the server-side
//! reduce overlaps the stragglers' local training, the leader's
//! aggregation step shrinks to a single P-length finalize pass, and no
//! cohort copy is ever made. (Deltas are still retained — uncopied —
//! until round end for incentive scoring.)
//!
//! **Order invariance.** Pool workers finish in nondeterministic order,
//! and float addition does not commute bitwise — a naive `f32`/`f64`
//! running sum would make the global model depend on thread timing.
//! Instead every contribution `w_i · delta_i[j]` is quantised to a
//! fixed-point grid (a deterministic, per-term operation) and reduced in
//! a 128-bit *integer* accumulator, where addition is exact and
//! commutative. The finalized mean is therefore **bit-identical for
//! every arrival order** — stronger than compensated (Kahan) summation,
//! which shrinks but does not eliminate order dependence. The grid step
//! is 2⁻⁴⁰ ≈ 9·10⁻¹³: since `w_i` is an integer and `delta_i[j]` an
//! `f32` (24-bit mantissa), the product is exact in `f64` and the
//! quantisation error per term is at most one grid step — far below the
//! 1e-5 tolerance the golden tests pin against [`super::fedavg_host`].
//!
//! **Lock striping.** The parameter range is split into fixed-size
//! stripes, each behind its own `Mutex`, and concurrent pushes start at
//! rotated stripe offsets, so K workers drain into the accumulator with
//! minimal contention instead of serialising on one lock.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::runtime::simd;
use crate::util::error::{bail, Result};
use crate::util::rng;

/// Coordinates per lock stripe (64 KiB of `f32` delta per stripe).
const STRIPE_COORDS: usize = 1 << 14;

/// Fixed-point scale: contributions are quantised to multiples of
/// 2⁻⁴⁰ before the exact integer reduce. Shared with the robust
/// sketch rules ([`super::robust`]), which live on the same grid.
pub(crate) const FX_SCALE: f64 = (1u64 << 40) as f64;

/// Headroom clamp on |w·delta| per term (pre-scale): at 2⁶⁰ the scaled
/// term fits in 100 bits, so the i128 accumulator holds ≥ 2²⁷ terms
/// before it could wrap — far beyond any cohort.
pub(crate) const FX_TERM_LIMIT: f64 = (1u64 << 60) as f64;

/// A shared, lock-striped, order-invariant weighted-delta accumulator.
///
/// Usage per round: [`reset`](Self::reset) (or a fresh `new`), then any
/// number of concurrent [`push`](Self::push) calls from worker threads,
/// then [`finalize`](Self::finalize) on the leader once all pushes have
/// completed (the entrypoint's pool join is that barrier). The result is
/// the weighted mean delta `Δ̄ = Σ w_i·delta_i / Σ w_i`, bit-identical
/// under any push order.
pub struct StreamingAccumulator {
    len: usize,
    /// Fixed-point partial sums, `STRIPE_COORDS` coordinates per stripe.
    stripes: Vec<Mutex<Vec<i128>>>,
    total_weight: AtomicU64,
    /// Updates pushed since the last reset; doubles as the rotation
    /// counter that staggers concurrent pushes across stripes.
    count: AtomicUsize,
}

impl StreamingAccumulator {
    /// An accumulator for `len`-parameter deltas, zeroed.
    pub fn new(len: usize) -> Self {
        let nstripes = len.div_ceil(STRIPE_COORDS).max(1);
        let stripes = (0..nstripes)
            .map(|s| {
                let lo = s * STRIPE_COORDS;
                let hi = ((s + 1) * STRIPE_COORDS).min(len);
                Mutex::new(vec![0i128; hi - lo])
            })
            .collect();
        Self {
            len,
            stripes,
            total_weight: AtomicU64::new(0),
            count: AtomicUsize::new(0),
        }
    }

    /// Parameter count this accumulator was built for.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Updates pushed since the last reset.
    pub fn count(&self) -> usize {
        self.count.load(Ordering::Acquire)
    }

    /// Zero the accumulator for reuse (the entrypoint keeps one across
    /// rounds, so streaming adds no steady-state allocation).
    pub fn reset(&self) {
        for stripe in &self.stripes {
            let mut acc = stripe.lock().expect("streaming stripe poisoned");
            acc.fill(0);
        }
        self.total_weight.store(0, Ordering::Release);
        self.count.store(0, Ordering::Release);
    }

    /// Fold one agent's delta in with integer weight `weight`
    /// (sample count for FedAvg-family rules, 1 for uniform rules).
    ///
    /// Safe to call concurrently from many threads; the stripe locks
    /// are held only for the corresponding coordinate range.
    pub fn push(&self, delta: &[f32], weight: u64) -> Result<()> {
        if delta.len() != self.len {
            bail!(
                "streaming push of {} params into accumulator of {}",
                delta.len(),
                self.len
            );
        }
        // A non-finite contribution would quantise to 0 (Rust's
        // saturating float→int cast maps NaN to 0), silently dropping a
        // diverged client's coordinates while its weight still counts.
        // The materialized path would propagate NaN into the global
        // model; here we fail fast instead — both make the divergence
        // visible, silence would not.
        if let Some(pos) = delta.iter().position(|d| !d.is_finite()) {
            bail!("streaming push rejected: delta[{pos}] is {}", delta[pos]);
        }
        let w = weight as f64;
        let nstripes = self.stripes.len();
        // The per-stripe inner loop — exact product (integer × 24-bit
        // mantissa), deterministic per-term quantisation, exact i128
        // reduce — runs on the dispatched SIMD kernel. Every dispatch
        // level is bit-identical to scalar (pinned by `runtime::simd`
        // tests), so arrival order *and* ISA cannot change the result.
        let kernel = simd::kernels().fixed_accumulate;
        // Rotate the starting stripe per push so concurrent workers
        // drain into different locks.
        let start = self.count.fetch_add(1, Ordering::AcqRel) % nstripes;
        for turn in 0..nstripes {
            let s = (start + turn) % nstripes;
            let lo = s * STRIPE_COORDS;
            let mut acc = self.stripes[s].lock().expect("streaming stripe poisoned");
            let take = acc.len();
            kernel(&mut acc, &delta[lo..lo + take], w, FX_TERM_LIMIT, FX_SCALE);
        }
        self.total_weight.fetch_add(weight, Ordering::AcqRel);
        Ok(())
    }

    /// Fold one agent's *pre-quantised* contribution in: `terms[j]` must
    /// be the exact fixed-point term the kernel would have produced for
    /// this `(delta, weight)` pair — i.e. [`quantize_weighted`]'s output.
    ///
    /// This is the wire-side twin of [`push`](Self::push): a remote
    /// worker quantises locally, ships the i64 terms, and the leader
    /// adds them here with exact integer math. Because the in-memory
    /// reduce is already integer-exact and order-invariant, the result
    /// is bit-identical to a local `push` of the same delta — the wire
    /// format *is* the in-memory contract.
    pub fn push_quantized(&self, terms: &[i64], weight: u64) -> Result<()> {
        if terms.len() != self.len {
            bail!(
                "streaming push of {} quantised terms into accumulator of {}",
                terms.len(),
                self.len
            );
        }
        let nstripes = self.stripes.len();
        let start = self.count.fetch_add(1, Ordering::AcqRel) % nstripes;
        for turn in 0..nstripes {
            let s = (start + turn) % nstripes;
            let lo = s * STRIPE_COORDS;
            let mut acc = self.stripes[s].lock().expect("streaming stripe poisoned");
            let take = acc.len();
            for (a, &q) in acc.iter_mut().zip(&terms[lo..lo + take]) {
                *a += q as i128;
            }
        }
        self.total_weight.fetch_add(weight, Ordering::AcqRel);
        Ok(())
    }

    /// The weighted mean delta `Δ̄ = Σ w_i·delta_i / Σ w_i`.
    ///
    /// Call after all pushes have completed (e.g. after the worker-pool
    /// join). Errors when nothing was pushed, or when every pushed
    /// weight was zero — the entrypoint maps all-zero sample counts to
    /// uniform weight 1 before pushing, mirroring
    /// [`super::sample_weights`]'s fallback.
    pub fn finalize(&self) -> Result<Vec<f32>> {
        if self.count() == 0 {
            bail!("streaming aggregation finalized with no updates");
        }
        let total = self.total_weight.load(Ordering::Acquire);
        if total == 0 {
            bail!("streaming aggregation finalized with zero total weight");
        }
        let inv = 1.0 / (FX_SCALE * total as f64);
        let mut out = Vec::with_capacity(self.len);
        for stripe in &self.stripes {
            let acc = stripe.lock().expect("streaming stripe poisoned");
            out.extend(acc.iter().map(|&a| (a as f64 * inv) as f32));
        }
        Ok(out)
    }
}

/// Integrity checksum over a delta's quantised fixed-point terms.
///
/// Each coordinate is quantised exactly as the streaming reduce would
/// fold it at weight 1 — `(d.clamp(±2⁶⁰) * 2⁴⁰) as i128`, the same
/// formula as the `fixed_accumulate` kernels — and the i128 terms plus
/// the length are chained through a SplitMix64 finalizer. Pure integer
/// math on deterministically quantised terms: the digest is
/// bit-identical across platforms, SIMD levels, and thread counts.
///
/// The engine stamps every update with this at dispatch and verifies it
/// on arrival, *before* the accumulator push, rejecting corrupt frames;
/// it is the frame checksum of the future multi-process wire protocol,
/// where the quantised i64 terms themselves go on the wire.
pub fn delta_checksum(delta: &[f32]) -> u64 {
    let mut h = rng::splitmix64_mix(0xF4A3_0D15_ED0C_0DE5 ^ delta.len() as u64);
    for &d in delta {
        let q = ((d as f64).clamp(-FX_TERM_LIMIT, FX_TERM_LIMIT) * FX_SCALE) as i128;
        h = rng::splitmix64_mix(h ^ q as u64);
        h = rng::splitmix64_mix(h ^ (q >> 64) as u64);
    }
    h
}

/// Quantise one weighted delta to the streaming reduce's fixed-point
/// grid: `terms[j] = ((w·delta[j]).clamp(±2⁶⁰) · 2⁴⁰) as integer` —
/// exactly the per-term formula of the `fixed_accumulate` kernels, so
/// [`StreamingAccumulator::push_quantized`] of the result is
/// bit-identical to [`StreamingAccumulator::push`] of the raw delta.
///
/// This is the multi-process wire encoding: workers quantise locally
/// and ship these i64 terms; the leader never sees the f32 delta.
/// Non-finite coordinates fail fast (mirroring `push`), and a weighted
/// term too large for i64 (|w·d| ≥ 2⁶³/2⁴⁰ = 2²³) is an error rather
/// than a silent wrap — real deltas are orders of magnitude below it.
pub fn quantize_weighted(delta: &[f32], weight: u64) -> Result<Vec<i64>> {
    if let Some(pos) = delta.iter().position(|d| !d.is_finite()) {
        bail!("quantize rejected: delta[{pos}] is {}", delta[pos]);
    }
    let w = weight as f64;
    let mut terms = Vec::with_capacity(delta.len());
    for (j, &d) in delta.iter().enumerate() {
        let term = (w * d as f64).clamp(-FX_TERM_LIMIT, FX_TERM_LIMIT);
        let q = (term * FX_SCALE) as i128;
        let Ok(q64) = i64::try_from(q) else {
            bail!("quantize rejected: term[{j}] = {q} overflows the i64 wire format");
        };
        terms.push(q64);
    }
    Ok(terms)
}

/// Integrity checksum over already-quantised wire terms, using the same
/// SplitMix64 chain as [`delta_checksum`]. For a weight-1 delta whose
/// terms fit the grid, `quantized_checksum(&quantize_weighted(d, 1)?)`
/// equals `delta_checksum(d)` — the wire digest and the in-memory
/// digest are one function.
pub fn quantized_checksum(terms: &[i64]) -> u64 {
    let mut h = rng::splitmix64_mix(0xF4A3_0D15_ED0C_0DE5 ^ terms.len() as u64);
    for &t in terms {
        let q = t as i128;
        h = rng::splitmix64_mix(h ^ q as u64);
        h = rng::splitmix64_mix(h ^ (q >> 64) as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregators::{fedavg_host, sample_weights, Update};
    use crate::util::Rng;

    fn updates(rng: &mut Rng, k: usize, p: usize) -> Vec<Update> {
        (0..k)
            .map(|i| Update {
                agent_id: i,
                delta: (0..p).map(|_| rng.next_gaussian() * 0.01).collect(),
                num_samples: 5 + i * 3,
            })
            .collect()
    }

    fn stream_mean(ups: &[Update], order: &[usize], p: usize) -> Vec<f32> {
        let acc = StreamingAccumulator::new(p);
        for &i in order {
            acc.push(&ups[i].delta, ups[i].num_samples as u64).unwrap();
        }
        acc.finalize().unwrap()
    }

    #[test]
    fn matches_fedavg_host_within_tolerance() {
        let mut rng = Rng::new(0x57e4);
        // Straddle several stripes and a non-multiple tail.
        for (k, p) in [(1usize, 64usize), (4, 1000), (7, STRIPE_COORDS + 13), (16, 40_000)] {
            let ups = updates(&mut rng, k, p);
            let global: Vec<f32> = (0..p).map(|_| rng.next_gaussian() * 0.1).collect();
            let w = sample_weights(&ups);
            let host = fedavg_host(&global, &ups, &w);
            let mean = stream_mean(&ups, &(0..k).collect::<Vec<_>>(), p);
            for (j, ((&m, &g), &h)) in mean.iter().zip(&global).zip(&host).enumerate() {
                let got = g + m;
                let tol = 1e-5 * h.abs().max(1.0);
                assert!((got - h).abs() <= tol, "k={k} p={p} coord {j}: {got} vs {h}");
            }
        }
    }

    #[test]
    fn arrival_order_is_bit_invariant() {
        let mut rng = Rng::new(0x0afe);
        let (k, p) = (9usize, 2 * STRIPE_COORDS + 77);
        let ups = updates(&mut rng, k, p);
        let mut order: Vec<usize> = (0..k).collect();
        let reference = stream_mean(&ups, &order, p);
        for _ in 0..5 {
            rng.shuffle(&mut order);
            let shuffled = stream_mean(&ups, &order, p);
            assert!(
                reference == shuffled,
                "streamed mean must be bit-identical under arrival order {order:?}"
            );
        }
    }

    #[test]
    fn concurrent_pushes_match_serial() {
        let mut rng = Rng::new(0xc0c0);
        let (k, p) = (8usize, STRIPE_COORDS * 3 + 5);
        let ups = updates(&mut rng, k, p);
        let serial = stream_mean(&ups, &(0..k).collect::<Vec<_>>(), p);
        let acc = StreamingAccumulator::new(p);
        std::thread::scope(|s| {
            for u in &ups {
                let acc = &acc;
                s.spawn(move || acc.push(&u.delta, u.num_samples as u64).unwrap());
            }
        });
        assert_eq!(acc.count(), k);
        let parallel = acc.finalize().unwrap();
        assert!(serial == parallel, "threaded pushes must be bit-identical to serial");
    }

    #[test]
    fn reset_allows_reuse() {
        let acc = StreamingAccumulator::new(8);
        acc.push(&[1.0; 8], 2).unwrap();
        acc.reset();
        assert_eq!(acc.count(), 0);
        acc.push(&[2.0; 8], 1).unwrap();
        let mean = acc.finalize().unwrap();
        assert!(mean.iter().all(|&m| (m - 2.0).abs() < 1e-6), "{mean:?}");
    }

    #[test]
    fn shape_mismatch_and_empty_are_errors() {
        let acc = StreamingAccumulator::new(4);
        assert!(acc.push(&[0.0; 3], 1).is_err());
        assert!(acc.finalize().is_err(), "no pushes => error");
        acc.push(&[0.0; 4], 0).unwrap();
        assert!(acc.finalize().is_err(), "zero total weight => error");
    }

    /// A diverged client (NaN/inf delta) must fail loudly — the
    /// saturating float→int cast would otherwise zero it silently.
    #[test]
    fn non_finite_deltas_are_rejected() {
        let acc = StreamingAccumulator::new(3);
        assert!(acc.push(&[0.0, f32::NAN, 0.0], 1).is_err());
        assert!(acc.push(&[f32::INFINITY, 0.0, 0.0], 1).is_err());
        assert_eq!(acc.count(), 0, "rejected pushes must not count");
    }

    #[test]
    fn checksum_detects_any_representable_perturbation() {
        let mut rng = Rng::new(0xc4ec);
        let delta: Vec<f32> = (0..512).map(|_| rng.next_gaussian() * 0.01).collect();
        let h = delta_checksum(&delta);
        assert_eq!(h, delta_checksum(&delta), "pure function of the payload");
        // Any single-coordinate bump above the 2^-40 grid must change
        // the digest — this is exactly the corruption model the fault
        // layer injects (`payload[j] += 1.0`).
        for j in [0usize, 7, 255, 511] {
            let mut bad = delta.clone();
            bad[j] += 1.0;
            assert_ne!(h, delta_checksum(&bad), "coord {j}");
        }
        // Length and order are part of the frame.
        assert_ne!(h, delta_checksum(&delta[..511]));
        let mut swapped = delta.clone();
        swapped.swap(0, 1);
        assert_ne!(h, delta_checksum(&swapped));
        // Empty frames hash deterministically too.
        assert_eq!(delta_checksum(&[]), delta_checksum(&[]));
    }

    /// The wire contract: quantise-then-push-terms must finalize
    /// bit-identically to pushing the raw f32 delta, across shapes that
    /// straddle stripes and under shuffled arrival orders mixing local
    /// and wire-side pushes.
    #[test]
    fn push_quantized_is_bit_identical_to_push() {
        let mut rng = Rng::new(0x91f3);
        for (k, p) in [(1usize, 64usize), (4, 1000), (7, STRIPE_COORDS + 13)] {
            let ups = updates(&mut rng, k, p);
            let local = stream_mean(&ups, &(0..k).collect::<Vec<_>>(), p);
            let mut order: Vec<usize> = (0..k).collect();
            for trial in 0..3 {
                rng.shuffle(&mut order);
                let acc = StreamingAccumulator::new(p);
                for (pos, &i) in order.iter().enumerate() {
                    let w = ups[i].num_samples as u64;
                    // Alternate wire-side and local pushes: the mix must
                    // still land on the same bits.
                    if (pos + trial) % 2 == 0 {
                        let terms = quantize_weighted(&ups[i].delta, w).unwrap();
                        acc.push_quantized(&terms, w).unwrap();
                    } else {
                        acc.push(&ups[i].delta, w).unwrap();
                    }
                }
                let wire = acc.finalize().unwrap();
                assert!(local == wire, "k={k} p={p} order {order:?}: wire != local");
            }
        }
    }

    /// At weight 1 every term fits the i64 wire format and the wire
    /// digest collapses to the in-memory delta digest.
    #[test]
    fn quantized_checksum_matches_delta_checksum_at_unit_weight() {
        let mut rng = Rng::new(0x77aa);
        let delta: Vec<f32> = (0..300).map(|_| rng.next_gaussian() * 0.01).collect();
        let terms = quantize_weighted(&delta, 1).unwrap();
        assert_eq!(quantized_checksum(&terms), delta_checksum(&delta));
        // And any single-term perturbation changes it.
        let mut bad = terms.clone();
        bad[123] ^= 1;
        assert_ne!(quantized_checksum(&bad), quantized_checksum(&terms));
        assert_ne!(quantized_checksum(&terms[..299]), quantized_checksum(&terms));
    }

    #[test]
    fn quantize_rejects_non_finite_and_overflow() {
        assert!(quantize_weighted(&[0.0, f32::NAN], 1).is_err());
        assert!(quantize_weighted(&[f32::INFINITY], 1).is_err());
        // |w·d| = 2^40 · 2^40 = 2^80 after scaling: overflows i64.
        assert!(quantize_weighted(&[1.0e12], 1 << 40).is_err());
        // Length mismatch on the accumulator side still errors.
        let acc = StreamingAccumulator::new(4);
        assert!(acc.push_quantized(&[0; 3], 1).is_err());
    }

    #[test]
    fn uniform_weights_average() {
        let acc = StreamingAccumulator::new(2);
        acc.push(&[1.0, -3.0], 1).unwrap();
        acc.push(&[3.0, 1.0], 1).unwrap();
        let mean = acc.finalize().unwrap();
        assert!((mean[0] - 2.0).abs() < 1e-6 && (mean[1] + 1.0).abs() < 1e-6, "{mean:?}");
    }
}
