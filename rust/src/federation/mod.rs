//! Federation: splitting a dataset across agents (paper §3.1, Fig 6).
//!
//! Implements the datamodule sharding logic of TorchFL, dataset-agnostic
//! (operates on label vectors only):
//!
//! - **IID** — shuffle, deal round-robin: every agent's shard is a
//!   uniform sample of the global distribution.
//! - **Non-IID(`niid_factor`)** — the classic McMahan sort-and-shard
//!   scheme TorchFL uses: sort indices by label, cut into
//!   `num_agents * niid_factor` contiguous shards, deal `niid_factor`
//!   shards to each agent. Each agent then holds ≈`niid_factor` distinct
//!   labels (paper Fig 6: unique labels per agent grow with the factor;
//!   `niid = 1` is the pathological single-label case).
//! - **Dirichlet(α)** — the label-skew generalisation used throughout
//!   the FL literature (an extension beyond TorchFL's offering): class
//!   c's samples are split across agents by a Dirichlet(α) draw.
//!
//! All schemes produce an exact partition: every index appears in
//! exactly one shard (property-tested).

use std::collections::BTreeSet;

use crate::util::error::{bail, Result};
use crate::util::Rng;

/// Sharding scheme (experiment-config surface).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scheme {
    Iid,
    /// `niid_factor` = shards (≈ distinct labels) per agent.
    NonIid { niid_factor: usize },
    /// Label-skew via symmetric Dirichlet(alpha).
    Dirichlet { alpha: f64 },
}

impl Scheme {
    /// Parse from config text, e.g. "iid", "niid:3", "dirichlet:0.5".
    pub fn parse(text: &str) -> Result<Scheme> {
        let t = text.trim().to_ascii_lowercase();
        if t == "iid" {
            return Ok(Scheme::Iid);
        }
        if let Some(rest) = t.strip_prefix("niid:") {
            let f: usize = rest.parse()?;
            if f == 0 {
                bail!("niid_factor must be >= 1");
            }
            return Ok(Scheme::NonIid { niid_factor: f });
        }
        if let Some(rest) = t.strip_prefix("dirichlet:") {
            let a: f64 = rest.parse()?;
            if a <= 0.0 {
                bail!("dirichlet alpha must be > 0");
            }
            return Ok(Scheme::Dirichlet { alpha: a });
        }
        bail!("unknown split scheme {text:?} (iid | niid:<k> | dirichlet:<a>)")
    }
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scheme::Iid => write!(f, "iid"),
            Scheme::NonIid { niid_factor } => write!(f, "niid:{niid_factor}"),
            Scheme::Dirichlet { alpha } => write!(f, "dirichlet:{alpha}"),
        }
    }
}

/// One agent's training shard, either as an explicit index list (the
/// scheme-partitioned schemes above) or as a closed-form contiguous
/// range over the virtual index space (the virtualized registry, where
/// materializing a million index vectors is exactly what we avoid).
///
/// Synthesis is a pure function of `(seed, split, index)` for *any*
/// index, so a contiguous range of the virtual index space is already
/// an IID sample of the procedural distribution — range shards and the
/// explicit `(lo..hi)` index list train bit-identically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardSpec {
    /// Explicit sample indices (materialized partitions).
    Indices(Vec<usize>),
    /// The half-open index range `[lo, hi)` (virtual registries).
    Range { lo: usize, hi: usize },
}

impl ShardSpec {
    pub fn len(&self) -> usize {
        match self {
            ShardSpec::Indices(v) => v.len(),
            ShardSpec::Range { lo, hi } => hi - lo,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the per-agent epoch order (what local training
    /// shuffles). Cohort-bounded: only sampled agents ever call this.
    pub fn to_order(&self) -> Vec<usize> {
        match self {
            ShardSpec::Indices(v) => v.clone(),
            ShardSpec::Range { lo, hi } => (*lo..*hi).collect(),
        }
    }
}

impl From<Vec<usize>> for ShardSpec {
    fn from(v: Vec<usize>) -> Self {
        ShardSpec::Indices(v)
    }
}

/// Closed-form shard bounds of `agent` when `total` samples are dealt
/// contiguously across `num_agents`: the half-open range
/// `[agent·total/A, (agent+1)·total/A)`. Balanced within one sample,
/// exact partition by construction, O(1) per query — the virtualized
/// replacement for materialized IID index vectors.
pub fn shard_range(total: usize, num_agents: usize, agent: usize) -> (usize, usize) {
    debug_assert!(agent < num_agents);
    (agent * total / num_agents, (agent + 1) * total / num_agents)
}

/// The result of sharding: one index list per agent.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Vec<usize>>,
    pub scheme: Scheme,
}

impl Partition {
    /// Histogram of labels per agent: `counts[agent][class]`.
    pub fn label_histogram(
        &self,
        labels: &[usize],
        num_classes: usize,
    ) -> Vec<Vec<usize>> {
        self.shards
            .iter()
            .map(|shard| {
                let mut h = vec![0usize; num_classes];
                for &i in shard {
                    h[labels[i]] += 1;
                }
                h
            })
            .collect()
    }

    /// Number of distinct labels each agent holds (paper Fig 6 metric).
    pub fn unique_labels(&self, labels: &[usize]) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.iter().map(|&i| labels[i]).collect::<BTreeSet<_>>().len())
            .collect()
    }
}

/// Shard `labels.len()` samples across `num_agents` agents.
pub fn shard(
    labels: &[usize],
    num_agents: usize,
    scheme: Scheme,
    rng: &mut Rng,
) -> Result<Partition> {
    if num_agents == 0 {
        bail!("num_agents must be >= 1");
    }
    if labels.len() < num_agents {
        bail!(
            "cannot shard {} samples across {num_agents} agents",
            labels.len()
        );
    }
    let shards = match scheme {
        Scheme::Iid => shard_iid(labels.len(), num_agents, rng),
        Scheme::NonIid { niid_factor } => {
            shard_sorted(labels, num_agents, niid_factor, rng)
        }
        Scheme::Dirichlet { alpha } => shard_dirichlet(labels, num_agents, alpha, rng),
    };
    Ok(Partition { shards, scheme })
}

fn shard_iid(n: usize, num_agents: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut shards = vec![Vec::with_capacity(n / num_agents + 1); num_agents];
    for (i, sample) in idx.into_iter().enumerate() {
        shards[i % num_agents].push(sample);
    }
    shards
}

fn shard_sorted(
    labels: &[usize],
    num_agents: usize,
    niid_factor: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let n = labels.len();
    // Sort indices by label (stable: ties keep index order).
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by_key(|&i| labels[i]);

    // Cut into num_agents * niid_factor contiguous shards and deal
    // niid_factor random shards to each agent.
    let total_shards = num_agents * niid_factor;
    let mut order: Vec<usize> = (0..total_shards).collect();
    rng.shuffle(&mut order);

    let mut shards = vec![Vec::new(); num_agents];
    for (pos, &shard_id) in order.iter().enumerate() {
        let agent = pos / niid_factor;
        let lo = shard_id * n / total_shards;
        let hi = (shard_id + 1) * n / total_shards;
        shards[agent].extend_from_slice(&idx[lo..hi]);
    }
    shards
}

fn shard_dirichlet(
    labels: &[usize],
    num_agents: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let num_classes = labels.iter().copied().max().map_or(1, |m| m + 1);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        by_class[l].push(i);
    }
    let mut shards = vec![Vec::new(); num_agents];
    for class_idx in by_class.into_iter() {
        if class_idx.is_empty() {
            continue;
        }
        let mut class_idx = class_idx;
        rng.shuffle(&mut class_idx);
        let props = rng.next_dirichlet(alpha, num_agents);
        // Cumulative cut points over the class's samples.
        let n = class_idx.len();
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (a, &p) in props.iter().enumerate() {
            acc += p;
            let end = if a + 1 == num_agents {
                n
            } else {
                (acc * n as f64).round() as usize
            }
            .clamp(start, n);
            shards[a].extend_from_slice(&class_idx[start..end]);
            start = end;
        }
    }
    // Dirichlet can leave an agent empty at tiny n; backfill one sample
    // from the largest shard so every agent can train.
    loop {
        let Some(empty) = shards.iter().position(|s| s.is_empty()) else {
            break;
        };
        let donor = (0..shards.len())
            .max_by_key(|&i| shards[i].len())
            .expect("nonempty");
        if shards[donor].len() <= 1 {
            break; // nothing to donate
        }
        let moved = shards[donor].pop().expect("donor nonempty");
        shards[empty].push(moved);
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize, classes: usize, seed: u64) -> Vec<usize> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.next_below(classes as u64) as usize).collect()
    }

    fn assert_partition(p: &Partition, n: usize) {
        let mut all: Vec<usize> = p.shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "not an exact partition");
    }

    #[test]
    fn iid_is_partition_and_balanced() {
        let l = labels(1000, 10, 1);
        let mut rng = Rng::new(2);
        let p = shard(&l, 7, Scheme::Iid, &mut rng).unwrap();
        assert_partition(&p, 1000);
        for s in &p.shards {
            assert!((142..=143).contains(&s.len()));
        }
        // IID: every agent sees (almost) every label.
        for u in p.unique_labels(&l) {
            assert!(u >= 9, "iid agent missing labels: {u}");
        }
    }

    #[test]
    fn niid_limits_unique_labels() {
        // Balanced labels so shards align with label boundaries.
        let l: Vec<usize> = (0..1000).map(|i| i / 100).collect(); // 10 classes
        let mut rng = Rng::new(3);
        for factor in [1usize, 3, 5] {
            let p = shard(
                &l,
                5,
                Scheme::NonIid {
                    niid_factor: factor,
                },
                &mut rng,
            )
            .unwrap();
            assert_partition(&p, 1000);
            // Each contiguous sorted shard spans at most 2 labels when the
            // shard is smaller than a class, so an agent holding `factor`
            // shards sees at most 2*factor distinct labels.
            for u in p.unique_labels(&l) {
                assert!(
                    u <= 2 * factor,
                    "niid:{factor} agent holds {u} labels (> 2*{factor})"
                );
            }
        }
    }

    #[test]
    fn niid_factor_monotone_in_unique_labels() {
        let l: Vec<usize> = (0..2000).map(|i| i / 200).collect();
        let mut rng = Rng::new(4);
        let mut means = Vec::new();
        for factor in [1usize, 3, 5] {
            let p = shard(&l, 5, Scheme::NonIid { niid_factor: factor }, &mut rng)
                .unwrap();
            let u = p.unique_labels(&l);
            means.push(u.iter().sum::<usize>() as f64 / u.len() as f64);
        }
        assert!(
            means[0] < means[1] && means[1] < means[2],
            "unique labels should grow with niid_factor: {means:?}"
        );
    }

    #[test]
    fn dirichlet_is_partition() {
        let l = labels(500, 10, 5);
        let mut rng = Rng::new(6);
        for alpha in [0.1, 1.0, 100.0] {
            let p = shard(&l, 8, Scheme::Dirichlet { alpha }, &mut rng).unwrap();
            assert_partition(&p, 500);
            assert!(p.shards.iter().all(|s| !s.is_empty()));
        }
    }

    #[test]
    fn dirichlet_skew_decreases_with_alpha() {
        let l = labels(5000, 10, 7);
        let mut rng = Rng::new(8);
        let skew = |alpha: f64, rng: &mut Rng| -> f64 {
            let p = shard(&l, 10, Scheme::Dirichlet { alpha }, rng).unwrap();
            let u = p.unique_labels(&l);
            u.iter().sum::<usize>() as f64 / u.len() as f64
        };
        let lo = skew(0.05, &mut rng);
        let hi = skew(100.0, &mut rng);
        assert!(
            lo < hi,
            "alpha=0.05 mean unique labels {lo} should be < alpha=100 {hi}"
        );
    }

    #[test]
    fn histogram_counts_sum_to_shard_sizes() {
        let l = labels(300, 5, 9);
        let mut rng = Rng::new(10);
        let p = shard(&l, 4, Scheme::NonIid { niid_factor: 2 }, &mut rng).unwrap();
        let h = p.label_histogram(&l, 5);
        for (agent, counts) in h.iter().enumerate() {
            assert_eq!(
                counts.iter().sum::<usize>(),
                p.shards[agent].len()
            );
        }
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("iid").unwrap(), Scheme::Iid);
        assert_eq!(
            Scheme::parse("niid:3").unwrap(),
            Scheme::NonIid { niid_factor: 3 }
        );
        assert!(matches!(
            Scheme::parse("dirichlet:0.5").unwrap(),
            Scheme::Dirichlet { alpha } if (alpha - 0.5).abs() < 1e-12
        ));
        assert!(Scheme::parse("niid:0").is_err());
        assert!(Scheme::parse("bogus").is_err());
        assert!(Scheme::parse("dirichlet:-1").is_err());
    }

    #[test]
    fn errors_on_degenerate_inputs() {
        let l = labels(3, 2, 11);
        let mut rng = Rng::new(12);
        assert!(shard(&l, 0, Scheme::Iid, &mut rng).is_err());
        assert!(shard(&l, 10, Scheme::Iid, &mut rng).is_err());
    }

    #[test]
    fn range_shards_partition_exactly_and_balance_within_one() {
        for &(total, agents) in &[(10, 3), (1024, 64), (1_000_000, 1_000_000), (7, 7)] {
            let mut covered = 0usize;
            let (mut min, mut max) = (usize::MAX, 0usize);
            for a in 0..agents {
                let (lo, hi) = shard_range(total, agents, a);
                assert_eq!(lo, covered, "gap before agent {a}");
                covered = hi;
                min = min.min(hi - lo);
                max = max.max(hi - lo);
            }
            assert_eq!(covered, total);
            assert!(max - min <= 1, "total={total} agents={agents}");
        }
    }

    #[test]
    fn shard_spec_range_orders_like_explicit_indices() {
        let range = ShardSpec::Range { lo: 5, hi: 9 };
        let explicit = ShardSpec::Indices(vec![5, 6, 7, 8]);
        assert_eq!(range.len(), 4);
        assert_eq!(range.to_order(), explicit.to_order());
        assert!(ShardSpec::Range { lo: 3, hi: 3 }.is_empty());
    }
}
