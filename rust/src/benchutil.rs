//! Tiny benchmark harness used by `cargo bench` targets.
//!
//! The vendored crate set carries no criterion, so the bench binaries
//! (`rust/benches/*.rs`, `harness = false`) use this: warmup + N timed
//! iterations, reporting min/mean/p50/max. Deterministic workloads, wall
//! clock, no statistics theatre — adequate for the before/after deltas
//! EXPERIMENTS.md §Perf tracks.
//!
//! Benches additionally emit throughput counters (steps/s, examples/s,
//! aggregation GB/s) into `BENCH_native.json` via [`merge_section`]:
//! each bench target owns one top-level section and read-modify-writes
//! the file, so running several benches accumulates one machine-readable
//! perf snapshot per checkout. CI runs the two smoke benches in fast
//! mode (`FERRISFL_BENCH_FAST=1`, see [`fast_mode`]) and uploads the
//! file as an artifact — the measured-perf trajectory of the repo.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::Json;

/// Timing summary over the measured iterations (seconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub max: f64,
}

impl BenchStats {
    /// Throughput in items/sec given items processed per iteration.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean
    }

    /// Throughput in GB/s given bytes touched per iteration.
    pub fn gb_per_sec(&self, bytes_per_iter: f64) -> f64 {
        bytes_per_iter / self.mean / 1e9
    }

    /// This measurement as a JSON object (times in ms, plus throughput
    /// fields when `items_per_iter` is given).
    pub fn to_json(&self, items_per_iter: Option<f64>) -> Json {
        let mut pairs = vec![
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean * 1e3)),
            ("p50_ms", Json::num(self.p50 * 1e3)),
            ("min_ms", Json::num(self.min * 1e3)),
            ("max_ms", Json::num(self.max * 1e3)),
        ];
        if let Some(items) = items_per_iter {
            pairs.push(("items_per_iter", Json::num(items)));
            pairs.push(("items_per_sec", Json::num(self.per_sec(items))));
        }
        Json::obj(pairs)
    }
}

/// True when `FERRISFL_BENCH_FAST` is set (and not "0"): benches shrink
/// workloads/iterations so CI can smoke-run them on every merge.
pub fn fast_mode() -> bool {
    crate::util::env::bench_fast()
}

/// Scale an iteration count down in fast mode (≥1 always).
pub fn scaled_iters(iters: usize) -> usize {
    if fast_mode() {
        (iters / 4).max(1)
    } else {
        iters
    }
}

/// The workspace root, resolved from the crate's own manifest dir at
/// compile time — stable no matter which directory the bench binary is
/// launched from.
pub fn workspace_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().map(PathBuf::from).unwrap_or(manifest)
}

/// Where bench JSON goes: `$FERRISFL_BENCH_JSON`, else
/// `BENCH_native.json` in the **workspace root**. (It used to default
/// to the process CWD, which under `cargo bench` is the package dir
/// `rust/` — so local runs and CI scattered snapshots into different
/// places depending on invocation.)
pub fn bench_json_path() -> PathBuf {
    crate::util::env::bench_json().unwrap_or_else(|| workspace_root().join("BENCH_native.json"))
}

/// Read-modify-write one top-level section of the bench JSON file, so
/// each bench target contributes its own section and a sequence of
/// bench runs accumulates a single perf snapshot.
pub fn merge_section(section: &str, value: Json) {
    merge_section_at(&bench_json_path(), section, value);
}

/// [`merge_section`] against an explicit path (tests use a temp file).
///
/// Resilient to a corrupt or truncated existing file: the unreadable
/// content is preserved next to the file as `<name>.corrupt` (instead
/// of being silently clobbered) and the merge proceeds from an empty
/// snapshot. The write itself goes through a temp file + rename, so an
/// interrupted bench can never leave a half-written `BENCH_native.json`
/// behind — the failure mode that used to abort the *next* bench run.
pub fn merge_section_at(path: &std::path::Path, section: &str, value: Json) {
    let mut root = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(v) => v,
            Err(e) => {
                let backup = path.with_extension("json.corrupt");
                let _ = std::fs::write(&backup, &text);
                eprintln!(
                    "warning: {} is not valid JSON ({e}); starting a fresh \
                     snapshot (old content saved to {})",
                    path.display(),
                    backup.display()
                );
                Json::obj(vec![])
            }
        },
        Err(_) => Json::obj(vec![]),
    };
    if !matches!(root, Json::Obj(_)) {
        eprintln!(
            "warning: {} holds a non-object JSON value; starting a fresh snapshot",
            path.display()
        );
        root = Json::obj(vec![]);
    }
    if let Json::Obj(map) = &mut root {
        map.insert(section.to_string(), value);
    }
    // Atomic replace: write the whole snapshot to a sibling temp file,
    // then rename over the target.
    let tmp = path.with_extension(format!("json.tmp.{}", std::process::id()));
    let write = std::fs::write(&tmp, root.to_string())
        .and_then(|()| std::fs::rename(&tmp, path));
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("\n[bench] wrote section {section:?} to {}", path.display());
    }
}

// ===================================================== regression diff
//
// The CI bench gate: extract comparable scalar metrics out of two bench
// snapshots (the committed `BENCH_baseline.json` and a fresh
// `BENCH_native.json`) and fail on any regression beyond a threshold.
// Used by the `bench_diff` binary.

/// One comparable scalar pulled out of a bench snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    /// Stable dotted name, e.g. `round_e2e/workers_4/mean_ms`.
    pub name: String,
    pub value: f64,
    /// Throughputs are better high; walltimes are better low.
    pub higher_is_better: bool,
}

/// One row of the baseline-vs-current comparison.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub name: String,
    pub base: Option<f64>,
    pub cur: Option<f64>,
    pub higher_is_better: bool,
    /// `cur/base - 1` (signed change), when both sides exist.
    pub change: Option<f64>,
    /// Worse than the threshold allows (only set when both sides exist).
    pub regressed: bool,
}

fn push_metric(out: &mut Vec<Metric>, name: String, v: Option<&Json>, higher: bool) {
    if let Some(Json::Num(n)) = v {
        if n.is_finite() && *n > 0.0 {
            out.push(Metric {
                name,
                value: *n,
                higher_is_better: higher,
            });
        }
    }
}

/// Extract the gated metrics from a bench snapshot: train-step and eval
/// throughput (steps/s / examples/s), the naive-vs-blocked numbers,
/// per-pool-size round walltime, aggregation GB/s, and the dispatched
/// micro-kernel throughput from the `kernels` bench. Unknown sections
/// are ignored, so old and new snapshots stay comparable.
pub fn collect_metrics(root: &Json) -> Vec<Metric> {
    let mut out = Vec::new();
    // train_step.{cases,eval}.<case>.items_per_sec (examples/s, higher
    // better)
    for sub in ["cases", "eval"] {
        if let Some(Json::Obj(cases)) = root.get("train_step").and_then(|s| s.get(sub)) {
            for (case, v) in cases {
                push_metric(
                    &mut out,
                    format!("train_step/{sub}/{case}/items_per_sec"),
                    v.get("items_per_sec"),
                    true,
                );
            }
        }
    }
    // train_step.naive_vs_blocked: gate on blocked steps/s only. The
    // speedup *ratio* is deliberately not gated — it also moves when
    // the naive baseline measurement shifts (different runner CPU,
    // cache warmth), which would fail CI without a real regression.
    if let Some(nvb) = root.get("train_step").and_then(|s| s.get("naive_vs_blocked")) {
        push_metric(
            &mut out,
            "train_step/naive_vs_blocked/steps_per_sec_blocked".into(),
            nvb.get("steps_per_sec_blocked"),
            true,
        );
    }
    // train_step.parallel / train_step.fused: gate on the absolute
    // parallel/fused throughput only — the serial/unfused side and the
    // speedup ratios are context (like naive_vs_blocked, ratios
    // double-count runner noise).
    if let Some(par) = root.get("train_step").and_then(|s| s.get("parallel")) {
        push_metric(
            &mut out,
            "train_step/parallel/steps_per_sec_parallel".into(),
            par.get("steps_per_sec_parallel"),
            true,
        );
    }
    if let Some(fu) = root.get("train_step").and_then(|s| s.get("fused")) {
        push_metric(
            &mut out,
            "train_step/fused/agent_steps_per_sec_fused".into(),
            fu.get("agent_steps_per_sec_fused"),
            true,
        );
    }
    // round_e2e.round_walltime.workers_N.mean_ms (lower better)
    if let Some(Json::Obj(ws)) = root.get("round_e2e").and_then(|s| s.get("round_walltime")) {
        for (w, v) in ws {
            push_metric(&mut out, format!("round_e2e/{w}/mean_ms"), v.get("mean_ms"), false);
        }
    }
    // aggregation.fedavg.<row>.gb_per_sec (higher better)
    if let Some(Json::Obj(rows)) = root.get("aggregation").and_then(|s| s.get("fedavg")) {
        for (row, v) in rows {
            push_metric(
                &mut out,
                format!("aggregation/fedavg/{row}/gb_per_sec"),
                v.get("gb_per_sec"),
                true,
            );
        }
    }
    // kernels.cases.<kernel>.*_simd: absolute dispatched-kernel
    // throughput (higher better). The scalar side and the speedup
    // *ratio* deliberately don't gate — like naive_vs_blocked, ratios
    // double-count runner noise.
    if let Some(Json::Obj(cases)) = root.get("kernels").and_then(|s| s.get("cases")) {
        for (case, v) in cases {
            for unit in ["gflops_simd", "gb_per_sec_simd", "melems_per_sec_simd"] {
                push_metric(&mut out, format!("kernels/{case}/{unit}"), v.get(unit), true);
            }
        }
    }
    out
}

/// Per-section context recorded alongside the metrics: the SIMD
/// dispatch level and panel-thread count each bench stamped into its
/// section (`simd` / `dispatch` / `threads` fields). `bench_diff`
/// prints these so a regression report always states which hardware
/// mode produced a snapshot.
pub fn section_meta(root: &Json) -> Vec<String> {
    let mut out = Vec::new();
    if let Json::Obj(map) = root {
        for (name, v) in map {
            let mut bits = Vec::new();
            for key in ["simd", "dispatch", "threads"] {
                match v.get(key) {
                    Some(Json::Str(s)) => bits.push(format!("{key}={s}")),
                    Some(Json::Num(n)) => bits.push(format!("{key}={n}")),
                    _ => {}
                }
            }
            if !bits.is_empty() {
                out.push(format!("{name} ({})", bits.join(", ")));
            }
        }
    }
    out
}

/// A baseline is *provisional* when it carries `"provisional": true` at
/// the top level: the diff table is still printed, but regressions do
/// not gate (used to bootstrap the committed baseline before a real CI
/// measurement is promoted into it).
pub fn is_provisional(root: &Json) -> bool {
    matches!(root.get("provisional"), Some(Json::Bool(true)))
}

/// Compare two snapshots. `max_regress` is the allowed fractional
/// slowdown (0.25 = fail beyond 25% worse). Returns the per-metric rows
/// (union of both sides, baseline order first) and whether any metric
/// regressed beyond the threshold.
pub fn diff(base: &Json, cur: &Json, max_regress: f64) -> (Vec<DiffRow>, bool) {
    let base_metrics = collect_metrics(base);
    let cur_metrics = collect_metrics(cur);
    let cur_by_name: std::collections::BTreeMap<&str, &Metric> =
        cur_metrics.iter().map(|m| (m.name.as_str(), m)).collect();
    let base_names: std::collections::BTreeSet<&str> =
        base_metrics.iter().map(|m| m.name.as_str()).collect();

    let mut rows = Vec::new();
    let mut any_regressed = false;
    for bm in &base_metrics {
        let cm = cur_by_name.get(bm.name.as_str());
        let (change, regressed) = match cm {
            Some(cm) => {
                let change = cm.value / bm.value - 1.0;
                // For higher-is-better metrics a *drop* is a regression;
                // for lower-is-better a *rise* is.
                let worse = if bm.higher_is_better { -change } else { change };
                (Some(change), worse > max_regress)
            }
            None => (None, false),
        };
        any_regressed |= regressed;
        rows.push(DiffRow {
            name: bm.name.clone(),
            base: Some(bm.value),
            cur: cm.map(|m| m.value),
            higher_is_better: bm.higher_is_better,
            change,
            regressed,
        });
    }
    for cm in &cur_metrics {
        if !base_names.contains(cm.name.as_str()) {
            rows.push(DiffRow {
                name: cm.name.clone(),
                base: None,
                cur: Some(cm.value),
                higher_is_better: cm.higher_is_better,
                change: None,
                regressed: false,
            });
        }
    }
    (rows, any_regressed)
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(v) if v >= 100.0 => format!("{v:.1}"),
        Some(v) => format!("{v:.4}"),
        None => "—".into(),
    }
}

fn fmt_change(r: &DiffRow) -> String {
    match r.change {
        Some(c) => format!("{:+.1}%", c * 100.0),
        None => "—".into(),
    }
}

/// The comparison as a GitHub-flavoured markdown table (for
/// `$GITHUB_STEP_SUMMARY`).
pub fn render_markdown(rows: &[DiffRow]) -> String {
    let mut s = String::from("| metric | baseline | current | change | status |\n");
    s.push_str("|---|---:|---:|---:|---|\n");
    for r in rows {
        let status = if r.regressed {
            "❌ regressed"
        } else if r.base.is_none() {
            "new"
        } else if r.cur.is_none() {
            "missing"
        } else {
            "ok"
        };
        s.push_str(&format!(
            "| `{}` {} | {} | {} | {} | {} |\n",
            r.name,
            if r.higher_is_better { "↑" } else { "↓" },
            fmt_opt(r.base),
            fmt_opt(r.cur),
            fmt_change(r),
            status
        ));
    }
    s
}

/// The comparison as a plain console table.
pub fn render_console(rows: &[DiffRow]) -> String {
    let mut s = format!(
        "{:<56} {:>12} {:>12} {:>8}  {}\n",
        "metric", "baseline", "current", "change", "status"
    );
    for r in rows {
        let status = if r.regressed {
            "REGRESSED"
        } else if r.base.is_none() {
            "new"
        } else if r.cur.is_none() {
            "missing"
        } else {
            "ok"
        };
        s.push_str(&format!(
            "{:<56} {:>12} {:>12} {:>8}  {}\n",
            r.name,
            fmt_opt(r.base),
            fmt_opt(r.cur),
            fmt_change(r),
            status
        ));
    }
    s
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        iters,
        min: times[0],
        mean: times.iter().sum::<f64>() / iters as f64,
        p50: times[iters / 2],
        max: times[iters - 1],
    }
}

/// Print one standard bench row.
pub fn report(name: &str, stats: &BenchStats, extra: &str) {
    println!(
        "{name:<44} mean {:>9.3} ms  p50 {:>9.3} ms  min {:>9.3} ms  {extra}",
        stats.mean * 1e3,
        stats.p50 * 1e3,
        stats.min * 1e3,
    );
}

/// Print a bench section header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(s.iters, 5);
        assert!(s.min >= 0.001);
        assert!(s.mean >= s.min && s.max >= s.mean);
        assert!(s.per_sec(10.0) > 0.0);
        assert!(s.gb_per_sec(1e9) > 0.0);
    }

    #[test]
    fn stats_to_json_has_throughput_fields() {
        let s = BenchStats {
            iters: 4,
            min: 0.001,
            mean: 0.002,
            p50: 0.002,
            max: 0.003,
        };
        let j = s.to_json(Some(32.0));
        assert_eq!(j.req("iters").unwrap().as_usize().unwrap(), 4);
        let per_sec = j.req("items_per_sec").unwrap().as_f64().unwrap();
        assert!((per_sec - 16_000.0).abs() < 1e-6, "{per_sec}");
        assert!(s.to_json(None).get("items_per_sec").is_none());
    }

    #[test]
    fn merge_section_accumulates_sections() {
        let path = std::env::temp_dir().join(format!(
            "ferrisfl_bench_test_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        merge_section_at(&path, "a", Json::num(1.0));
        merge_section_at(&path, "b", Json::num(2.0));
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.req("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(root.req("b").unwrap().as_f64().unwrap(), 2.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_section_survives_truncated_file_and_backs_it_up() {
        let path = std::env::temp_dir().join(format!(
            "ferrisfl_bench_corrupt_{}.json",
            std::process::id()
        ));
        let backup = path.with_extension("json.corrupt");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&backup);
        // A truncated snapshot (interrupted writer).
        std::fs::write(&path, "{\"train_step\": {\"cases\": {\"ml").unwrap();
        merge_section_at(&path, "fresh", Json::num(7.0));
        // The merge produced a valid snapshot with the new section...
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.req("fresh").unwrap().as_f64().unwrap(), 7.0);
        // ...and preserved the corrupt content for inspection.
        let saved = std::fs::read_to_string(&backup).unwrap();
        assert!(saved.starts_with("{\"train_step\""));
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&backup);
    }

    #[test]
    fn merge_section_replaces_non_object_roots() {
        let path = std::env::temp_dir().join(format!(
            "ferrisfl_bench_nonobj_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, "[1, 2, 3]").unwrap();
        merge_section_at(&path, "s", Json::num(1.0));
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.req("s").unwrap().as_f64().unwrap(), 1.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_json_default_is_workspace_rooted() {
        // Only exercised when the env override is absent (the common
        // local case); CI sets FERRISFL_BENCH_JSON explicitly.
        if crate::util::env::bench_json().is_none() {
            let p = bench_json_path();
            assert!(p.ends_with("BENCH_native.json"));
            assert!(p.is_absolute(), "default must not depend on CWD: {p:?}");
            assert_eq!(p.parent().unwrap(), workspace_root());
        }
    }

    // ------------------------------------------------- regression diff

    fn snapshot(round_ms: f64, steps_per_sec: f64, gbs: f64) -> Json {
        Json::parse(&format!(
            r#"{{
              "train_step": {{
                "simd": "avx2", "threads": 4,
                "cases": {{"mlp-s@synth-mnist sgd full": {{"items_per_sec": {steps_per_sec}}}}},
                "naive_vs_blocked": {{"steps_per_sec_blocked": {steps_per_sec}, "speedup": 3.0}},
                "parallel": {{"threads": 4, "steps_per_sec_serial": 50.0,
                              "steps_per_sec_parallel": {steps_per_sec}, "speedup": 2.1}},
                "fused": {{"slots": 4, "agent_steps_per_sec_unfused": 400.0,
                           "agent_steps_per_sec_fused": {steps_per_sec}, "speedup": 1.4}}
              }},
              "round_e2e": {{"round_walltime": {{"workers_4": {{"mean_ms": {round_ms}}}}}}},
              "aggregation": {{"fedavg": {{"lenet5 K=8 offload": {{"gb_per_sec": {gbs}}}}}}},
              "kernels": {{"dispatch": "avx2", "cases": {{
                "axpy8_2": {{"gflops_scalar": 9.0, "gflops_simd": {gbs}, "speedup": 2.0}}
              }}}}
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn collect_metrics_extracts_all_sections() {
        let m = collect_metrics(&snapshot(120.0, 5000.0, 2.5));
        let names: Vec<&str> = m.iter().map(|x| x.name.as_str()).collect();
        assert!(names.contains(&"round_e2e/workers_4/mean_ms"));
        assert!(names.contains(&"aggregation/fedavg/lenet5 K=8 offload/gb_per_sec"));
        assert!(names.contains(&"train_step/naive_vs_blocked/steps_per_sec_blocked"));
        assert!(
            !names.contains(&"train_step/naive_vs_blocked/speedup"),
            "the naive-vs-blocked ratio must not gate (noisy on shared runners)"
        );
        assert!(names
            .contains(&"train_step/cases/mlp-s@synth-mnist sgd full/items_per_sec"));
        assert!(names.contains(&"kernels/axpy8_2/gflops_simd"));
        assert!(
            !names.contains(&"kernels/axpy8_2/speedup")
                && !names.contains(&"kernels/axpy8_2/gflops_scalar"),
            "kernel ratios and the scalar side must not gate"
        );
        let round = m.iter().find(|x| x.name.contains("mean_ms")).unwrap();
        assert!(!round.higher_is_better, "walltime gates on increases");
        // New multi-core rows: only the parallel/fused absolutes gate.
        assert!(names.contains(&"train_step/parallel/steps_per_sec_parallel"));
        assert!(names.contains(&"train_step/fused/agent_steps_per_sec_fused"));
        assert!(
            !names.contains(&"train_step/parallel/steps_per_sec_serial")
                && !names.contains(&"train_step/parallel/speedup")
                && !names.contains(&"train_step/fused/agent_steps_per_sec_unfused"),
            "serial/unfused sides and ratios must not gate"
        );
    }

    #[test]
    fn section_meta_reports_dispatch_and_threads() {
        let meta = section_meta(&snapshot(100.0, 5000.0, 2.0));
        let train = meta.iter().find(|s| s.starts_with("train_step")).unwrap();
        assert!(train.contains("simd=avx2"), "{train}");
        assert!(train.contains("threads=4"), "{train}");
        let kernels = meta.iter().find(|s| s.starts_with("kernels")).unwrap();
        assert!(kernels.contains("dispatch=avx2"), "{kernels}");
        assert!(section_meta(&Json::num(3.0)).is_empty());
    }

    #[test]
    fn diff_passes_within_threshold_and_fails_on_2x_slowdown() {
        let base = snapshot(100.0, 5000.0, 2.0);
        // 10% slower round, 10% fewer steps/s: inside a 25% gate.
        let drift = snapshot(110.0, 4500.0, 1.9);
        let (rows, regressed) = diff(&base, &drift, 0.25);
        assert!(!regressed, "{}", render_console(&rows));
        // An injected 2x slowdown must trip the gate.
        let slow = snapshot(200.0, 2500.0, 2.0);
        let (rows, regressed) = diff(&base, &slow, 0.25);
        assert!(regressed);
        let bad: Vec<&DiffRow> = rows.iter().filter(|r| r.regressed).collect();
        assert!(bad.iter().any(|r| r.name == "round_e2e/workers_4/mean_ms"));
        assert!(bad.iter().any(|r| r.name.contains("items_per_sec")));
        // Improvements never gate, in either direction convention.
        let fast = snapshot(50.0, 10_000.0, 4.0);
        let (_, regressed) = diff(&base, &fast, 0.25);
        assert!(!regressed);
    }

    #[test]
    fn diff_tolerates_missing_and_new_metrics() {
        let base = snapshot(100.0, 5000.0, 2.0);
        let cur = Json::parse(
            r#"{"round_e2e": {"round_walltime": {"workers_4": {"mean_ms": 90.0},
                "workers_8": {"mean_ms": 60.0}}}}"#,
        )
        .unwrap();
        let (rows, regressed) = diff(&base, &cur, 0.25);
        assert!(!regressed, "absent metrics must not gate");
        assert!(rows.iter().any(|r| r.base.is_some() && r.cur.is_none()));
        assert!(rows.iter().any(|r| r.name == "round_e2e/workers_8/mean_ms" && r.base.is_none()));
        let md = render_markdown(&rows);
        assert!(md.contains("| metric |"));
        assert!(md.contains("missing"));
        assert!(md.contains("new"));
    }

    #[test]
    fn provisional_baselines_are_flagged() {
        assert!(is_provisional(
            &Json::parse(r#"{"provisional": true}"#).unwrap()
        ));
        assert!(!is_provisional(&snapshot(1.0, 1.0, 1.0)));
    }
}
