//! Tiny benchmark harness used by `cargo bench` targets.
//!
//! The vendored crate set carries no criterion, so the bench binaries
//! (`rust/benches/*.rs`, `harness = false`) use this: warmup + N timed
//! iterations, reporting min/mean/p50/max. Deterministic workloads, wall
//! clock, no statistics theatre — adequate for the before/after deltas
//! EXPERIMENTS.md §Perf tracks.
//!
//! Benches additionally emit throughput counters (steps/s, examples/s,
//! aggregation GB/s) into `BENCH_native.json` via [`merge_section`]:
//! each bench target owns one top-level section and read-modify-writes
//! the file, so running several benches accumulates one machine-readable
//! perf snapshot per checkout. CI runs the two smoke benches in fast
//! mode (`FERRISFL_BENCH_FAST=1`, see [`fast_mode`]) and uploads the
//! file as an artifact — the measured-perf trajectory of the repo.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::Json;

/// Timing summary over the measured iterations (seconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub max: f64,
}

impl BenchStats {
    /// Throughput in items/sec given items processed per iteration.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean
    }

    /// Throughput in GB/s given bytes touched per iteration.
    pub fn gb_per_sec(&self, bytes_per_iter: f64) -> f64 {
        bytes_per_iter / self.mean / 1e9
    }

    /// This measurement as a JSON object (times in ms, plus throughput
    /// fields when `items_per_iter` is given).
    pub fn to_json(&self, items_per_iter: Option<f64>) -> Json {
        let mut pairs = vec![
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean * 1e3)),
            ("p50_ms", Json::num(self.p50 * 1e3)),
            ("min_ms", Json::num(self.min * 1e3)),
            ("max_ms", Json::num(self.max * 1e3)),
        ];
        if let Some(items) = items_per_iter {
            pairs.push(("items_per_iter", Json::num(items)));
            pairs.push(("items_per_sec", Json::num(self.per_sec(items))));
        }
        Json::obj(pairs)
    }
}

/// True when `FERRISFL_BENCH_FAST` is set (and not "0"): benches shrink
/// workloads/iterations so CI can smoke-run them on every merge.
pub fn fast_mode() -> bool {
    std::env::var("FERRISFL_BENCH_FAST").map(|v| v != "0").unwrap_or(false)
}

/// Scale an iteration count down in fast mode (≥1 always).
pub fn scaled_iters(iters: usize) -> usize {
    if fast_mode() {
        (iters / 4).max(1)
    } else {
        iters
    }
}

/// Where bench JSON goes: `$FERRISFL_BENCH_JSON`, else
/// `BENCH_native.json` in the bench binary's working directory (the
/// *package* dir, `rust/`, under `cargo bench` — CI pins the env var to
/// the workspace root so the artifact upload finds it).
pub fn bench_json_path() -> PathBuf {
    std::env::var("FERRISFL_BENCH_JSON")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("BENCH_native.json"))
}

/// Read-modify-write one top-level section of the bench JSON file, so
/// each bench target contributes its own section and a sequence of
/// bench runs accumulates a single perf snapshot.
pub fn merge_section(section: &str, value: Json) {
    merge_section_at(&bench_json_path(), section, value);
}

/// [`merge_section`] against an explicit path (tests use a temp file).
pub fn merge_section_at(path: &std::path::Path, section: &str, value: Json) {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| Json::obj(vec![]));
    if !matches!(root, Json::Obj(_)) {
        root = Json::obj(vec![]);
    }
    if let Json::Obj(map) = &mut root {
        map.insert(section.to_string(), value);
    }
    if let Err(e) = std::fs::write(path, root.to_string()) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("\n[bench] wrote section {section:?} to {}", path.display());
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        iters,
        min: times[0],
        mean: times.iter().sum::<f64>() / iters as f64,
        p50: times[iters / 2],
        max: times[iters - 1],
    }
}

/// Print one standard bench row.
pub fn report(name: &str, stats: &BenchStats, extra: &str) {
    println!(
        "{name:<44} mean {:>9.3} ms  p50 {:>9.3} ms  min {:>9.3} ms  {extra}",
        stats.mean * 1e3,
        stats.p50 * 1e3,
        stats.min * 1e3,
    );
}

/// Print a bench section header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(s.iters, 5);
        assert!(s.min >= 0.001);
        assert!(s.mean >= s.min && s.max >= s.mean);
        assert!(s.per_sec(10.0) > 0.0);
        assert!(s.gb_per_sec(1e9) > 0.0);
    }

    #[test]
    fn stats_to_json_has_throughput_fields() {
        let s = BenchStats {
            iters: 4,
            min: 0.001,
            mean: 0.002,
            p50: 0.002,
            max: 0.003,
        };
        let j = s.to_json(Some(32.0));
        assert_eq!(j.req("iters").unwrap().as_usize().unwrap(), 4);
        let per_sec = j.req("items_per_sec").unwrap().as_f64().unwrap();
        assert!((per_sec - 16_000.0).abs() < 1e-6, "{per_sec}");
        assert!(s.to_json(None).get("items_per_sec").is_none());
    }

    #[test]
    fn merge_section_accumulates_sections() {
        let path = std::env::temp_dir().join(format!(
            "ferrisfl_bench_test_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        merge_section_at(&path, "a", Json::num(1.0));
        merge_section_at(&path, "b", Json::num(2.0));
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.req("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(root.req("b").unwrap().as_f64().unwrap(), 2.0);
        let _ = std::fs::remove_file(&path);
    }
}
