//! Tiny benchmark harness used by `cargo bench` targets.
//!
//! The vendored crate set carries no criterion, so the bench binaries
//! (`rust/benches/*.rs`, `harness = false`) use this: warmup + N timed
//! iterations, reporting min/mean/p50/max. Deterministic workloads, wall
//! clock, no statistics theatre — adequate for the before/after deltas
//! EXPERIMENTS.md §Perf tracks.

use std::time::Instant;

/// Timing summary over the measured iterations (seconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub max: f64,
}

impl BenchStats {
    /// Throughput in items/sec given items processed per iteration.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        iters,
        min: times[0],
        mean: times.iter().sum::<f64>() / iters as f64,
        p50: times[iters / 2],
        max: times[iters - 1],
    }
}

/// Print one standard bench row.
pub fn report(name: &str, stats: &BenchStats, extra: &str) {
    println!(
        "{name:<44} mean {:>9.3} ms  p50 {:>9.3} ms  min {:>9.3} ms  {extra}",
        stats.mean * 1e3,
        stats.p50 * 1e3,
        stats.min * 1e3,
    );
}

/// Print a bench section header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(s.iters, 5);
        assert!(s.min >= 0.001);
        assert!(s.mean >= s.min && s.max >= s.mean);
        assert!(s.per_sec(10.0) > 0.0);
    }
}
