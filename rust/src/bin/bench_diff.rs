//! `bench_diff` — the CI perf-regression gate.
//!
//! Compares a fresh `BENCH_native.json` against the committed
//! `BENCH_baseline.json` and exits non-zero when any gated metric
//! (steps/s, examples/s, round walltime, aggregation GB/s — see
//! `benchutil::collect_metrics`) regressed beyond the threshold.
//!
//! ```sh
//! bench_diff <baseline.json> <current.json> [--max-regress 0.25]
//! ```
//!
//! When `$GITHUB_STEP_SUMMARY` is set, the per-metric delta table is
//! appended there as markdown (the job summary page). A baseline whose
//! top level carries `"provisional": true` reports but never fails —
//! the bootstrap state before a real CI measurement is promoted into
//! the committed file.

use std::process::ExitCode;

use ferrisfl::benchutil::{diff, is_provisional, render_console, render_markdown, section_meta};
use ferrisfl::util::Json;

fn usage() -> ExitCode {
    eprintln!("usage: bench_diff <baseline.json> <current.json> [--max-regress <frac>]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths: Vec<&str> = Vec::new();
    let mut max_regress = 0.25f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--max-regress" => {
                i += 1;
                let Some(v) = args.get(i).and_then(|v| v.parse::<f64>().ok()) else {
                    return usage();
                };
                if !(v.is_finite() && v > 0.0) {
                    return usage();
                }
                max_regress = v;
            }
            flag if flag.starts_with("--") => return usage(),
            p => paths.push(p),
        }
        i += 1;
    }
    let &[base_path, cur_path] = paths.as_slice() else {
        return usage();
    };

    let base_text = match std::fs::read_to_string(base_path) {
        Ok(t) => t,
        Err(e) => {
            // No baseline committed yet (forks, fresh checkouts): report
            // only, don't gate.
            println!("bench_diff: no baseline at {base_path} ({e}); nothing to gate against");
            return ExitCode::SUCCESS;
        }
    };
    let base = match Json::parse(&base_text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("bench_diff: baseline {base_path} is not valid JSON: {e}");
            return ExitCode::FAILURE;
        }
    };
    let cur_text = match std::fs::read_to_string(cur_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench_diff: cannot read current snapshot {cur_path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let cur = match Json::parse(&cur_text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("bench_diff: current snapshot {cur_path} is not valid JSON: {e}");
            return ExitCode::FAILURE;
        }
    };

    let provisional = is_provisional(&base);
    let (rows, regressed) = diff(&base, &cur, max_regress);
    println!(
        "bench gate: {} metric(s), threshold {:.0}%{}",
        rows.len(),
        max_regress * 100.0,
        if provisional { " (provisional baseline: report-only)" } else { "" }
    );
    // The dispatch level and panel-thread count each bench stamped into
    // its section — so a delta always states what mode produced it.
    let meta = section_meta(&cur);
    if !meta.is_empty() {
        println!("current sections: {}", meta.join("; "));
    }
    let base_meta = section_meta(&base);
    if !base_meta.is_empty() {
        println!("baseline sections: {}", base_meta.join("; "));
    }
    println!();
    print!("{}", render_console(&rows));

    if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write as _;
        let header = format!(
            "## Bench gate ({} metrics, ≤{:.0}% regression{})\n\n",
            rows.len(),
            max_regress * 100.0,
            if provisional { ", provisional baseline" } else { "" }
        );
        let table = render_markdown(&rows);
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&summary) {
            let _ = f.write_all(header.as_bytes());
            let _ = f.write_all(table.as_bytes());
            let _ = f.write_all(b"\n");
        }
    }

    if regressed && !provisional {
        eprintln!("\nbench_diff: perf regression beyond {:.0}%", max_regress * 100.0);
        return ExitCode::FAILURE;
    }
    if regressed {
        println!("\nbench_diff: regressions detected but baseline is provisional; not gating");
    } else {
        println!("\nbench_diff: OK");
    }
    ExitCode::SUCCESS
}
