//! Update compression — the communication-efficiency substrate
//! (paper §6.3 names gradient/parameter compression as a target
//! extension; cross-device FL is upload-bound).
//!
//! A [`Compressor`] turns a dense delta into a [`CompressedDelta`] on
//! the client and reconstructs it on the server, tracking wire bytes so
//! experiments can trade accuracy against upload size:
//!
//! - [`TopK`] — keep the `k` largest-magnitude coordinates (classic
//!   sparsification; unbiased under error feedback, here plain).
//! - [`RandK`] — keep `k` random coordinates, rescaled by `d/k` so the
//!   expectation matches the dense delta.
//! - [`Int8`] — per-tensor affine quantization to i8.
//! - [`NoCompression`] — identity baseline.

use crate::util::error::{bail, Result};
use crate::util::Rng;

/// A compressed client→server update plus bookkeeping.
#[derive(Clone, Debug)]
pub enum CompressedDelta {
    Dense(Vec<f32>),
    /// (dim, indices, values)
    Sparse {
        dim: usize,
        idx: Vec<u32>,
        val: Vec<f32>,
        /// rescale factor applied at decompression (RandK uses d/k).
        scale: f32,
    },
    /// Per-tensor affine i8: value = q * scale + zero.
    Quantized {
        q: Vec<i8>,
        scale: f32,
        zero: f32,
    },
}

impl CompressedDelta {
    /// Bytes this update would cost on the wire.
    pub fn wire_bytes(&self) -> usize {
        match self {
            CompressedDelta::Dense(v) => v.len() * 4,
            CompressedDelta::Sparse { idx, val, .. } => idx.len() * 4 + val.len() * 4 + 8,
            CompressedDelta::Quantized { q, .. } => q.len() + 8,
        }
    }

    /// Reconstruct the dense delta.
    pub fn decompress(&self) -> Vec<f32> {
        match self {
            CompressedDelta::Dense(v) => v.clone(),
            CompressedDelta::Sparse {
                dim,
                idx,
                val,
                scale,
            } => {
                let mut out = vec![0.0f32; *dim];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v * scale;
                }
                out
            }
            CompressedDelta::Quantized { q, scale, zero } => {
                q.iter().map(|&qi| qi as f32 * scale + zero).collect()
            }
        }
    }
}

/// Client-side compression strategy.
pub trait Compressor: Send {
    fn compress(&mut self, delta: &[f32]) -> CompressedDelta;

    /// True when compress→decompress reproduces the delta exactly and
    /// costs nothing on the wire accounting beyond dense bytes — the
    /// entrypoint may then skip the round-trip entirely (and stream
    /// the round).
    fn is_identity(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Identity baseline.
#[derive(Default)]
pub struct NoCompression;

impl Compressor for NoCompression {
    fn compress(&mut self, delta: &[f32]) -> CompressedDelta {
        CompressedDelta::Dense(delta.to_vec())
    }

    fn is_identity(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

/// Keep the fraction `frac` of largest-|.| coordinates.
pub struct TopK {
    pub frac: f64,
}

impl TopK {
    pub fn new(frac: f64) -> Self {
        assert!((0.0..=1.0).contains(&frac) && frac > 0.0);
        Self { frac }
    }
}

impl Compressor for TopK {
    fn compress(&mut self, delta: &[f32]) -> CompressedDelta {
        let d = delta.len();
        let k = ((d as f64 * self.frac).ceil() as usize).clamp(1, d);
        // Partial select: indices of the k largest magnitudes.
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
            delta[b as usize]
                .abs()
                .partial_cmp(&delta[a as usize].abs())
                .unwrap()
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| delta[i as usize]).collect();
        CompressedDelta::Sparse {
            dim: d,
            idx,
            val,
            scale: 1.0,
        }
    }

    fn name(&self) -> &'static str {
        "topk"
    }
}

/// Keep `frac` random coordinates, unbiased (scaled by 1/frac).
pub struct RandK {
    pub frac: f64,
    rng: Rng,
}

impl RandK {
    pub fn new(frac: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&frac) && frac > 0.0);
        Self {
            frac,
            rng: Rng::new(seed),
        }
    }
}

impl Compressor for RandK {
    fn compress(&mut self, delta: &[f32]) -> CompressedDelta {
        let d = delta.len();
        let k = ((d as f64 * self.frac).ceil() as usize).clamp(1, d);
        let mut idx: Vec<u32> = self
            .rng
            .sample_indices(d, k)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| delta[i as usize]).collect();
        CompressedDelta::Sparse {
            dim: d,
            idx,
            val,
            scale: (d as f64 / k as f64) as f32,
        }
    }

    fn name(&self) -> &'static str {
        "randk"
    }
}

/// Per-tensor affine i8 quantization.
#[derive(Default)]
pub struct Int8;

impl Compressor for Int8 {
    fn compress(&mut self, delta: &[f32]) -> CompressedDelta {
        let lo = delta.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = delta.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let (lo, hi) = if lo.is_finite() && hi.is_finite() {
            (lo, hi)
        } else {
            (0.0, 0.0)
        };
        let scale = ((hi - lo) / 254.0).max(1e-12);
        let zero = (lo + hi) * 0.5;
        let q = delta
            .iter()
            .map(|&v| (((v - zero) / scale).round().clamp(-127.0, 127.0)) as i8)
            .collect();
        CompressedDelta::Quantized { q, scale, zero }
    }

    fn name(&self) -> &'static str {
        "int8"
    }
}

/// Parse a config name: `none | topk:<frac> | randk:<frac> | int8`.
pub fn from_name(name: &str, seed: u64) -> Result<Box<dyn Compressor>> {
    let t = name.trim().to_ascii_lowercase();
    if t == "none" || t.is_empty() {
        return Ok(Box::new(NoCompression));
    }
    if t == "int8" {
        return Ok(Box::new(Int8));
    }
    if let Some(rest) = t.strip_prefix("topk:") {
        return Ok(Box::new(TopK::new(rest.parse()?)));
    }
    if let Some(rest) = t.strip_prefix("randk:") {
        return Ok(Box::new(RandK::new(rest.parse()?, seed)));
    }
    bail!("unknown compressor {name:?} (none | topk:<f> | randk:<f> | int8)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.next_gaussian()).collect()
    }

    #[test]
    fn dense_round_trips_exactly() {
        let d = delta(100, 1);
        let c = NoCompression.compress(&d);
        assert_eq!(c.decompress(), d);
        assert_eq!(c.wire_bytes(), 400);
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let d = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let c = TopK::new(0.4).compress(&d);
        let out = c.decompress();
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
        // 2 entries x (4B idx + 4B val) + 8B header
        assert_eq!(c.wire_bytes(), 24);
    }

    #[test]
    fn topk_wire_bytes_scale_with_frac() {
        let d = delta(10_000, 2);
        let small = TopK::new(0.01).compress(&d).wire_bytes();
        let big = TopK::new(0.5).compress(&d).wire_bytes();
        assert!(small < big);
        // k=100 entries -> 100*8 + 8 header, far below the 40 KB dense cost
        assert!(small <= 10_000 * 4 / 49);
    }

    #[test]
    fn randk_is_unbiased_in_expectation() {
        let d = vec![1.0f32; 1000];
        let mut c = RandK::new(0.1, 7);
        // Average many reconstructions: each coordinate ~ 1.0.
        let mut acc = vec![0.0f64; 1000];
        let reps = 300;
        for _ in 0..reps {
            for (a, v) in acc.iter_mut().zip(c.compress(&d).decompress()) {
                *a += v as f64;
            }
        }
        let mean: f64 = acc.iter().map(|a| a / reps as f64).sum::<f64>() / 1000.0;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn int8_bounded_error() {
        let d = delta(5000, 3);
        let c = Int8.compress(&d);
        let out = c.decompress();
        let lo = d.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = d.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let step = (hi - lo) / 254.0;
        for (a, b) in d.iter().zip(&out) {
            assert!((a - b).abs() <= step * 0.75 + 1e-6);
        }
        assert_eq!(c.wire_bytes(), 5008);
    }

    #[test]
    fn int8_constant_vector() {
        let d = vec![0.5f32; 64];
        let out = Int8.compress(&d).decompress();
        for v in out {
            assert!((v - 0.5).abs() < 1e-5);
        }
    }

    #[test]
    fn from_name_parses() {
        for n in ["none", "topk:0.1", "randk:0.05", "int8"] {
            assert!(from_name(n, 0).is_ok(), "{n}");
        }
        assert!(from_name("zstd", 0).is_err());
    }

    /// Only the identity compressor may advertise exact round-tripping
    /// — the round pipeline streams (skips the wire round-trip) based
    /// on this probe.
    #[test]
    fn only_nocompression_is_identity() {
        assert!(from_name("none", 0).unwrap().is_identity());
        for n in ["topk:0.1", "randk:0.05", "int8"] {
            assert!(!from_name(n, 0).unwrap().is_identity(), "{n}");
        }
    }
}
