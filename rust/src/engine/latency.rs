//! Per-client latency models (config: `engine.latency`).
//!
//! How long a sampled client takes between dispatch and its delta
//! arriving at the server — compute plus upload, as one number. Samples
//! are drawn from an independent SplitMix64 stream keyed by
//! `(seed, agent_id, round)`, so a given client's latency in a given
//! round is a pure function of the experiment seed: straggler patterns
//! are bit-reproducible and independent of the training RNG streams.

use std::str::FromStr;

use crate::util::error::{bail, Context, Error, Result};
use crate::util::Rng;

/// Salt decorrelating latency streams from every other use of the seed.
const LATENCY_SALT: u64 = 0x4C41_5445_4E43_59; // "LATENCY"

/// A per-client latency distribution, in seconds.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum LatencyModel {
    /// Zero latency — every client "arrives" the instant it is
    /// dispatched. The degenerate (lockstep) model; the default.
    #[default]
    None,
    /// Every client takes exactly this many seconds.
    Constant(f64),
    /// Lognormal: `median * exp(sigma * Z)`, `Z ~ N(0,1)`. The classic
    /// heavy-tailed straggler model (a few clients are much slower).
    Lognormal {
        /// Median latency in seconds (the `exp(mu)` of the lognormal).
        median: f64,
        /// Log-scale spread (0 = constant at the median).
        sigma: f64,
    },
    /// Each sample is drawn uniformly from this list — replay measured
    /// device latencies.
    Trace(Vec<f64>),
}

impl LatencyModel {
    /// True for the zero-latency (lockstep) model.
    pub fn is_none(&self) -> bool {
        matches!(self, LatencyModel::None)
    }

    /// The latency of `agent_id` in `round`, in seconds. Deterministic:
    /// a pure function of `(seed, agent_id, round)`.
    pub fn sample(&self, seed: u64, agent_id: usize, round: usize) -> f64 {
        self.sample_attempt(seed, agent_id, round, 0)
    }

    /// The latency of retry attempt `attempt` (0 = the original
    /// dispatch, which draws exactly [`LatencyModel::sample`]'s stream;
    /// retries split the stream once more so each attempt redraws
    /// independently but reproducibly).
    pub fn sample_attempt(&self, seed: u64, agent_id: usize, round: usize, attempt: u32) -> f64 {
        let mut rng = || {
            let r = Rng::new(seed ^ LATENCY_SALT).split(agent_id as u64).split(round as u64);
            if attempt == 0 {
                r
            } else {
                r.split(attempt as u64)
            }
        };
        match self {
            LatencyModel::None => 0.0,
            LatencyModel::Constant(secs) => *secs,
            LatencyModel::Lognormal { median, sigma } => {
                let z = rng().next_gaussian() as f64;
                (median.max(1e-12).ln() + sigma * z).exp()
            }
            LatencyModel::Trace(samples) => {
                samples[rng().next_below(samples.len() as u64) as usize]
            }
        }
    }

    /// Reject models a struct literal could build but parsing would not:
    /// negative/non-finite parameters or an empty trace.
    pub fn validate(&self) -> Result<()> {
        let ok = |v: f64| v.is_finite() && v >= 0.0;
        match self {
            LatencyModel::None => Ok(()),
            LatencyModel::Constant(secs) if ok(*secs) => Ok(()),
            LatencyModel::Lognormal { median, sigma } if ok(*median) && ok(*sigma) => Ok(()),
            LatencyModel::Trace(s) if !s.is_empty() && s.iter().all(|&v| ok(v)) => Ok(()),
            other => bail!("invalid latency model {other:?} (negative, non-finite, or empty)"),
        }
    }
}

impl FromStr for LatencyModel {
    type Err = Error;

    /// `none` | `constant:SECS` | `lognormal:MEDIAN,SIGMA` |
    /// `trace:S1,S2,...` — the config/CLI syntax.
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        let model = match s.split_once(':') {
            None => match s.to_ascii_lowercase().as_str() {
                "" | "none" | "0" => LatencyModel::None,
                other => bail!(
                    "unknown latency model {other:?} \
                     (none | constant:SECS | lognormal:MEDIAN,SIGMA | trace:S1,S2,...)"
                ),
            },
            Some((name, args)) => match name.trim().to_ascii_lowercase().as_str() {
                "constant" => LatencyModel::Constant(
                    args.trim().parse::<f64>().with_context(|| format!("constant:{args}"))?,
                ),
                "lognormal" => {
                    let (median, sigma) = args
                        .split_once(',')
                        .with_context(|| format!("lognormal needs MEDIAN,SIGMA, got {args:?}"))?;
                    let median = median.trim().parse::<f64>().context("lognormal MEDIAN")?;
                    let sigma = sigma.trim().parse::<f64>().context("lognormal SIGMA")?;
                    LatencyModel::Lognormal { median, sigma }
                }
                "trace" => LatencyModel::Trace(
                    args.split(',')
                        .map(|v| v.trim().parse::<f64>().with_context(|| format!("trace {v:?}")))
                        .collect::<Result<Vec<f64>>>()?,
                ),
                other => bail!("unknown latency model {other:?}"),
            },
        };
        model.validate()?;
        Ok(model)
    }
}

impl std::fmt::Display for LatencyModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LatencyModel::None => f.write_str("none"),
            LatencyModel::Constant(secs) => write!(f, "constant:{secs}"),
            LatencyModel::Lognormal { median, sigma } => write!(f, "lognormal:{median},{sigma}"),
            LatencyModel::Trace(samples) => {
                f.write_str("trace:")?;
                for (i, s) in samples.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{s}")?;
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_roundtrips() {
        for spec in ["none", "constant:0.5", "lognormal:1,0.5", "trace:0.1,0.5,2"] {
            let m: LatencyModel = spec.parse().unwrap();
            assert_eq!(m.to_string().parse::<LatencyModel>().unwrap(), m, "{spec}");
        }
        assert_eq!("".parse::<LatencyModel>().unwrap(), LatencyModel::None);
        assert_eq!("0".parse::<LatencyModel>().unwrap(), LatencyModel::None);
        assert!("warp:9".parse::<LatencyModel>().is_err());
        assert!("constant:-1".parse::<LatencyModel>().is_err());
        assert!("lognormal:1".parse::<LatencyModel>().is_err());
        assert!("trace:".parse::<LatencyModel>().is_err());
    }

    #[test]
    fn samples_are_deterministic_per_agent_round() {
        let m: LatencyModel = "lognormal:1.0,0.8".parse().unwrap();
        let a = m.sample(42, 3, 5);
        assert_eq!(a.to_bits(), m.sample(42, 3, 5).to_bits());
        assert_ne!(a.to_bits(), m.sample(42, 4, 5).to_bits(), "per-agent streams differ");
        assert_ne!(a.to_bits(), m.sample(42, 3, 6).to_bits(), "per-round streams differ");
        assert_ne!(a.to_bits(), m.sample(43, 3, 5).to_bits(), "per-seed streams differ");
        assert!(a > 0.0 && a.is_finite());
    }

    #[test]
    fn attempt_zero_is_the_base_sample_and_retries_redraw() {
        let m: LatencyModel = "lognormal:1.0,0.8".parse().unwrap();
        let base = m.sample(42, 3, 5);
        assert_eq!(base.to_bits(), m.sample_attempt(42, 3, 5, 0).to_bits());
        let retry1 = m.sample_attempt(42, 3, 5, 1);
        let retry2 = m.sample_attempt(42, 3, 5, 2);
        assert_ne!(base.to_bits(), retry1.to_bits(), "retries redraw");
        assert_ne!(retry1.to_bits(), retry2.to_bits(), "per-attempt streams differ");
        assert_eq!(retry1.to_bits(), m.sample_attempt(42, 3, 5, 1).to_bits(), "replay is exact");
        // Constant models are attempt-invariant by construction.
        let c: LatencyModel = "constant:2.5".parse().unwrap();
        assert_eq!(c.sample_attempt(1, 2, 3, 7), 2.5);
    }

    #[test]
    fn trace_samples_come_from_the_trace() {
        let m: LatencyModel = "trace:0.25,1.5,4.0".parse().unwrap();
        for aid in 0..32 {
            let s = m.sample(7, aid, 0);
            assert!([0.25, 1.5, 4.0].contains(&s), "got {s}");
        }
    }

    #[test]
    fn zero_and_constant_models() {
        assert_eq!(LatencyModel::None.sample(1, 2, 3), 0.0);
        assert!(LatencyModel::None.is_none());
        let c: LatencyModel = "constant:2.5".parse().unwrap();
        assert_eq!(c.sample(1, 2, 3), 2.5);
        assert!(!c.is_none());
    }

    #[test]
    fn lognormal_median_is_roughly_right() {
        let m = LatencyModel::Lognormal { median: 2.0, sigma: 0.5 };
        let mut xs: Vec<f64> = (0..4001).map(|aid| m.sample(11, aid, 0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        assert!((med - 2.0).abs() < 0.2, "empirical median {med}");
    }
}
