//! The round scheduling policy — when a round stops collecting updates
//! and how late updates are weighted.

use super::clock::{ClockKind, SimTime};
use super::faults::FaultPlan;
use super::latency::LatencyModel;
use super::recovery::RecoveryPolicy;

/// Fixed-point scale applied to buffered-mode stream weights so the
/// staleness discount survives integer rounding: a weight is
/// `round(base * 1024 / (1 + staleness)^alpha)`. The scale cancels in
/// the accumulator's normalized weighted mean. It is applied to *every*
/// update of a non-degenerate run (never mixed with unscaled weights),
/// and not at all under the degenerate policy — scaling perturbs the
/// fixed-point quantisation, and degenerate runs are pinned
/// bit-identical to the lockstep reference.
const STALENESS_WEIGHT_SCALE: f64 = 1024.0;

/// Everything the engine needs to schedule a run, derived from
/// `FlParams` by `FlParams::round_policy`.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundPolicy {
    /// Per-client dispatch→arrival latency distribution.
    pub latency: LatencyModel,
    /// Collection window per round; `None` waits for every arrival.
    pub deadline: Option<SimTime>,
    /// Buffered-aggregation goal: finalize the round as soon as this
    /// many updates (fresh + stale) arrived — FedBuff's buffer size K.
    pub goal: Option<usize>,
    /// Staleness discount exponent `alpha` in `(1 + staleness)^-alpha`
    /// (staleness = rounds between dispatch and application).
    pub staleness_alpha: f64,
    /// Virtual (simulated) or wall (measured) time.
    pub clock: ClockKind,
    /// Seeded fault injection (crashes, lost/corrupt deltas, churn).
    pub faults: FaultPlan,
    /// What to do about failures (retry/backoff, resampling, quorum).
    pub recovery: RecoveryPolicy,
}

impl Default for RoundPolicy {
    fn default() -> Self {
        Self::lockstep()
    }
}

impl RoundPolicy {
    /// The degenerate policy: zero latency, wait for everyone, virtual
    /// clock — exactly the lockstep loop.
    pub fn lockstep() -> Self {
        Self {
            latency: LatencyModel::None,
            deadline: None,
            goal: None,
            staleness_alpha: 0.5,
            clock: ClockKind::Virtual,
            faults: FaultPlan::default(),
            recovery: RecoveryPolicy::none(),
        }
    }

    /// True when this policy reproduces the lockstep loop bit-identically
    /// (zero latency, no deadline, no goal, virtual clock, at most the
    /// legacy dropout fault, no recovery). A vanilla fault plan keeps
    /// parity because its dropout draws are the reference's own; any
    /// richer fault or recovery knob changes what a round can do (skip
    /// on quorum, retry, replace) and breaks degeneracy.
    pub fn is_degenerate(&self) -> bool {
        self.latency.is_none()
            && self.deadline.is_none()
            && self.goal.is_none()
            && self.clock == ClockKind::Virtual
            && self.faults.is_vanilla()
            && self.recovery.is_none()
    }

    /// True when the fault/recovery machinery is in play: the driver
    /// routes dispatch through fate draws, availability screens, and
    /// failure events instead of the plain schedule.
    pub fn chaos_active(&self) -> bool {
        !self.faults.is_vanilla() || !self.recovery.is_none()
    }

    /// True when rounds may finalize before every dispatched update
    /// arrives (a deadline or goal-count is set), i.e. updates can be
    /// applied stale in later rounds — FedBuff-style buffering.
    pub fn buffered(&self) -> bool {
        self.deadline.is_some() || self.goal.is_some()
    }

    /// The integer weight a delta contributes to the streaming reduce:
    /// `base` (the shard's sample count, or 1 for uniform rules) under
    /// the degenerate policy, else fixed-point staleness-discounted
    /// (never 0 — an accepted update always contributes).
    pub fn stream_weight(&self, base: u64, staleness: u64) -> u64 {
        if self.is_degenerate() {
            return base;
        }
        let discount = (1.0 + staleness as f64).powf(-self.staleness_alpha);
        ((base as f64 * STALENESS_WEIGHT_SCALE * discount).round() as u64).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lockstep_policy_is_degenerate() {
        let p = RoundPolicy::lockstep();
        assert!(p.is_degenerate());
        assert!(!p.buffered());
        assert_eq!(RoundPolicy::default(), p);
    }

    #[test]
    fn any_async_knob_breaks_degeneracy() {
        let mut p = RoundPolicy::lockstep();
        p.latency = LatencyModel::Constant(0.5);
        assert!(!p.is_degenerate());
        assert!(!p.buffered(), "latency alone does not buffer across rounds");

        let mut p = RoundPolicy::lockstep();
        p.deadline = Some(SimTime::from_secs_f64(2.0));
        assert!(!p.is_degenerate());
        assert!(p.buffered());

        let mut p = RoundPolicy::lockstep();
        p.goal = Some(4);
        assert!(!p.is_degenerate());
        assert!(p.buffered());

        let mut p = RoundPolicy::lockstep();
        p.clock = ClockKind::Wall;
        assert!(!p.is_degenerate());
    }

    #[test]
    fn faults_and_recovery_break_degeneracy_except_vanilla_dropout() {
        // The legacy dropout is drawn from the main experiment RNG in
        // the reference's own order, so it preserves lockstep parity.
        let mut p = RoundPolicy::lockstep();
        p.faults = "dropout:0.25".parse().unwrap();
        assert!(p.is_degenerate(), "vanilla dropout keeps lockstep parity");
        assert!(!p.chaos_active());

        let mut p = RoundPolicy::lockstep();
        p.faults = "crash:0.1".parse().unwrap();
        assert!(!p.is_degenerate());
        assert!(p.chaos_active());

        let mut p = RoundPolicy::lockstep();
        p.faults = "churn:diurnal:60,0.5".parse().unwrap();
        assert!(!p.is_degenerate());

        let mut p = RoundPolicy::lockstep();
        p.recovery.max_retries = 2;
        assert!(!p.is_degenerate());
        assert!(p.chaos_active());

        let mut p = RoundPolicy::lockstep();
        p.recovery.quorum = 0.5;
        assert!(!p.is_degenerate(), "quorum can skip rounds the reference would aggregate");
    }

    #[test]
    fn degenerate_weight_is_the_raw_base() {
        // Bit-parity with the lockstep reference requires the exact
        // same integer weights it pushes.
        let p = RoundPolicy::lockstep();
        for base in [0u64, 1, 37, 5000] {
            assert_eq!(p.stream_weight(base, 0), base);
        }
    }

    #[test]
    fn staleness_discount_is_monotone_and_never_zero() {
        let mut p = RoundPolicy::lockstep();
        p.goal = Some(2);
        p.staleness_alpha = 0.5;
        let fresh = p.stream_weight(50, 0);
        assert_eq!(fresh, 50 * 1024, "fresh updates carry the full scaled base");
        let mut last = fresh;
        for staleness in 1..6 {
            let w = p.stream_weight(50, staleness);
            assert!(w < last, "staleness {staleness}: {w} !< {last}");
            assert!(w >= 1);
            last = w;
        }
        // alpha = 0 disables the discount entirely.
        p.staleness_alpha = 0.0;
        assert_eq!(p.stream_weight(50, 9), 50 * 1024);
    }
}
