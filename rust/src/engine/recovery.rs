//! Failure recovery for the event engine (config: `[faults]` knobs
//! `retry` / `backoff` / `resample` / `quorum`).
//!
//! Where [`super::faults::FaultPlan`] injects failures, a
//! [`RecoveryPolicy`] decides what the server does about them:
//!
//! - **retry with backoff** — a failed client attempt is re-dispatched
//!   after `base * factor^attempt * (1 + jitter * U)` seconds, up to
//!   `max_retries` extra attempts. Local training is a pure function of
//!   `(seed, round, agent)`, so a retry re-sends the *identical* delta
//!   the first attempt computed — the engine caches it and never
//!   recomputes.
//! - **replacement resampling** — when a client fails permanently, an
//!   available, not-yet-used agent is drawn (from a per-round recovery
//!   stream) to fill its cohort slot.
//! - **quorum** — if a round closes with fewer arrivals than
//!   `ceil(quorum * planned_cohort)`, the round is skipped gracefully:
//!   the global model is left byte-unchanged and the skip is logged,
//!   instead of aggregating a degenerate cohort.
//!
//! Backoff jitter comes from the failed attempt's own fault stream
//! (see [`super::faults::AttemptDraw::jitter`]) and replacement picks
//! from [`RecoveryPolicy::resample_rng`], so recovery — like the
//! faults themselves — replays bit-identically from the seed. This
//! retry/backoff schedule is the timeout policy the multi-process
//! transport (ROADMAP) inherits.

use std::str::FromStr;

use crate::engine::faults::FAULT_SALT;
use crate::util::error::{bail, Context, Error, Result};
use crate::util::Rng;

/// Salt (as a `split` argument on the fault stream) for the per-round
/// replacement-resampling stream. Far outside the agent-id range, so it
/// can never collide with an agent's per-round fault stream.
const RESAMPLE_SALT: u64 = u64::MAX;

/// Exponential backoff with seeded jitter, in seconds.
///
/// Config/CLI syntax: `BASE[,FACTOR[,JITTER]]` — e.g. `0.5`, `0.5,2`,
/// `0.5,2,0.1`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Backoff {
    /// Delay before the first retry, in seconds.
    pub base: f64,
    /// Multiplier per further attempt (1.0 = constant delay).
    pub factor: f64,
    /// Jitter amplitude in `[0, 1]`: the delay is scaled by
    /// `1 + jitter * U` with `U` uniform in `[0, 1)`.
    pub jitter: f64,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff { base: 1.0, factor: 2.0, jitter: 0.1 }
    }
}

impl Backoff {
    /// The delay after failed attempt number `attempt` (0-based), given
    /// that attempt's jitter draw `jitter_u` in `[0, 1)`.
    pub fn delay_secs(&self, attempt: u32, jitter_u: f64) -> f64 {
        let growth = self.factor.powi(attempt.min(i32::MAX as u32) as i32);
        self.base * growth * (1.0 + self.jitter * jitter_u)
    }

    /// Reject schedules a struct literal could build but parsing would
    /// not.
    pub fn validate(&self) -> Result<()> {
        if !(self.base.is_finite() && self.base >= 0.0) {
            bail!("backoff base must be a non-negative number of seconds, got {}", self.base);
        }
        if !(self.factor.is_finite() && self.factor >= 1.0) {
            bail!("backoff factor must be >= 1, got {}", self.factor);
        }
        if !(0.0..=1.0).contains(&self.jitter) {
            bail!("backoff jitter must be in [0, 1], got {}", self.jitter);
        }
        Ok(())
    }
}

impl FromStr for Backoff {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut parts = s.split(',').map(str::trim);
        let d = Backoff::default();
        let base = parts
            .next()
            .filter(|p| !p.is_empty())
            .with_context(|| format!("backoff needs BASE[,FACTOR[,JITTER]], got {s:?}"))?
            .parse::<f64>()
            .context("backoff BASE")?;
        let factor = match parts.next() {
            Some(p) => p.parse::<f64>().context("backoff FACTOR")?,
            None => d.factor,
        };
        let jitter = match parts.next() {
            Some(p) => p.parse::<f64>().context("backoff JITTER")?,
            None => d.jitter,
        };
        if parts.next().is_some() {
            bail!("backoff takes at most BASE,FACTOR,JITTER, got {s:?}");
        }
        let b = Backoff { base, factor, jitter };
        b.validate()?;
        Ok(b)
    }
}

impl std::fmt::Display for Backoff {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{},{},{}", self.base, self.factor, self.jitter)
    }
}

/// What the server does about client failures.
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveryPolicy {
    /// Extra attempts per client per round (0 = fail permanently on the
    /// first failure).
    pub max_retries: u32,
    /// Retry delay schedule.
    pub backoff: Backoff,
    /// Resample a replacement client when one fails permanently.
    pub resample: bool,
    /// Minimum fraction of the planned cohort that must arrive, in
    /// `[0, 1]`; a round closing below `ceil(quorum * planned)` is
    /// skipped with the model unchanged. 0 disables the check.
    pub quorum: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy::none()
    }
}

impl RecoveryPolicy {
    /// The inert policy: no retries, no replacements, no quorum.
    pub fn none() -> Self {
        RecoveryPolicy { max_retries: 0, backoff: Backoff::default(), resample: false, quorum: 0.0 }
    }

    /// True when the policy can never change a round's behaviour.
    pub fn is_none(&self) -> bool {
        self.max_retries == 0 && !self.resample && self.quorum <= 0.0
    }

    /// The minimum number of arrivals a `planned`-client round needs to
    /// aggregate.
    pub fn quorum_min(&self, planned: usize) -> usize {
        if self.quorum <= 0.0 {
            0
        } else {
            (self.quorum * planned as f64).ceil() as usize
        }
    }

    /// The per-round stream replacement clients are drawn from. Picks
    /// are made in event order, which is itself deterministic, so
    /// replacement cohorts replay bit-identically.
    pub fn resample_rng(seed: u64, round: usize) -> Rng {
        Rng::new(seed ^ FAULT_SALT).split(RESAMPLE_SALT).split(round as u64)
    }

    /// Reject policies a struct literal could build but parsing/config
    /// validation would not.
    pub fn validate(&self) -> Result<()> {
        self.backoff.validate()?;
        if !(0.0..=1.0).contains(&self.quorum) {
            bail!("quorum must be a fraction in [0, 1], got {}", self.quorum);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_parses_and_roundtrips() {
        for spec in ["0.5", "0.5,2", "0.5,2,0.1", "1,1,0"] {
            let b: Backoff = spec.parse().unwrap();
            assert_eq!(b.to_string().parse::<Backoff>().unwrap(), b, "{spec}");
        }
        assert_eq!("2.5".parse::<Backoff>().unwrap().factor, Backoff::default().factor);
        assert!("".parse::<Backoff>().is_err());
        assert!("-1".parse::<Backoff>().is_err());
        assert!("1,0.5".parse::<Backoff>().is_err(), "factor < 1 shrinks: rejected");
        assert!("1,2,1.5".parse::<Backoff>().is_err());
        assert!("1,2,0.1,9".parse::<Backoff>().is_err());
    }

    #[test]
    fn backoff_delays_grow_exponentially_with_bounded_jitter() {
        let b: Backoff = "0.5,2,0.5".parse().unwrap();
        for attempt in 0..5u32 {
            let lo = 0.5 * 2f64.powi(attempt as i32);
            let d0 = b.delay_secs(attempt, 0.0);
            let d1 = b.delay_secs(attempt, 0.999);
            assert_eq!(d0, lo, "zero jitter draw is the bare schedule");
            assert!(d1 > lo && d1 < lo * 1.5, "jitter adds at most 50%: {d1}");
        }
    }

    #[test]
    fn quorum_minimum_rounds_up() {
        let p = RecoveryPolicy { quorum: 0.5, ..RecoveryPolicy::none() };
        assert_eq!(p.quorum_min(0), 0);
        assert_eq!(p.quorum_min(4), 2);
        assert_eq!(p.quorum_min(5), 3, "ceil, not floor");
        assert_eq!(RecoveryPolicy::none().quorum_min(100), 0);
        let all = RecoveryPolicy { quorum: 1.0, ..RecoveryPolicy::none() };
        assert_eq!(all.quorum_min(7), 7);
    }

    #[test]
    fn none_policy_classification() {
        assert!(RecoveryPolicy::none().is_none());
        assert!(!RecoveryPolicy { max_retries: 1, ..RecoveryPolicy::none() }.is_none());
        assert!(!RecoveryPolicy { resample: true, ..RecoveryPolicy::none() }.is_none());
        assert!(!RecoveryPolicy { quorum: 0.25, ..RecoveryPolicy::none() }.is_none());
        RecoveryPolicy::none().validate().unwrap();
    }

    #[test]
    fn resample_stream_is_per_round_and_deterministic() {
        let mut a = RecoveryPolicy::resample_rng(42, 3);
        let mut b = RecoveryPolicy::resample_rng(42, 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = RecoveryPolicy::resample_rng(42, 4);
        let mut a2 = RecoveryPolicy::resample_rng(42, 3);
        assert_ne!(a2.next_u64(), c.next_u64(), "per-round streams differ");
    }
}
