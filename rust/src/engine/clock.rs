//! Simulated time: [`SimTime`] instants and the [`Clock`] trait.
//!
//! The engine orders events on an integer-microsecond timeline so the
//! total order of any event set is exact (no float-comparison ties).
//! Two clocks drive it:
//!
//! - [`VirtualClock`] — pure simulation: `advance_to` jumps straight to
//!   the next event, so a "30 s round deadline" costs no walltime and a
//!   run is a deterministic function of config + seed.
//! - [`WallClock`] — real runs: `now` is measured elapsed time and
//!   `advance_to` is a no-op (real time cannot be steered); event
//!   timestamps reflect what actually happened.

use std::str::FromStr;
use std::time::Instant;

use crate::util::error::{bail, Error, Result};

/// A point on the engine's timeline: integer microseconds since the
/// start of the run. Integer so that event ordering is a total order
/// with exact ties (see `EventQueue`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(u64);

impl SimTime {
    /// The start of the run.
    pub const ZERO: SimTime = SimTime(0);

    /// From a microsecond count.
    pub fn from_micros(us: u64) -> Self {
        SimTime(us)
    }

    /// From seconds, rounded to the nearest microsecond. Non-finite or
    /// negative inputs clamp to zero.
    pub fn from_secs_f64(secs: f64) -> Self {
        if !secs.is_finite() || secs <= 0.0 {
            return SimTime::ZERO;
        }
        SimTime((secs * 1e6).round().min(u64::MAX as f64) as u64)
    }

    /// Microseconds since the start of the run.
    pub fn as_micros(self) -> u64 {
        self.0
    }

    /// Seconds since the start of the run.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    /// `self + rhs`, saturating at the end of time.
    pub fn saturating_add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_add(rhs.0))
    }

    /// `self - rhs`, saturating at zero.
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

/// Which clock drives the engine (config: `engine.clock`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ClockKind {
    /// Deterministic simulation; the default.
    #[default]
    Virtual,
    /// Measured walltime; per-client latency is the measured local
    /// training time (plus any configured latency model on top).
    Wall,
}

impl ClockKind {
    /// Canonical config/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ClockKind::Virtual => "virtual",
            ClockKind::Wall => "wall",
        }
    }
}

impl FromStr for ClockKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "virtual" => Ok(ClockKind::Virtual),
            "wall" => Ok(ClockKind::Wall),
            other => bail!("unknown clock {other:?} (virtual | wall)"),
        }
    }
}

impl std::fmt::Display for ClockKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The engine's source of time.
pub trait Clock: Send {
    /// Current time on this clock's timeline.
    fn now(&self) -> SimTime;

    /// Move the timeline forward to `t` (never backward). Virtual
    /// clocks jump; wall clocks ignore it — elapsed time is what it is.
    fn advance_to(&mut self, t: SimTime);

    /// Which kind of clock this is.
    fn kind(&self) -> ClockKind;
}

/// Deterministic simulated clock: time is exactly the latest event
/// timestamp it was advanced to.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: SimTime,
}

impl VirtualClock {
    /// A clock at [`SimTime::ZERO`].
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> SimTime {
        self.now
    }

    fn advance_to(&mut self, t: SimTime) {
        self.now = self.now.max(t);
    }

    fn kind(&self) -> ClockKind {
        ClockKind::Virtual
    }
}

/// Real elapsed time since construction.
#[derive(Debug)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    /// A clock whose zero is "now".
    pub fn new() -> Self {
        Self { origin: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> SimTime {
        SimTime::from_secs_f64(self.origin.elapsed().as_secs_f64())
    }

    fn advance_to(&mut self, _t: SimTime) {}

    fn kind(&self) -> ClockKind {
        ClockKind::Wall
    }
}

/// Construct the clock for `kind`.
pub fn from_kind(kind: ClockKind) -> Box<dyn Clock> {
    match kind {
        ClockKind::Virtual => Box::new(VirtualClock::new()),
        ClockKind::Wall => Box::new(WallClock::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_roundtrips_and_clamps() {
        assert_eq!(SimTime::from_secs_f64(1.5).as_micros(), 1_500_000);
        assert!((SimTime::from_micros(250_000).as_secs_f64() - 0.25).abs() < 1e-12);
        assert_eq!(SimTime::from_secs_f64(-3.0), SimTime::ZERO);
        assert_eq!(SimTime::from_secs_f64(f64::NAN), SimTime::ZERO);
        assert_eq!(SimTime::from_secs_f64(f64::INFINITY), SimTime::ZERO);
        let big = SimTime::from_micros(u64::MAX);
        assert_eq!(big.saturating_add(big), big);
        assert_eq!(SimTime::ZERO.saturating_sub(big), SimTime::ZERO);
    }

    #[test]
    fn virtual_clock_jumps_and_never_rewinds() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), SimTime::ZERO);
        c.advance_to(SimTime::from_micros(500));
        assert_eq!(c.now().as_micros(), 500);
        c.advance_to(SimTime::from_micros(100)); // stale event time
        assert_eq!(c.now().as_micros(), 500);
    }

    #[test]
    fn wall_clock_is_monotone_and_ignores_advance() {
        let mut c = WallClock::new();
        let a = c.now();
        c.advance_to(SimTime::from_secs_f64(3600.0));
        let b = c.now();
        assert!(b >= a);
        assert!(b.as_secs_f64() < 60.0, "advance_to must not steer a wall clock");
    }

    #[test]
    fn clock_kind_parses_and_displays() {
        assert_eq!("virtual".parse::<ClockKind>().unwrap(), ClockKind::Virtual);
        assert_eq!(" WALL ".parse::<ClockKind>().unwrap(), ClockKind::Wall);
        assert!("cuckoo".parse::<ClockKind>().is_err());
        assert_eq!(ClockKind::Virtual.to_string(), "virtual");
        assert_eq!(ClockKind::default(), ClockKind::Virtual);
    }
}
